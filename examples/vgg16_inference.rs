//! End-to-end driver (paper §6 / Figure 7 measured): VGG16 inference
//! through the full three-layer stack on a real small workload.
//!
//!     make artifacts && cargo run --release --example vgg16_inference
//!
//! The network's 16 layers run as AOT Pallas/XLA executables chained on the
//! PJRT device; the decision-tree selector picks one of the 8 deployed
//! kernel configurations per layer. Three backends are compared, exactly
//! like the paper's SYCL-DNN / SYCL-BLAS / CLBlast figure.

use std::path::PathBuf;
use std::time::Instant;

use kernelsel::classify::codegen::CompiledTree;
use kernelsel::classify::{ClassifierKind, KernelClassifier};
use kernelsel::coordinator::{SelectorPolicy, VggEngine};
use kernelsel::dataset::{benchmark_shapes, config_by_name};
use kernelsel::devsim::{generate_dataset, profile_by_name};
use kernelsel::runtime::{Manifest, Runtime};
use kernelsel::util::fill_buffer;

const ITERS: usize = 8;

fn main() -> Result<(), String> {
    let dir = PathBuf::from("artifacts");
    let runtime = Runtime::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let network = std::env::args().nth(1).unwrap_or_else(|| "vgg16-tiny".into());

    // Tune the runtime selector: benchmark data -> decision tree over the
    // shipped 8-kernel deployment. Prefer *measured* local-CPU data from
    // `kernelsel collect` (the paper tunes on the target device!); fall
    // back to the simulated CPU profile.
    let measured = std::path::Path::new("results/measured_cpu.csv");
    let ds = if measured.exists() {
        println!("tuning selector on measured local-CPU data ...");
        kernelsel::dataset::PerfDataset::load("local-cpu", measured)?
    } else {
        println!("tuning selector on simulated i7-6700k data (run `kernelsel collect` for measured tuning) ...");
        generate_dataset(profile_by_name("i7-6700k").unwrap(), &benchmark_shapes())
    };
    let deployed: Vec<usize> = manifest
        .deployed
        .iter()
        .map(|n| config_by_name(n).unwrap().index())
        .collect();
    let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, 7);
    let tree = CompiledTree::compile(&clf).unwrap();
    let single = config_by_name(&manifest.single_best).unwrap().index();

    println!("\n=== {network}: single-image inference, {ITERS} timed iterations ===");
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>14}",
        "backend", "mean ms", "min ms", "p-layer ms", "distinct cfgs"
    );
    for policy in [
        SelectorPolicy::Tree(tree.clone()),
        SelectorPolicy::Single(single),
        SelectorPolicy::Xla,
    ] {
        let engine = VggEngine::load(&runtime, &manifest, &network, &policy)?;
        let image = fill_buffer(99, engine.input_shape().iter().product());
        // Warmup compiles everything.
        let (logits, timings) = engine.infer(&image)?;
        let mut times = Vec::with_capacity(ITERS);
        for _ in 0..ITERS {
            let t0 = Instant::now();
            engine.infer(&image)?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let slowest = timings
            .iter()
            .max_by(|a, b| a.secs.partial_cmp(&b.secs).unwrap())
            .unwrap();
        println!(
            "{:<16} {:>10.2} {:>10.2} {:>10.2} {:>14}   top-logit {:.3}",
            engine.backend(),
            mean,
            min,
            slowest.secs * 1e3,
            engine.distinct_configs(),
            logits.iter().cloned().fold(f64::NEG_INFINITY as f32, f32::max),
        );
    }

    println!("\nper-layer breakdown (tuned backend):");
    let engine = VggEngine::load(&runtime, &manifest, &network, &SelectorPolicy::Tree(tree))?;
    let image = fill_buffer(99, engine.input_shape().iter().product());
    let (_, timings) = engine.infer(&image)?;
    for t in &timings {
        println!(
            "  {:<10} gemm {:>22}  cfg {:<6}  {:>8.3} ms",
            t.layer,
            t.gemm_shape.label(),
            t.config.map(|c| c.to_string()).unwrap_or_else(|| "xla".into()),
            t.secs * 1e3
        );
    }
    Ok(())
}
