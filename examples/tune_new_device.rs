//! Tuning workflow for a brand-new device (the paper's headline use case:
//! "new devices [can] be supported with very little developer effort").
//!
//!     cargo run --release --example tune_new_device [device]
//!
//! Walks the full automated pipeline for a device we never hand-tuned:
//!   1. collect the benchmark dataset (simulated Mali G71 here),
//!   2. compare the six kernel-subset selection methods (Fig 5/6 style),
//!   3. pick PCA+K-means @ 8 kernels, train the decision-tree selector,
//!   4. evaluate classifier vs oracle on held-out shapes,
//!   5. emit the deploy JSON (feed to `python -m compile.aot --deploy`)
//!      and the nested-if Rust selector source.

use kernelsel::classify::codegen::{to_rust_source, CompiledTree};
use kernelsel::classify::{ClassifierKind, KernelClassifier};
use kernelsel::dataset::{benchmark_shapes, config_by_index, Normalization};
use kernelsel::devsim::{generate_dataset, profile_by_name};
use kernelsel::selection::{
    achievable_percent, achieved_percent, select, single_best, Method, ALL_METHODS,
};

fn main() {
    let device = std::env::args().nth(1).unwrap_or_else(|| "mali-g71".into());
    let profile = profile_by_name(&device).expect("known device profile");
    println!("== step 1: collect benchmark data for {device} ==");
    let ds = generate_dataset(profile, &benchmark_shapes());
    println!(
        "   {} size sets x 640 configs; best-config range {:.1}..{:.1} GFLOP/s",
        ds.n_shapes(),
        (0..ds.n_shapes()).map(|i| ds.best_gflops(i)).fold(f64::INFINITY, f64::min),
        (0..ds.n_shapes()).map(|i| ds.best_gflops(i)).fold(0.0, f64::max),
    );

    let split = ds.split(0.8, 7);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);

    println!("\n== step 2: selection methods at k=8 (held-out oracle %) ==");
    for method in ALL_METHODS {
        let picks = select(method, &train, Normalization::Standard, 8, 7);
        println!("   {:12} {:6.2}%", method.name(), achievable_percent(&test, &picks));
    }

    println!("\n== step 3: deploy PCA+K-means @ 8 + decision tree ==");
    let deployed = select(Method::PcaKMeans, &train, Normalization::Standard, 8, 7);
    let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &train, &deployed, 7);
    let tree = CompiledTree::compile(&clf).unwrap();

    println!("\n== step 4: held-out evaluation ==");
    let oracle = achievable_percent(&test, &deployed);
    let achieved = achieved_percent(&test, &clf.choices(&test));
    println!("   oracle over deployed kernels : {oracle:6.2}% of optimal");
    println!("   decision-tree selector       : {achieved:6.2}% of optimal");
    println!("   selector tree               : {} nodes", tree.n_nodes());

    println!("\n== step 5: deployment outputs ==");
    let names: Vec<String> = deployed
        .iter()
        .map(|&c| format!("\"{}\"", config_by_index(c).name()))
        .collect();
    println!(
        "deploy.json:\n{{\n  \"deployed\": [{}],\n  \"single_best\": \"{}\"\n}}",
        names.join(", "),
        config_by_index(single_best(&train)).name()
    );
    println!("\ngenerated runtime selector (first 24 lines):");
    for line in to_rust_source(&tree, "select_kernel").lines().take(24) {
        println!("  {line}");
    }
    println!("  ...");
    println!(
        "\nnext: python -m compile.aot --deploy deploy.json  # ship these {} kernels",
        deployed.len()
    );
}
