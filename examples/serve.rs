//! Serving demo: drive the sharded executor pool with concurrent client
//! threads and report latency/throughput — the library as a GEMM-serving
//! microservice.
//!
//!     cargo run --release --example serve -- --shards 4
//!     cargo run --release --example serve -- --shards 4 --routing affinity
//!     cargo run --release --example serve -- --routing load-aware --imbalance 2
//!     cargo run --release --example serve -- --profile r9-nano \
//!         --retune-interval 150 --drift-threshold 1.2 --require-swap
//!     cargo run --release --example serve -- --telemetry-out /tmp/telemetry.json
//!     cargo run --release --example serve -- --telemetry-in /tmp/telemetry.json
//!     cargo run --release --example serve -- --admission bounded \
//!         --max-inflight 64 --max-queue-us 5000
//!     cargo run --release --example serve -- --admission deadline-shed \
//!         --max-queue-us 2000
//!     cargo run --release --example serve -- --engine cpu \
//!         --retune-interval 150 --require-swap
//!     cargo run --release --example serve -- --tenants 3 --quota 32 \
//!         --slo interactive --admission bounded
//!     cargo run --release --example serve -- --trace-out /tmp/trace.json \
//!         --metrics-out /tmp/metrics.prom
//!     cargo run --release --example serve -- --explore 100,64 \
//!         --telemetry-out /tmp/warm.json
//!     cargo run --release --example serve -- --explore 100,64 \
//!         --telemetry-in /tmp/warm.json --retune-interval 150 \
//!         --require-warm-start
//!
//! Clients submit mixed-shape GEMM requests; the submit path resolves each
//! to a deployed kernel via the memoized decision-tree selector and routes
//! it to one of N executor shards — by shape affinity alone
//! (`--routing affinity`), or load-aware (the default): affinity as a
//! preference, spilling to the least-loaded shard when the preferred
//! shard's load gauge exceeds `--imbalance N` times the minimum, with idle
//! shards stealing ready batches from overloaded peers. Each shard batches
//! same-executable requests on its own backend. Runs out of the box on the
//! SimBackend (no artifacts, no native XLA needed); per-shard batch,
//! fallback, spill and steal metrics print at shutdown.
//!
//! With `--retune-interval MS` a background retuner watches the
//! measured-cost telemetry and hot-swaps re-tuned selectors under
//! traffic. `--profile NAME` picks the simulated serving device — serving
//! a different device than the i7-6700k the selector was tuned on is what
//! makes drift (and a swap) happen. `--require-swap` keeps serving extra
//! traffic rounds until a swap is observed and exits non-zero if none
//! lands (the CI tuning smoke).
//!
//! `--telemetry-out PATH` writes the final telemetry snapshot as
//! `kernelsel-telemetry-v1` JSON at shutdown, and `--telemetry-in PATH`
//! seeds the sink from such a file at startup — measured cost hints and
//! retune state survive restarts instead of re-warming from nothing.
//!
//! `--admission unbounded|bounded|deadline-shed` picks the overload
//! policy (default unbounded — accept everything). `--max-inflight N`
//! caps pool-wide in-flight requests for `bounded`; `--max-queue-us N`
//! is the shared budget knob: the per-shard queue-time budget for
//! `bounded` (admit + shed-on-drain) and the end-to-end deadline for
//! `deadline-shed`. Rejected and shed counts print at shutdown.
//!
//! `--tenants N` registers N equal-weight tenants and round-robins the
//! client threads across them (`--tenants 0`, the default, serves
//! everything anonymously — the pre-tenant behavior). `--quota Q` caps
//! tenant-attributed in-flight requests pool-wide at Q slots, split into
//! weighted-fair reserved shares; past-share submits reject with
//! `quota-exceeded` and a retry hint. `--slo interactive|standard|batch`
//! sets every registered tenant's SLO class, scaling its admission
//! latency budgets. Per-tenant goodput/rejected/shed/p99 lanes print in
//! the shutdown report.
//!
//! `--trace-out PATH` turns the flight recorder on and writes the full
//! lifecycle trace at shutdown: `kernelsel-trace-v1` JSON at PATH plus a
//! Chrome Trace Event Format twin at PATH.chrome.json (load it in
//! `chrome://tracing` / Perfetto). `--trace-sample N` records every Nth
//! request chain (default 1 = all). `--metrics-out PATH` rewrites the
//! live Prometheus-style exposition (per-shard and per-tenant lanes,
//! typed refusals, selection regret) to PATH every 200 ms while serving
//! and once more at shutdown.
//!
//! `--chaos SEED,RATE,KINDS` arms seeded fault injection on every shard:
//! `KINDS` is a `+`-separated subset of `transient`, `corrupt`, `spike`
//! and `panic` (e.g. `--chaos 7,500,transient+corrupt`), `RATE` the
//! per-execution fault probability in permille inside the plan's fixed
//! fault window. Faulted runs exercise the integrity canary, the variant
//! quarantine breaker and the shard supervisor; the shutdown report's
//! quarantine/respawn/retry counters print either way.
//! `--require-recovery` keeps trickling traffic (up to 20 s) until the
//! pool demonstrably self-healed — quarantine tripped AND restored, plus
//! a worker respawn when the plan panics — and exits non-zero otherwise
//! (the CI chaos smoke).
//!
//! `--explore EPS,BUDGET[,SEED[,TOPK]]` arms runtime exploration: a
//! seeded epsilon fraction (`EPS` permille) of submits is redirected to
//! an unmeasured-but-shipped config at the same shape, capped at
//! `BUDGET` lifetime probes, and the first submit of a never-seen shape
//! bucket queues an off-hot-path micro-benchmark of the `TOPK`
//! prior-ranked healthy variants. Probes ride idle capacity only and
//! are shed to zero before admission rejects in-SLO work. Probe
//! measurements persist through `--telemetry-out`, so the next run's
//! `--telemetry-in` restores measured coverage instead of re-probing.
//! `--require-warm-start` (with `--explore`, `--telemetry-in` and
//! `--retune-interval`) keeps trickling traffic until the first retune
//! lands on the restored measurements and exits non-zero if that took
//! any live probing — the CI warm-start smoke. `--requests N` overrides
//! the per-client request count (default 24) so an exploration run can
//! drive enough traffic to measure a whole (bucket x config) matrix.
//!
//! `--engine sim|cpu` picks the backend (default sim). With `cpu` the
//! pool executes real f32 GEMM on the host through the `engine::cpu`
//! variant family: traffic drives the CPU manifest's bounded shape
//! buckets, costs are priced by the analytic CPU model, and the run
//! starts from a deliberately naive selector (the scalar single-threaded
//! variant pinned for every shape) so the measured-telemetry retuner has
//! real ground to win back — the `--require-swap` smoke then asserts a
//! hot-swap lands on real hardware, not just in simulation.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use kernelsel::classify::codegen::CompiledTree;
use kernelsel::classify::{ClassifierKind, KernelClassifier};
use kernelsel::coordinator::{
    AdmissionPolicy, Coordinator, PoolConfig, Routing, SelectorPolicy, SloClass, TenantId,
    TenantSpec, TraceConfig,
};
use kernelsel::dataset::{benchmark_shapes, config_by_name, GemmShape};
use kernelsel::devsim::{generate_dataset, profile_by_name};
use kernelsel::engine::cpu::cpu_variants;
use kernelsel::engine::{EngineKind, FaultPlan};
use kernelsel::runtime::Manifest;
use kernelsel::tuning::{ExploreConfig, RetuneConfig, TelemetrySnapshot};
use kernelsel::util::fill_buffer;

const CLIENTS: usize = 4;
const REQUESTS_PER_CLIENT: usize = 24;
// `--requests N` overrides REQUESTS_PER_CLIENT — exploration smokes drive
// enough traffic to measure a whole (bucket x config) matrix in one run.

fn flag_str(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn flag(name: &str, default: usize) -> usize {
    flag_str(name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn has_flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

/// First sample value of an exposition counter family (`0` when absent) —
/// how the recovery wait watches quarantine/respawn counters land live.
fn prom_counter(text: &str, name: &str) -> usize {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .find(|l| l.split([' ', '{']).next() == Some(name))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse::<f64>().ok())
        .map_or(0, |v| v as usize)
}

fn main() -> Result<(), String> {
    let shards = flag("--shards", 4);
    let routing = match flag_str("--routing") {
        Some(v) => Routing::by_name(&v)
            .ok_or_else(|| format!("unknown --routing {v:?} (affinity|load-aware)"))?,
        None => Routing::default(),
    };
    let imbalance = match flag_str("--imbalance") {
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| format!("invalid --imbalance {v:?} (want a number, e.g. 4)"))?,
        None => 4.0,
    };
    // The simulated serving device. The selector below is tuned on the
    // i7-6700k, so serving any *other* profile makes the measured costs
    // drift from the predictions — what online retuning exists to fix.
    let profile = match flag_str("--profile") {
        Some(v) => {
            profile_by_name(&v)
                .ok_or_else(|| format!("unknown --profile {v:?}"))?
                .name
        }
        None => "i7-6700k",
    };
    let drift_threshold = match flag_str("--drift-threshold") {
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| format!("invalid --drift-threshold {v:?} (want a factor > 1)"))?,
        None => 1.25,
    };
    let retune = flag_str("--retune-interval")
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| format!("invalid --retune-interval {v:?} (want millis)"))
        })
        .transpose()?
        .map(|millis| RetuneConfig {
            interval: Duration::from_millis(millis.max(1)),
            drift_threshold,
            min_cell_samples: 2,
            ..RetuneConfig::default()
        });
    let require_swap = has_flag("--require-swap");
    if require_swap && retune.is_none() {
        return Err("--require-swap needs --retune-interval".to_string());
    }
    let max_inflight = flag("--max-inflight", 256);
    let max_queue_us = flag("--max-queue-us", 5_000) as u64;
    let admission = match flag_str("--admission") {
        Some(v) => AdmissionPolicy::by_name(&v, max_inflight, max_queue_us * 1_000)
            .ok_or_else(|| {
                format!("unknown --admission {v:?} (unbounded|bounded|deadline-shed)")
            })?,
        None => AdmissionPolicy::Unbounded,
    };
    let n_tenants = flag("--tenants", 0);
    let quota_slots = flag("--quota", 0);
    let slo = match flag_str("--slo") {
        Some(v) => SloClass::by_name(&v)
            .ok_or_else(|| format!("unknown --slo {v:?} (interactive|standard|batch)"))?,
        None => SloClass::Standard,
    };
    let tenants: Vec<TenantSpec> = (1..=n_tenants)
        .map(|i| TenantSpec::new(TenantId(i as u32), format!("tenant{i}"), 1, slo))
        .collect();
    let trace_out = flag_str("--trace-out");
    let trace = trace_out.as_ref().map(|_| TraceConfig {
        sample_every: flag("--trace-sample", 1).max(1) as u64,
        ..TraceConfig::default()
    });
    let metrics_out = flag_str("--metrics-out");
    let chaos = flag_str("--chaos").map(|v| FaultPlan::parse(&v)).transpose()?;
    let require_recovery = has_flag("--require-recovery");
    if require_recovery && chaos.is_none() {
        return Err("--require-recovery needs --chaos".to_string());
    }
    let explore = flag_str("--explore").map(|v| ExploreConfig::parse(&v)).transpose()?;
    let require_warm_start = has_flag("--require-warm-start");
    if require_warm_start
        && (explore.is_none() || retune.is_none() || flag_str("--telemetry-in").is_none())
    {
        return Err(
            "--require-warm-start needs --explore, --retune-interval and --telemetry-in"
                .to_string(),
        );
    }
    let engine_name = flag_str("--engine").unwrap_or_else(|| "sim".to_string());
    let dir = PathBuf::from("artifacts");

    // Engine-specific setup: selector policy, engine spec, hint pricing
    // and the traffic shape mix.
    let (policy, engine, pricing_profile, shapes) = match engine_name.as_str() {
        "sim" => {
            // Real artifacts when `make artifacts` has run; synthetic
            // deployment (served by the SimBackend) otherwise.
            let manifest = Manifest::load_or_synthetic(&dir);
            // Tuned policy: decision tree over the shipped deployment.
            let ds = generate_dataset(profile_by_name("i7-6700k").unwrap(), &benchmark_shapes());
            let deployed: Vec<usize> = manifest
                .deployed
                .iter()
                .map(|n| config_by_name(n).unwrap().index())
                .collect();
            let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, 7);
            let policy = SelectorPolicy::Tree(CompiledTree::compile(&clf).unwrap());
            // The shape mix a DNN-serving workload would issue (vgg16-tiny
            // GEMMs + generic buckets — all shipped in both manifests).
            let shapes = vec![
                GemmShape::new(128, 128, 128, 1),
                GemmShape::new(512, 784, 512, 1),
                GemmShape::new(64, 2304, 128, 1),
                GemmShape::new(1024, 27, 64, 1),
                GemmShape::new(256, 576, 128, 1),
            ];
            // The policy above is tuned on the i7-6700k dataset; pricing
            // the hints on the same device makes serving any other
            // --profile show up as measurable drift.
            (policy, EngineKind::Sim { profile }, Some("i7-6700k"), shapes)
        }
        "cpu" => {
            // Start from the worst reasonable prior — the scalar
            // single-threaded variant pinned for every shape — so the
            // measured-telemetry retuner has real performance to win back.
            let naive = cpu_variants()
                .into_iter()
                .find(|v| v.name() == "cpu_small_pa_sc_t1")
                .expect("scalar single-threaded variant exists");
            // CPU traffic drives the manifest's bounded shape buckets
            // (these execute for real on the host per request). Leaving
            // pricing_profile unset selects the analytic CPU cost model.
            let shapes: Vec<GemmShape> = Manifest::synthetic_cpu_shapes()
                .into_iter()
                .map(|(m, k, n, b)| GemmShape::new(m, k, n, b))
                .collect();
            (SelectorPolicy::Single(naive.index), EngineKind::Cpu { threads: 0 }, None, shapes)
        }
        other => return Err(format!("unknown --engine {other:?} (sim|cpu)")),
    };

    let backend_desc = match &engine {
        EngineKind::Sim { .. } => format!("{} ({profile})", engine.name()),
        _ => engine.name().to_string(),
    };
    let pool = PoolConfig {
        shards,
        engine,
        routing,
        imbalance,
        admission,
        retune: retune.clone(),
        pricing_profile,
        tenants,
        quota_slots,
        trace,
        fault: chaos,
        explore,
        ..PoolConfig::default()
    };
    println!(
        "starting coordinator: {} shard(s), policy={}, backend={backend_desc}, \
         routing={} (imbalance {:.1}), admission={}, retune={}, tenants={}",
        shards,
        policy.name(),
        pool.routing.name(),
        pool.imbalance,
        pool.admission.name(),
        match &retune {
            Some(cfg) => format!("every {:?} (drift > {:.2}x)", cfg.interval, cfg.drift_threshold),
            None => "off".to_string(),
        },
        match n_tenants {
            0 => "off (anonymous)".to_string(),
            n => format!("{n} x {} (quota {quota_slots})", slo.name()),
        },
    );
    if let Some(e) = &explore {
        println!(
            "explore armed: eps {}/1000, budget {} probe(s), seed {}, first-sight top-{}",
            e.eps_permille, e.budget, e.seed, e.top_k
        );
    }
    if let Some(plan) = &chaos {
        println!(
            "chaos armed: seed {} window [{}, {}) transient/corrupt/spike \
             {}/{}/{} permille, panic_at {:?}",
            plan.seed,
            plan.onset,
            plan.fault_until,
            plan.transient_permille,
            plan.corrupt_permille,
            plan.spike_permille,
            plan.panic_at,
        );
    }
    let coord = Arc::new(Coordinator::start_pool(dir, policy, pool)?);

    // Restore persisted telemetry before traffic flows: measured cost
    // hints and retune state pick up where the previous run stopped.
    if let Some(path) = flag_str("--telemetry-in") {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading --telemetry-in {path}: {e}"))?;
        let doc = kernelsel::util::json::parse(&text)
            .map_err(|e| format!("parsing --telemetry-in {path}: {e}"))?;
        let snapshot = TelemetrySnapshot::from_json(&doc)
            .map_err(|e| format!("--telemetry-in {path}: {e}"))?;
        coord.telemetry().absorb(&snapshot);
        println!(
            "seeded telemetry from {path}: {} cells, {} samples",
            snapshot.cells.len(),
            coord.telemetry().total_samples()
        );
    }

    // Periodic exposition scraper: rewrite the live metrics text while
    // traffic flows, the way a Prometheus agent would read it.
    let scraper_stop = Arc::new(AtomicBool::new(false));
    let scraper = metrics_out.clone().map(|path| {
        let coord = coord.clone();
        let stop = scraper_stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                if let Err(e) = std::fs::write(&path, coord.metrics_text()) {
                    eprintln!("writing --metrics-out {path}: {e}");
                    return;
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        })
    });

    // Warm the executable caches (first-touch compiles would otherwise
    // dominate the latency distribution — see EXPERIMENTS.md §Perf).
    for &s in &shapes {
        let lhs = fill_buffer(1, s.batch * s.m * s.k);
        let rhs = fill_buffer(2, s.batch * s.k * s.n);
        let _ = coord.call(s, lhs, rhs);
    }

    let requests_per_client = flag("--requests", REQUESTS_PER_CLIENT);
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for client in 0..CLIENTS {
        let coord = coord.clone();
        let shapes = shapes.clone();
        // Round-robin the client threads across the registered tenants;
        // with --tenants 0 everything stays anonymous.
        let tenant = if n_tenants > 0 {
            TenantId((client % n_tenants + 1) as u32)
        } else {
            TenantId::ANONYMOUS
        };
        joins.push(std::thread::spawn(move || {
            let mut ok = 0usize;
            let mut total_latency = 0.0f64;
            for i in 0..requests_per_client {
                let s = shapes[(client + i) % shapes.len()];
                let lhs = fill_buffer((client * 1000 + i) as u32, s.batch * s.m * s.k);
                let rhs = fill_buffer((client * 1000 + i + 500) as u32, s.batch * s.k * s.n);
                match coord.call_as(tenant, s, lhs, rhs) {
                    Ok(resp) if resp.result.is_ok() => {
                        ok += 1;
                        total_latency += resp.latency.as_secs_f64();
                    }
                    Ok(resp) => eprintln!("request failed: {:?}", resp.result.err()),
                    Err(e) => eprintln!("coordinator error: {e}"),
                }
            }
            (ok, total_latency)
        }));
    }
    let mut ok = 0usize;
    let mut latency_sum = 0.0;
    for j in joins {
        let (o, l) = j.join().expect("client thread");
        ok += o;
        latency_sum += l;
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = CLIENTS * requests_per_client;

    // Keep trickling traffic until the background retuner lands a swap
    // (the CI tuning smoke asserts adaptivity, not just liveness).
    if require_swap {
        let deadline = Instant::now() + Duration::from_secs(20);
        while coord.retune_stats().swaps == 0 && Instant::now() < deadline {
            // Trickle two cheap shapes; telemetry already covers the
            // full mix from the main run.
            for (i, s) in [shapes[0], shapes[3]].iter().enumerate() {
                let lhs = fill_buffer(i as u32, s.batch * s.m * s.k);
                let rhs = fill_buffer(i as u32 + 3, s.batch * s.k * s.n);
                let _ = coord.call(*s, lhs, rhs);
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let stats = coord.retune_stats();
        println!(
            "retune wait: swaps={} retunes={} drift_trips={} generation={}",
            stats.swaps, stats.retunes, stats.drift_trips, stats.generation
        );
    }

    // Keep trickling traffic until the first retune lands on the restored
    // telemetry. The CI warm-start smoke asserts that a pool seeded from a
    // previous run's snapshot converges on measured data without a single
    // live probe (the exit gate below).
    let mut warm_start_met = !require_warm_start;
    if require_warm_start {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            let tuning = coord.retune_stats();
            if tuning.retunes >= 1 {
                warm_start_met = true;
                break;
            }
            if Instant::now() >= deadline {
                break;
            }
            for (i, s) in [shapes[0], shapes[shapes.len() - 1]].iter().enumerate() {
                let lhs = fill_buffer(i as u32, s.batch * s.m * s.k);
                let rhs = fill_buffer(i as u32 + 3, s.batch * s.k * s.n);
                let _ = coord.call(*s, lhs, rhs);
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let tuning = coord.retune_stats();
        let probes = coord.explore_stats();
        println!(
            "warm-start wait: retunes={} swaps={} probes issued={} shed={} \
             first-sight runs={}",
            tuning.retunes,
            tuning.swaps,
            probes.probes_issued,
            probes.probes_shed,
            probes.first_sight_runs
        );
    }

    // Keep trickling traffic until the pool demonstrably self-healed from
    // the injected faults: quarantine tripped AND restored (plus a worker
    // respawn when the plan panics). The CI chaos smoke asserts recovery,
    // not just survival.
    let mut recovery_met = !require_recovery;
    if require_recovery {
        let needs_respawn = chaos.as_ref().is_some_and(|p| p.panic_at.is_some());
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            let text = coord.metrics_text();
            let trips = prom_counter(&text, "kernelsel_quarantine_trips_total");
            let restores = prom_counter(&text, "kernelsel_quarantine_restores_total");
            let respawns = prom_counter(&text, "kernelsel_worker_respawns");
            if trips >= 1 && restores >= 1 && (!needs_respawn || respawns >= 1) {
                recovery_met = true;
                println!(
                    "recovery wait: trips={trips} restores={restores} respawns={respawns}"
                );
                break;
            }
            if Instant::now() >= deadline {
                println!(
                    "recovery wait: DEADLINE trips={trips} restores={restores} \
                     respawns={respawns}"
                );
                break;
            }
            // Trickle two cheap shapes so executions keep advancing the
            // fault window, the quarantine cooloff and the probe cadence.
            for (i, s) in [shapes[0], shapes[3]].iter().enumerate() {
                let lhs = fill_buffer(i as u32, s.batch * s.m * s.k);
                let rhs = fill_buffer(i as u32 + 3, s.batch * s.k * s.n);
                let _ = coord.call(*s, lhs, rhs);
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    // Final exposition dump after the scraper stops: the file on disk
    // must reflect every completed request, not the last 200 ms tick.
    scraper_stop.store(true, Ordering::Relaxed);
    if let Some(handle) = scraper {
        let _ = handle.join();
    }
    if let Some(path) = &metrics_out {
        std::fs::write(path, coord.metrics_text())
            .map_err(|e| format!("writing --metrics-out {path}: {e}"))?;
        println!("wrote metrics exposition to {path}");
    }
    // The recorder outlives the pool via its own Arc, so the trace is
    // exported after shutdown — once every shard has drained and flushed.
    let recorder = coord.recorder().cloned();

    let coverage = explore.map(|_| coord.explore_coverage(1));
    let telemetry = coord.telemetry().clone();
    let report = Arc::try_unwrap(coord).ok().expect("sole owner").stop_detailed();

    // Persist the telemetry snapshot after shutdown — the pool drains its
    // first-sight micro-benchmark worker on stop, so the export carries
    // every probe measurement for the next run's --telemetry-in.
    if let Some(path) = flag_str("--telemetry-out") {
        let snapshot = telemetry.snapshot();
        let text = snapshot.to_json().to_string() + "\n";
        std::fs::write(&path, text).map_err(|e| format!("writing --telemetry-out {path}: {e}"))?;
        println!("wrote telemetry snapshot ({} cells) to {path}", snapshot.cells.len());
    }
    println!(
        "\n{ok}/{total} requests ok in {wall:.3}s -> {:.1} req/s, mean latency {:.2} ms",
        total as f64 / wall,
        latency_sum / ok.max(1) as f64 * 1e3
    );
    println!("{}", report.summary());
    if !admission.is_unbounded() {
        println!(
            "admission ({}): rejected={} shed={} inflight_peak={}",
            admission.name(),
            report.total.rejected,
            report.total.shed,
            report.total.inflight_peak
        );
    }
    if let (Some(path), Some(rec)) = (&trace_out, &recorder) {
        std::fs::write(path, rec.to_json().to_string() + "\n")
            .map_err(|e| format!("writing --trace-out {path}: {e}"))?;
        let chrome_path = format!("{path}.chrome.json");
        std::fs::write(&chrome_path, rec.to_chrome_json().to_string() + "\n")
            .map_err(|e| format!("writing {chrome_path}: {e}"))?;
        println!(
            "wrote trace ({} events, {} chains, {} dropped) to {path} (+ {chrome_path})",
            rec.recorded(),
            rec.chains(),
            rec.dropped()
        );
    }
    if chaos.is_some() {
        println!(
            "chaos: quarantine trips={} probes={} restores={} respawns={} \
             retries spent={} denied={}",
            report.total.quarantine_trips,
            report.total.quarantine_probes,
            report.total.quarantine_restores,
            report.total.worker_respawns,
            report.total.retries,
            report.total.retries_denied,
        );
    }
    if let Some((measured, total_pairs)) = coverage {
        println!(
            "explore: coverage {measured}/{total_pairs} (bucket x healthy-shipped pairs), \
             probes issued={} shed={} completed={}, first-sight shapes={} runs={}",
            report.explore.probes_issued,
            report.explore.probes_shed,
            report.explore.probes_completed,
            report.explore.first_sight_shapes,
            report.explore.first_sight_runs,
        );
    }
    if require_swap && report.total.selector_swaps == 0 {
        return Err("no selector swap observed (drift never retuned the pool)".to_string());
    }
    if require_warm_start {
        if !warm_start_met {
            return Err(
                "warm start failed: no retune landed on the restored telemetry within the \
                 deadline"
                    .to_string(),
            );
        }
        if report.explore.probes_issued > 0 {
            return Err(format!(
                "warm start violated: {} live probe(s) issued despite restored coverage",
                report.explore.probes_issued
            ));
        }
    }
    if !recovery_met {
        return Err(
            "pool did not self-heal: quarantine never tripped+restored (or the panicked \
             worker was never respawned) within the recovery deadline"
                .to_string(),
        );
    }
    Ok(())
}
