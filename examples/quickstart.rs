//! Quickstart: load the tuned-kernel library's artifacts, run one GEMM
//! through the PJRT runtime with two backends, and compare.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the 60-second tour: the manifest tells us which kernels were
//! shipped (the binary-size constraint of the paper), the runtime compiles
//! the HLO once, and the same buffers run through both the Pallas
//! single-best kernel and the XLA-dot comparator.

use std::path::PathBuf;
use std::time::Instant;

use kernelsel::dataset::config_by_name;
use kernelsel::runtime::{Manifest, Runtime};
use kernelsel::util::fill_buffer;

fn main() -> Result<(), String> {
    let dir = PathBuf::from("artifacts");
    let runtime = Runtime::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    println!(
        "platform: {} | {} artifacts | deployed kernels: {:?}",
        runtime.platform(),
        manifest.artifacts.len(),
        manifest.deployed
    );

    // A mid-size GEMM from the quickstart bucket set.
    let (m, k, n, b) = (512, 784, 512, 1);
    let lhs = fill_buffer(1, b * m * k);
    let rhs = fill_buffer(2, b * k * n);
    let flops = 2.0 * (b * m * k * n) as f64;

    let best = config_by_name(&manifest.single_best).expect("config").index();
    for (label, cfg) in [("pallas single-best", Some(best)), ("xla dot", None)] {
        let meta = manifest
            .find_matmul(cfg, m, k, n, b)
            .expect("artifact for quickstart shape")
            .clone();
        // First call compiles; second call measures the steady state.
        let warm = runtime.run_matmul(&meta, &lhs, &rhs)?;
        let t0 = Instant::now();
        let out = runtime.run_matmul(&meta, &lhs, &rhs)?;
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(out.len(), warm.len());
        println!(
            "{label:>20}: {:>8.2} ms  ({:.2} GFLOP/s)  [{}]",
            secs * 1e3,
            flops / secs / 1e9,
            meta.path
        );
    }

    let stats = runtime.stats();
    println!(
        "runtime: {} compiles ({:.2}s), {} executions ({:.3}s)",
        stats.compiles, stats.compile_secs, stats.executions, stats.execute_secs
    );
    Ok(())
}
