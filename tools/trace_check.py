#!/usr/bin/env python3
"""Validate a `kernelsel-trace-v1` flight-recorder export.

Usage:
    python3 tools/trace_check.py TRACE.json

Toolchain-free sanity gate for the traces `serve --trace-out` (and any
embedder of `FlightRecorder::to_json`) writes — CI runs it against the
bench-smoke trace so a schema or lifecycle regression fails the build
without needing a Rust toolchain on the checking side. Three passes:

  1. **Schema** — required top-level keys with the right types, and every
     event carries the common fields plus the kind-specific payload
     fields (a `submit` has a shape and a cost, an `execute` has
     predicted/measured costs and a generation, ...).
  2. **Clock** — the exported timeline is globally sorted by timestamp,
     and in particular each shard's own events never move backwards.
  3. **Causality** — every traced chain (`seq > 0`) opens with exactly
     one `submit` and reaches exactly one terminal (`complete`, `shed`
     or `reject`); a completed chain carries at least one `execute`;
     unchained events (`seq == 0`) are only the pool-level kinds
     (`batch`, `steal`, `swap`, the quarantine transitions, `respawn`,
     `retry`, `explore-probe`). Skipped (with a note) when the recorder reported dropped
     events — an incomplete timeline cannot prove lifecycle violations.
  4. **Quarantine lifecycle** — per config, `quarantine-probe` events
     appear only while that config is blocked (between a
     `quarantine-trip` and its `quarantine-restore`), and a restore
     never lands on a config that was not tripped first. `respawn`
     events are accepted wherever they appear: the panic that killed the
     worker is by nature untraced (the unwinding shard writes no event),
     so there is no preceding marker to anchor them to.

Exits 0 when green; prints each violation and exits 1 otherwise.
"""
import json
import sys

SCHEMA = "kernelsel-trace-v1"
NUMERIC = (int, float)

# Common fields every event carries; `shard` is numeric or null.
COMMON = {"t_ns": NUMERIC, "seq": NUMERIC, "kind": str, "tenant": NUMERIC}

# Kind-specific payload fields and their types.
KIND_FIELDS = {
    "submit": {"m": NUMERIC, "k": NUMERIC, "n": NUMERIC, "batch": NUMERIC, "cost_ns": NUMERIC},
    "route": {"spilled": bool},
    "reject": {"reason": str, "retry_after_ns": NUMERIC},
    "steal": {"victim": NUMERIC, "requests": NUMERIC},
    "batch": {"size": NUMERIC, "oldest_queued_ns": NUMERIC},
    "execute": {"generation": NUMERIC, "predicted_ns": NUMERIC, "measured_ns": NUMERIC},
    "complete": {"latency_ns": NUMERIC, "ok": bool},
    "shed": {"queued_ns": NUMERIC, "budget_ns": NUMERIC},
    "swap": {"generation": NUMERIC, "domain": NUMERIC},
    "quarantine-trip": {"config": NUMERIC, "trips": NUMERIC},
    "quarantine-probe": {"config": NUMERIC},
    "quarantine-restore": {"config": NUMERIC, "restores": NUMERIC},
    "respawn": {"requests": NUMERIC},
    "retry": {"reason": str, "attempt": NUMERIC, "tokens_milli": NUMERIC},
    "explore-probe": {"config": NUMERIC, "measured_ns": NUMERIC},
}
TERMINALS = {"complete", "shed", "reject"}
POOL_LEVEL = {
    "batch",
    "steal",
    "swap",
    "quarantine-trip",
    "quarantine-probe",
    "quarantine-restore",
    "respawn",
    "retry",
    "explore-probe",
}


def check_schema(doc, errors):
    for key, want in [
        ("schema", str),
        ("sample_every", NUMERIC),
        ("dropped", NUMERIC),
        ("chains", NUMERIC),
        ("events", list),
    ]:
        if not isinstance(doc.get(key), want):
            errors.append(f"top-level: missing or mistyped {key!r}")
    if doc.get("schema") != SCHEMA:
        errors.append(f"top-level: schema is {doc.get('schema')!r}, want {SCHEMA!r}")


def check_event(i, ev, errors):
    if not isinstance(ev, dict):
        errors.append(f"event[{i}]: not an object")
        return None
    for key, want in COMMON.items():
        if not isinstance(ev.get(key), want):
            errors.append(f"event[{i}]: missing or mistyped {key!r}")
            return None
    if not (ev.get("shard") is None or isinstance(ev.get("shard"), NUMERIC)):
        errors.append(f"event[{i}]: 'shard' must be numeric or null")
        return None
    kind = ev["kind"]
    if kind not in KIND_FIELDS:
        errors.append(f"event[{i}]: unknown kind {kind!r}")
        return None
    for key, want in KIND_FIELDS[kind].items():
        if not isinstance(ev.get(key), want):
            errors.append(f"event[{i}] ({kind}): missing or mistyped {key!r}")
    if kind == "execute" and not (
        ev.get("config") is None or isinstance(ev.get("config"), NUMERIC)
    ):
        errors.append(f"event[{i}] (execute): 'config' must be numeric or null")
    return ev


def check_clock(events, errors):
    last_global = None
    last_by_shard = {}
    for i, ev in enumerate(events):
        t = ev["t_ns"]
        if last_global is not None and t < last_global:
            errors.append(f"event[{i}]: timestamp {t} before predecessor {last_global}")
        last_global = t
        shard = ev.get("shard")
        if shard is not None:
            prev = last_by_shard.get(shard)
            if prev is not None and t < prev:
                errors.append(f"event[{i}]: shard {shard} clock moved backwards ({t} < {prev})")
            last_by_shard[shard] = t


def check_causality(events, errors):
    chains = {}
    for i, ev in enumerate(events):
        seq, kind = ev["seq"], ev["kind"]
        if seq == 0:
            if kind not in POOL_LEVEL:
                errors.append(f"event[{i}]: unchained {kind!r} (seq 0 is pool-level only)")
            continue
        cell = chains.setdefault(seq, {"submit": 0, "terminal": 0, "execute": 0, "kinds": []})
        cell["kinds"].append(kind)
        if kind == "submit":
            cell["submit"] += 1
        elif kind in TERMINALS:
            cell["terminal"] += 1
        elif kind == "execute":
            cell["execute"] += 1
    for seq, cell in sorted(chains.items()):
        if cell["submit"] != 1:
            errors.append(f"chain {seq}: {cell['submit']} submit events (want exactly 1)")
        if cell["terminal"] != 1:
            errors.append(
                f"chain {seq}: {cell['terminal']} terminal events "
                f"(want exactly one of complete/shed/reject; saw {cell['kinds']})"
            )
        if "complete" in cell["kinds"] and cell["execute"] < 1:
            errors.append(f"chain {seq}: completed without an execute event")
    return len(chains)


def check_quarantine_lifecycle(events, errors):
    """Per config: probes only while blocked, restores only after a trip.

    A config becomes blocked at its first `quarantine-trip` and unblocked
    at `quarantine-restore` (re-trips while blocked are failed probes and
    keep it blocked). `quarantine-probe` outside a blocked span means the
    breaker probed a healthy config; a restore without a preceding trip
    means it promoted a config that was never quarantined. `respawn`
    events are deliberately not anchored: the panic that necessitated one
    is untraced (see module docstring).
    """
    blocked = set()
    for i, ev in enumerate(events):
        kind = ev["kind"]
        if kind not in ("quarantine-trip", "quarantine-probe", "quarantine-restore"):
            continue
        config = ev["config"]
        if kind == "quarantine-trip":
            blocked.add(config)
        elif kind == "quarantine-probe":
            if config not in blocked:
                errors.append(
                    f"event[{i}]: probe of config {config} while not quarantined"
                )
        elif kind == "quarantine-restore":
            if config not in blocked:
                errors.append(
                    f"event[{i}]: restore of config {config} that never tripped"
                )
            blocked.discard(config)


def main():
    if len(sys.argv) != 2:
        print(__doc__.strip().splitlines()[0], file=sys.stderr)
        print("usage: python3 tools/trace_check.py TRACE.json", file=sys.stderr)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot read {path}: {e}", file=sys.stderr)
        return 1

    errors = []
    check_schema(doc, errors)
    events = [e for e in doc.get("events", []) if isinstance(e, dict)]
    events = [e for i, e in enumerate(events) if check_event(i, e, errors) is not None]
    if not errors:
        check_clock(events, errors)
        dropped = doc.get("dropped", 0)
        if dropped:
            print(f"note: {dropped} dropped events — causality pass skipped")
            n_chains = sum(1 for e in events if e["kind"] == "submit")
        else:
            n_chains = check_causality(events, errors)
            check_quarantine_lifecycle(events, errors)

    if errors:
        for err in errors:
            print(f"FAIL: {err}", file=sys.stderr)
        print(f"{path}: {len(errors)} violation(s)", file=sys.stderr)
        return 1
    print(
        f"{path}: OK — {len(events)} events, {n_chains} traced chain(s), "
        f"sample_every={doc['sample_every']}, dropped={doc['dropped']}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
