#!/usr/bin/env python3
"""Ratchet a committed CI bench baseline from a measured run.

Usage:
    python3 tools/ratchet_baseline.py MEASURED.json TARGET.json \
        [--allow-regression] [--provenance TEXT]

MEASURED.json is the artifact a green bench-smoke run uploaded
(BENCH_pool.json from the coordinator_skew bench, or BENCH_cpu.json from
the cpu_gemm bench); TARGET.json is the committed baseline it replaces
(ci/BENCH_pool.json / ci/BENCH_cpu.json). The tool:

  1. validates the measured file against its declared schema
     (kernelsel-bench-pool-v1 or kernelsel-bench-cpu-v1) — every
     required key present with the right type;
  2. checks the improvement direction against the existing baseline:
     a ratchet only moves floors UP. For the pool schema, each matched
     (mix, routing, shards, admission) cell's throughput_rps must not
     drop (overload/tenants cells are exempt — they are self-gated by
     the bench, not by the baseline); for the cpu schema,
     regret_geomean and each regime's max_spread must not drop.
     --allow-regression downgrades direction failures to warnings (for
     deliberately lowering a floor after e.g. a runner downgrade);
  3. rewrites TARGET.json with the measured document plus an injected
     "provenance" line recording where the numbers came from, so a
     hand-written seed is distinguishable from a measured ratchet.

Exit codes: 0 ratcheted, 1 validation/direction failure, 2 usage error.
"""
import datetime
import json
import os
import sys

POOL_SCHEMA = "kernelsel-bench-pool-v1"
CPU_SCHEMA = "kernelsel-bench-cpu-v1"

POOL_ENTRY_KEYS = {
    "mix": str, "routing": str, "admission": str, "shards": (int, float),
    "requests": (int, float), "throughput_rps": (int, float),
    "goodput_rps": (int, float), "p50_ms": (int, float),
    "p99_ms": (int, float), "spilled": (int, float), "steals": (int, float),
    "rejected": (int, float), "shed": (int, float),
}
CPU_ENTRY_KEYS = {
    "regime": str, "m": (int, float), "k": (int, float), "n": (int, float),
    "batch": (int, float), "best_variant": str, "best_gflops": (int, float),
    "worst_variant": str, "worst_gflops": (int, float),
    "spread": (int, float), "chosen_variant": str,
    "chosen_gflops": (int, float), "ratio_to_best": (int, float),
}
# Self-gated pool mixes: the bench enforces their acceptance criteria via
# exit codes, so the ratchet never direction-checks them.
SELF_GATED_MIXES = {"overload", "tenants", "explore"}


def fail(msg):
    print(f"ratchet_baseline: {msg}", file=sys.stderr)
    sys.exit(1)


def check_entry(entry, keys, where):
    if not isinstance(entry, dict):
        fail(f"{where}: entry is not an object")
    for key, typ in keys.items():
        if key not in entry:
            fail(f"{where}: missing key {key!r}")
        if not isinstance(entry[key], typ) or isinstance(entry[key], bool):
            fail(f"{where}: key {key!r} has type {type(entry[key]).__name__}")


def validate(doc, path):
    if not isinstance(doc, dict):
        fail(f"{path}: top level is not an object")
    schema = doc.get("schema")
    if schema == POOL_SCHEMA:
        entries = doc.get("entries")
        if not isinstance(entries, list) or not entries:
            fail(f"{path}: entries must be a non-empty array")
        for i, e in enumerate(entries):
            check_entry(e, POOL_ENTRY_KEYS, f"{path} entries[{i}]")
            tenant = e.get("tenant")
            if tenant is not None and not isinstance(tenant, str):
                fail(f"{path} entries[{i}]: tenant must be a string")
    elif schema == CPU_SCHEMA:
        for key in ("mode", "threads", "reps", "k_best", "regret_geomean"):
            if key not in doc:
                fail(f"{path}: missing top-level key {key!r}")
        if not isinstance(doc["regret_geomean"], (int, float)):
            fail(f"{path}: regret_geomean is not a number")
        entries = doc.get("entries")
        if not isinstance(entries, list) or not entries:
            fail(f"{path}: entries must be a non-empty array")
        for i, e in enumerate(entries):
            check_entry(e, CPU_ENTRY_KEYS, f"{path} entries[{i}]")
        regimes = doc.get("regimes")
        if not isinstance(regimes, list) or not regimes:
            fail(f"{path}: regimes must be a non-empty array")
        for i, r in enumerate(regimes):
            check_entry(r, {"regime": str, "max_spread": (int, float)},
                        f"{path} regimes[{i}]")
    else:
        fail(f"{path}: unknown schema {schema!r}")
    return schema


def pool_cell_key(entry):
    return (entry["mix"], entry["routing"], int(entry["shards"]),
            entry.get("admission", "unbounded"), entry.get("tenant"))


def direction_failures(schema, old, new):
    """Floors that the candidate would LOWER relative to the baseline."""
    out = []
    if schema == POOL_SCHEMA:
        old_cells = {pool_cell_key(e): e for e in old.get("entries", [])
                     if isinstance(e, dict) and "mix" in e}
        for e in new["entries"]:
            if e["mix"] in SELF_GATED_MIXES:
                continue
            prev = old_cells.get(pool_cell_key(e))
            if prev is None or "throughput_rps" not in prev:
                continue
            if e["throughput_rps"] < prev["throughput_rps"]:
                out.append(
                    f"{e['mix']}/{e['routing']}/{e['shards']}: throughput "
                    f"{e['throughput_rps']:.1f} < baseline "
                    f"{prev['throughput_rps']:.1f}")
    else:
        old_regret = old.get("regret_geomean")
        if isinstance(old_regret, (int, float)) \
                and new["regret_geomean"] < old_regret:
            out.append(f"regret_geomean {new['regret_geomean']:.3f} < "
                       f"baseline {old_regret:.3f}")
        old_regimes = {r.get("regime"): r.get("max_spread")
                       for r in old.get("regimes", [])
                       if isinstance(r, dict)}
        for r in new["regimes"]:
            prev = old_regimes.get(r["regime"])
            if isinstance(prev, (int, float)) and r["max_spread"] < prev:
                out.append(f"{r['regime']} max_spread "
                           f"{r['max_spread']:.2f} < baseline {prev:.2f}")
    return out


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    allow_regression = "--allow-regression" in argv
    provenance = None
    if "--provenance" in argv:
        i = argv.index("--provenance")
        if i + 1 >= len(argv):
            print(__doc__, file=sys.stderr)
            return 2
        provenance = argv[i + 1]
        args = [a for a in args if a != provenance]
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    measured_path, target_path = args

    with open(measured_path) as f:
        measured = json.load(f)
    schema = validate(measured, measured_path)
    print(f"OK: {measured_path} is valid {schema}")

    if os.path.exists(target_path):
        with open(target_path) as f:
            try:
                old = json.load(f)
            except ValueError:
                fail(f"{target_path}: existing baseline is not JSON")
        if old.get("schema") not in (None, schema):
            fail(f"{target_path}: schema {old.get('schema')!r} != {schema!r}")
        lowered = direction_failures(schema, old, measured)
        if lowered and not allow_regression:
            print("ratchet_baseline: candidate LOWERS committed floors "
                  "(pass --allow-regression to accept):", file=sys.stderr)
            for line in lowered:
                print(f"  {line}", file=sys.stderr)
            return 1
        for line in lowered:
            print(f"WARNING (accepted): {line}")
        print(f"OK: improvement direction vs {target_path} "
              f"({len(lowered)} floors lowered)")
    else:
        print(f"no existing baseline at {target_path}; seeding fresh")

    if provenance is None:
        stamp = datetime.datetime.now(datetime.timezone.utc)
        provenance = (f"ratcheted from {os.path.basename(measured_path)} by "
                      f"tools/ratchet_baseline.py on "
                      f"{stamp.strftime('%Y-%m-%d')}")
    measured["provenance"] = provenance
    with open(target_path, "w") as f:
        json.dump(measured, f, indent=2)
        f.write("\n")
    print(f"OK: wrote {target_path} (provenance: {provenance})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
