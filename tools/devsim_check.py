#!/usr/bin/env python3
"""Python port of rust/src/devsim to de-risk the retune_convergence bench design.

Simulates: initial selector = per-shape best shipped config under devsim(i7);
serving measures devsim(nano) times; greedy retune cycles with per-config
geometric-mean drift correction on devsim(i7) priors. Checks the post-swap
selector strictly beats the cold one in mean simulated latency on a mix.
"""
import math

MASK = (1 << 64) - 1

def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK

class Rng:
    def __init__(self, seed):
        s = []
        sm = seed & MASK
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s
        self.spare = None

    def next_u64(self):
        s = self.s
        result = (rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def fork(self, stream):
        """Port of Rng::fork: derive an independent per-stream generator."""
        return Rng(self.next_u64() ^ (stream * 0x9E3779B97F4A7C15) & MASK)

    def below(self, n):
        """Port of Rng::below (uniform-scaled, same float path as Rust)."""
        assert n > 0, "Rng.below(0)"
        return int(self.uniform() * n) % n

    def normal(self):
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        while True:
            u1 = self.uniform()
            if u1 <= 2.2250738585072014e-308:
                continue
            u2 = self.uniform()
            r = math.sqrt(-2.0 * math.log(u1))
            theta = 2.0 * math.pi * u2
            self.spare = r * math.sin(theta)
            return r * math.cos(theta)

TILE_SIZES = [1, 2, 4, 8]
WORKGROUPS = [(1, 64), (1, 128), (8, 8), (8, 16), (8, 32), (16, 8), (16, 16), (32, 8), (64, 1), (128, 1)]

def config_by_index(idx):
    ti, wi = idx // 10, idx % 10
    ri, ai, ci = ti // 16, (ti // 4) % 4, ti % 4
    wr, wc = WORKGROUPS[wi]
    return dict(acc_r=TILE_SIZES[ri], acc_a=TILE_SIZES[ai], acc_c=TILE_SIZES[ci], wg_r=wr, wg_c=wc)

def config_name(c):
    return f"r{c['acc_r']}a{c['acc_a']}c{c['acc_c']}_wg{c['wg_r']}x{c['wg_c']}"

NAME_TO_INDEX = {config_name(config_by_index(i)): i for i in range(640)}

PROFILES = {
    "r9-nano": dict(kind="gpu", compute_units=64.0, peak_gflops=8192.0, mem_bw_gbs=512.0,
                    cache_bw_gbs=1024.0, cache_kb=2048.0, threads_for_peak=512.0,
                    regs_per_thread=160.0, spill_exponent=1.6, ilp_for_peak=16.0,
                    intensity_half=1.15, vec_width=2.0, kernel_launch_us=8.0,
                    wg_overhead_us=0.10, cache_pressure=0.18, noise_sigma=0.055),
    "i7-6700k": dict(kind="cpu", compute_units=4.0, peak_gflops=512.0, mem_bw_gbs=34.0,
                     cache_bw_gbs=300.0, cache_kb=8192.0, threads_for_peak=16.0,
                     regs_per_thread=224.0, spill_exponent=0.8, ilp_for_peak=8.0,
                     intensity_half=0.7, vec_width=8.0, kernel_launch_us=25.0,
                     wg_overhead_us=0.4, cache_pressure=0.5, noise_sigma=0.06),
}

def vector_eff(p, a, c):
    pref = p["vec_width"]
    def one(w):
        if w <= pref:
            return min(0.55 + 0.45 * (w / pref), 1.0)
        return 1.0 - 0.08 * (w / pref - 1.0)
    return min(max(one(a) * one(c), 0.2), 1.0)

def wg_shape_eff(p, wr, wc):
    if p["kind"] == "cpu":
        return 1.0 - 0.02 * ((wr * wc) / 256.0)
    aspect = max(wr / wc, wc / wr)
    return min(max(1.0 - 0.035 * math.log2(aspect), 0.6), 1.0)

def coalesce_eff(p, wr, wc, a, c):
    if p["kind"] == "cpu":
        width = (max(a, c) * 4.0) / (p["vec_width"] * 4.0)
        return min(max(0.5 + 0.5 * min(width, 1.0), 0.3), 1.0)
    row_span = min(wc * c, 64.0) / 64.0
    col_pen = 1.0 - 0.1 * (wr / (wr + 16.0))
    return (0.35 + 0.65 * row_span) * col_pen

def noise_seed(device, shape, cfg_index):
    h = 0xcbf29ce484222325
    def eat(x):
        nonlocal h
        h ^= x
        h = (h * 0x100000001b3) & MASK
    for b in device.encode():
        eat(b)
    m, k, n, batch = shape
    for v in [m, k, n, batch, cfg_index]:
        eat(v)
    return h

def simulate(pname, shape, cfg_index):
    p = PROFILES[pname]
    cfg = config_by_index(cfg_index)
    m, k, n, b = [float(x) for x in shape]
    r, a, c = float(cfg["acc_r"]), float(cfg["acc_a"]), float(cfg["acc_c"])
    wr, wc = float(cfg["wg_r"]), float(cfg["wg_c"])

    tiles_m = math.ceil(m / r)
    tiles_n = math.ceil(n / c)
    threads = b * tiles_m * tiles_n
    wgs_m = math.ceil(tiles_m / wr)
    wgs_n = math.ceil(tiles_n / wc)
    wgs = b * wgs_m * wgs_n

    padded_m = wgs_m * wr * r
    padded_n = wgs_n * wc * c
    useful_flops = 2.0 * b * m * k * n
    padded_flops = 2.0 * b * padded_m * k * padded_n

    regs = r * c + 2.0 * r * a + 2.0 * a * c + 8.0
    if regs <= p["regs_per_thread"]:
        spill = 1.0
    else:
        spill = (p["regs_per_thread"] / regs) ** p["spill_exponent"]
    ilp = min(r * c / p["ilp_for_peak"], 1.0) ** 0.5
    intensity = r * c / (r + c)
    intensity_eff = intensity / (intensity + p["intensity_half"])
    vec = vector_eff(p, a, c)
    compute_rate = p["peak_gflops"] * 1e9 * ilp * intensity_eff * spill * vec

    hw_threads = p["compute_units"] * p["threads_for_peak"]
    par = min(threads / hw_threads, 1.0)
    waves = math.ceil(wgs / p["compute_units"])
    tail = min(max(wgs / (waves * p["compute_units"]), 0.05), 1.0)
    wg_fit = wg_shape_eff(p, wr, wc)
    rate = compute_rate * par * (tail ** 0.5) * wg_fit
    t_compute = padded_flops / max(rate, 1.0)

    blocks_m = wgs_m
    blocks_n = wgs_n
    bytes_ = 4.0 * b * (padded_m * k * blocks_n + k * padded_n * blocks_m + m * n)
    working_set = 4.0 * b * (m * k + k * n + m * n)
    bw = (p["cache_bw_gbs"] if working_set <= p["cache_kb"] * 1024.0 else p["mem_bw_gbs"]) * 1e9
    bw_eff = coalesce_eff(p, wr, wc, a, c)
    block_ws = 4.0 * (wr * r * k + k * wc * c)
    cache_per_cu = p["cache_kb"] * 1024.0 / p["compute_units"]
    cache_eff = 1.0 if block_ws <= cache_per_cu else (cache_per_cu / block_ws) ** p["cache_pressure"]
    t_mem = bytes_ / (bw * bw_eff * cache_eff)

    t_overhead = p["kernel_launch_us"] * 1e-6 + (wgs / p["compute_units"]) * p["wg_overhead_us"] * 1e-6
    t = max(t_compute, t_mem) + t_overhead
    gflops = useful_flops / t / 1e9
    eps = Rng(noise_seed(pname, shape, cfg_index)).normal()
    gflops *= math.exp(p["noise_sigma"] * eps)
    return max(gflops, 0.05)

def secs(pname, shape, cfg_index):
    m, k, n, b = shape
    flops = 2.0 * b * m * k * n
    g = max(simulate(pname, shape, cfg_index), 1e-3)
    return flops / (g * 1e9)

SHIPPED = ["r8a4c4_wg16x16", "r4a4c4_wg8x16", "r4a8c4_wg16x16", "r2a4c8_wg8x32",
           "r8a2c2_wg8x8", "r1a4c2_wg1x128", "r2a8c2_wg32x8", "r4a2c8_wg16x8"]
POOL = [NAME_TO_INDEX[s] for s in SHIPPED]

BUCKETS = [(32, 32, 32, 1), (32, 32, 32, 4), (64, 64, 64, 1), (64, 64, 64, 4),
           (128, 128, 128, 1), (256, 256, 256, 1), (512, 784, 512, 1), (512, 784, 512, 16),
           (64, 2304, 128, 1), (1024, 27, 64, 1), (256, 576, 128, 1), (196, 4608, 512, 1),
           (32, 12321, 27, 1), (1, 4096, 1000, 1)]

print(f"{'shape':>22} {'i7-best':>18} {'nano-best':>18}  t_nano(i7pick)  t_nano(nanopick)  ratio")
diverge = 0
for s in BUCKETS:
    t_i7 = {c: secs("i7-6700k", s, c) for c in POOL}
    t_nano = {c: secs("r9-nano", s, c) for c in POOL}
    i7_best = min(POOL, key=lambda c: t_i7[c])
    nano_best = min(POOL, key=lambda c: t_nano[c])
    r = t_nano[i7_best] / t_nano[nano_best]
    if i7_best != nano_best:
        diverge += 1
    print(f"{str(s):>22} {config_name(config_by_index(i7_best)):>18} "
          f"{config_name(config_by_index(nano_best)):>18}  {t_nano[i7_best]*1e6:10.1f}us  "
          f"{t_nano[nano_best]*1e6:10.1f}us  {r:6.2f}x")
print(f"\n{diverge}/{len(BUCKETS)} buckets where i7-best != nano-best on the shipped pool\n")

# ---- greedy retune-loop simulation ------------------------------------------
# Mirrors rust/benches/retune_convergence.rs: cold picks = per-shape best
# shipped config under devsim(i7); serving measures devsim(nano); each cycle
# retunes on measured cells + drift-corrected i7 priors, iterating until the
# pick set stabilizes (measured-backed picks can never be worse than cold).
MIX = {(32, 32, 32, 1): 6, (64, 64, 64, 1): 2, (32, 32, 32, 4): 2,
       (64, 64, 64, 4): 4, (128, 128, 128, 1): 2, (1024, 27, 64, 1): 2}

shapes = list(MIX)
t_i7 = {s: {c: secs("i7-6700k", s, c) for c in POOL} for s in shapes}
t_nano = {s: {c: secs("r9-nano", s, c) for c in POOL} for s in shapes}

picks = {s: min(POOL, key=lambda c: t_i7[s][c]) for s in shapes}
measured = {}  # (shape, cfg) -> t_nano

def mean_latency(p):
    return sum(MIX[s] * t_nano[s][p[s]] for s in shapes) / sum(MIX.values())

L0 = mean_latency(picks)
print(f"phase 0 (cold, i7-tuned) mean simulated latency: {L0*1e6:.1f} us")

for cycle in range(1, 25):
    for s in shapes:
        measured[(s, picks[s])] = t_nano[s][picks[s]]
    ratios, all_logs = {}, []
    for (s, c), tm in measured.items():
        lr = math.log(tm / t_i7[s][c])
        ratios.setdefault(c, []).append(lr)
        all_logs.append(lr)
    per_cfg = {c: math.exp(sum(v) / len(v)) for c, v in ratios.items()}
    global_ratio = math.exp(sum(all_logs) / len(all_logs))
    new_picks = {}
    for s in shapes:
        def value(c):
            if (s, c) in measured:
                return measured[(s, c)]
            return t_i7[s][c] * per_cfg.get(c, global_ratio)
        new_picks[s] = min(POOL, key=value)
    changed = sum(1 for s in shapes if new_picks[s] != picks[s])
    picks = new_picks
    print(f"cycle {cycle}: {changed} picks changed, mean latency "
          f"{mean_latency(picks)*1e6:.1f} us (global drift ratio {global_ratio:.2f})")
    if changed == 0:
        break

L_final = mean_latency(picks)
L_opt = mean_latency({s: min(POOL, key=lambda c: t_nano[s][c]) for s in shapes})
print(f"\nfinal {L_final*1e6:.1f} us vs cold {L0*1e6:.1f} us "
      f"({L0/L_final:.2f}x better); oracle {L_opt*1e6:.1f} us")
assert L_final < L0, "converged retune must strictly improve mean latency"
print("OK: retune loop strictly improves mean latency at convergence")

# ---- flattened-tree evaluator sanity check ----------------------------------
# Mirrors rust/src/ml/decision_tree.rs: an exact-fit CART classifier
# (DecisionTreeA: unbounded depth, gini splits, last-max tie-break) trained on
# the shipped selector's labels (per-bucket best shipped config under
# devsim(i7)), then flattened into the SoA arrays (feat / thr / kids) the
# serving hot path walks. The flat branchless walk must agree with the
# recursive reference on every bucket, and the exact-fit property means both
# must reproduce the training labels.

def features(shape):
    m, k, n, b = [float(x) for x in shape]
    return [math.log2(m), math.log2(k), math.log2(n), math.log2(b),
            math.log2(m * n * b), math.log2(m * k * n * b),
            math.log2(m / n), math.log2(k / math.sqrt(m * n))]

def gini(counts):
    total = sum(counts.values())
    if total == 0:
        return 0.0
    return 1.0 - sum((c / total) ** 2 for c in counts.values())

def best_split(rows, labels):
    """Best (feature, threshold) by gini improvement; None when pure."""
    n = len(rows)
    if n < 2 or len(set(labels)) == 1:
        return None
    from collections import Counter
    parent = gini(Counter(labels))
    best = None
    for f in range(len(rows[0])):
        order = sorted(range(n), key=lambda i: rows[i][f])
        for pos in range(1, n):
            lo, hi = rows[order[pos - 1]][f], rows[order[pos]][f]
            if hi <= lo:
                continue
            left = Counter(labels[i] for i in order[:pos])
            right = Counter(labels[i] for i in order[pos:])
            score = parent - (pos / n) * gini(left) - ((n - pos) / n) * gini(right)
            if best is None or score > best[0] + 1e-12:
                best = (score, f, (lo + hi) / 2.0)
    if best is None or best[0] <= 1e-12:
        return None
    return best[1], best[2]

def build_tree(rows, labels):
    """Nodes as dicts; exact fit (distinct rows, min_leaf=1)."""
    nodes = []

    def rec(idx):
        me = len(nodes)
        nodes.append(None)
        sub_rows = [rows[i] for i in idx]
        sub_labels = [labels[i] for i in idx]
        split = best_split(sub_rows, sub_labels)
        if split is None:
            from collections import Counter
            counts = Counter(sub_labels)
            top = max(counts.values())
            # Rust's max_by_key keeps the LAST maximal element while
            # enumerating a dense per-class counts array by index, i.e.
            # ties resolve to the HIGHEST class index — not to Counter
            # insertion order.
            cls = max(c for c in counts if counts[c] == top)
            nodes[me] = dict(leaf=True, cls=cls)
            return me
        f, t = split
        left = [i for i in idx if rows[i][f] <= t]
        right = [i for i in idx if rows[i][f] > t]
        nodes[me] = dict(leaf=False, f=f, t=t,
                         l=rec(left), r=rec(right))
        return me

    rec(list(range(len(rows))))
    return nodes

def predict_recursive(nodes, row):
    i = 0
    while True:
        node = nodes[i]
        if node["leaf"]:
            return node["cls"]
        i = node["l"] if row[node["f"]] <= node["t"] else node["r"]

def flatten_tree(nodes):
    """SoA arrays exactly like FlatTree: feat (None=leaf), thr, kids."""
    LEAF = None
    feat, thr, kids = [], [], []
    for node in nodes:
        if node["leaf"]:
            feat.append(LEAF)
            thr.append(0.0)
            kids.append((node["cls"], node["cls"]))
        else:
            feat.append(node["f"])
            thr.append(node["t"])
            kids.append((node["l"], node["r"]))
    return feat, thr, kids

def predict_flat(flat, row):
    feat, thr, kids = flat
    i = 0
    while True:
        f = feat[i]
        if f is None:
            return kids[i][0]
        i = kids[i][1 if row[f] > thr[i] else 0]

shipped_labels = [min(POOL, key=lambda c: secs("i7-6700k", s, c)) for s in BUCKETS]
rows = [features(s) for s in BUCKETS]
tree_nodes = build_tree(rows, shipped_labels)
flat = flatten_tree(tree_nodes)
mismatch = 0
for s, row, label in zip(BUCKETS, rows, shipped_labels):
    rec_pick = predict_recursive(tree_nodes, row)
    flat_pick = predict_flat(flat, row)
    assert flat_pick == rec_pick, f"flat walk diverges from recursive at {s}"
    if rec_pick != label:
        mismatch += 1
assert mismatch == 0, f"exact-fit tree missed {mismatch}/{len(BUCKETS)} training buckets"
n_leaves = sum(1 for f in flat[0] if f is None)
print(f"OK: flattened SoA evaluator == recursive CART on all {len(BUCKETS)} buckets "
      f"({len(flat[0])} nodes, {n_leaves} leaves, exact fit on the shipped selector)")

# ---- admission-control predicate check --------------------------------------
# Port of rust/src/coordinator/admission.rs: the DeadlineShed reject
# predicate (deadline_would_shed) and the BoundedQueue / DeadlineShed admit
# decisions with their retry-after hints, verified on a grid of synthetic
# gauge states built from the same devsim cost hints the router prices
# with (cost + 20k ns fixed overhead per queued request, exactly
# ShardLoad::score_ns). All arithmetic is saturating u64, mirrored here.

U64_MAX = (1 << 64) - 1
QUEUED_OVERHEAD_NS = 20_000      # server.rs QUEUED_OVERHEAD_NS
MIN_RETRY_HINT_NS = 1_000        # admission.rs MIN_RETRY_HINT_NS

def sat_add(a, b):
    return min(a + b, U64_MAX)

def deadline_would_shed(cost_ns, backlog_ns, deadline_ns):
    """Port of admission::deadline_would_shed (saturating add)."""
    return sat_add(backlog_ns, cost_ns) > deadline_ns

def admit_bounded(max_inflight, max_queue_ns, cost_ns, backlog_ns, inflight):
    """Port of AdmissionPolicy::BoundedQueue::admit.
    Returns None on admit, else ('queue-full', retry_hint_ns)."""
    if inflight >= max_inflight:
        return ("queue-full", max(backlog_ns // max(inflight, 1), MIN_RETRY_HINT_NS))
    if backlog_ns > max_queue_ns:
        return ("queue-full", max(backlog_ns - max_queue_ns, MIN_RETRY_HINT_NS))
    return None

def admit_deadline(deadline_ns, cost_ns, backlog_ns):
    """Port of AdmissionPolicy::DeadlineShed::admit.
    Returns None on admit, else ('deadline-unmeetable', retry_hint_ns)."""
    if deadline_would_shed(cost_ns, backlog_ns, deadline_ns):
        hint = max(sat_add(backlog_ns, cost_ns) - deadline_ns, 0)
        return ("deadline-unmeetable", max(hint, MIN_RETRY_HINT_NS))
    return None

# Synthetic gauge states: shard backlogs built from real devsim cost hints
# for the shipped hot shapes at queue depths 0..24, exactly as the gauges
# accumulate them (sum of per-request cost + fixed overhead per queued).
hot_shapes = [(128, 128, 128, 1), (64, 64, 64, 1), (32, 32, 32, 4), (256, 256, 256, 1)]
proxy = NAME_TO_INDEX["r4a4c4_wg16x16"]  # the XLA-comparator pricing proxy
costs_ns = {s: int(secs("i7-6700k", s, proxy) * 1e9) for s in hot_shapes}

checked = 0
for s, cost in costs_ns.items():
    for depth in range(25):
        backlog = depth * (cost + QUEUED_OVERHEAD_NS)
        for deadline in [1, cost, 200_000, 384_000, 2_000_000, U64_MAX]:
            shed = deadline_would_shed(cost, backlog, deadline)
            # Feasibility is exactly "fits the deadline": admitted iff
            # backlog + own cost <= deadline.
            assert shed == (backlog + cost > deadline), (s, depth, deadline)
            verdict = admit_deadline(deadline, cost, backlog)
            assert (verdict is not None) == shed
            if verdict is not None:
                reason, hint = verdict
                assert reason == "deadline-unmeetable"
                assert hint >= MIN_RETRY_HINT_NS
                if backlog + cost - deadline >= MIN_RETRY_HINT_NS:
                    assert hint == backlog + cost - deadline
            checked += 1
        # BoundedQueue: the two limbs trip independently, and the
        # retry-after hints follow the documented formulas (inflight limb
        # checked first, both floored at MIN_RETRY_HINT_NS).
        for max_inflight, max_queue in [(0, U64_MAX), (8, U64_MAX), (1000, 384_000)]:
            verdict = admit_bounded(max_inflight, max_queue, cost, backlog, depth)
            want_reject = depth >= max_inflight or backlog > max_queue
            assert (verdict is not None) == want_reject, (s, depth, max_inflight, max_queue)
            if verdict is not None:
                reason, hint = verdict
                assert reason == "queue-full"
                if depth >= max_inflight:
                    assert hint == max(backlog // max(depth, 1), MIN_RETRY_HINT_NS)
                else:
                    assert hint == max(backlog - max_queue, MIN_RETRY_HINT_NS)
            checked += 1

# Monotonicity: growing backlog can only flip admit -> reject, never back.
for deadline in [200_000, 2_000_000]:
    prev_rejected = False
    cost = costs_ns[(128, 128, 128, 1)]
    for depth in range(40):
        backlog = depth * (cost + QUEUED_OVERHEAD_NS)
        rejected = admit_deadline(deadline, cost, backlog) is not None
        assert not (prev_rejected and not rejected), "reject must be monotone in backlog"
        prev_rejected = rejected

# Saturation: pathological gauges never wrap into a false admit; a
# u64::MAX deadline is effectively unbounded (the sum saturates *to* it,
# not past it).
assert deadline_would_shed(U64_MAX, U64_MAX, U64_MAX - 1)
assert not deadline_would_shed(U64_MAX, U64_MAX, U64_MAX)
assert not deadline_would_shed(0, 0, 0)
assert deadline_would_shed(1, 0, 0)

# The worked example pinned by the Rust unit test
# (admission.rs deadline_shed_predicate_matches_policy_decisions).
assert admit_deadline(200_000, 150_000, 100_000) == ("deadline-unmeetable", 50_000)

print(f"OK: admission predicates (DeadlineShed + BoundedQueue) match the Rust "
      f"contract on {checked} synthetic gauge states "
      f"(hot-shape cost hints {sorted(v // 1000 for v in costs_ns.values())} us)")

# ---- measured-drain retry-hint check ----------------------------------------
# Port of AdmissionPolicy::admit_with_drain: when a shard has served at
# least one batch, its EWMA drain rate (completions/sec) re-prices every
# retry-after hint in "jobs to drain / measured rate" instead of the gauge
# estimate. The admit/reject DECISION is identical to the drain=0 paths
# ported above — only the hints change — and drain=0 must reproduce the
# plain formulas bit for bit.

def drain_hint_ns(jobs, drain_per_sec):
    """Port of admission::drain_hint_ns (saturating, floored)."""
    ns = max(jobs, 1) * 1e9 / drain_per_sec
    if math.isfinite(ns) and ns < U64_MAX:
        return max(int(ns), MIN_RETRY_HINT_NS)
    return U64_MAX

def admit_bounded_drain(max_inflight, max_queue_ns, cost_ns, backlog_ns,
                        inflight, queued_depth, drain_per_sec):
    """Port of BoundedQueue::admit_with_drain."""
    measured = drain_per_sec > 0.0
    if inflight >= max_inflight:
        if measured:
            hint = drain_hint_ns(inflight - max_inflight + 1, drain_per_sec)
        else:
            hint = max(backlog_ns // max(inflight, 1), MIN_RETRY_HINT_NS)
        return ("queue-full", hint)
    if backlog_ns > max_queue_ns:
        if measured:
            per_job = max(backlog_ns // max(queued_depth, 1), 1)
            jobs = max(-(-(backlog_ns - max_queue_ns) // per_job), 1)
            hint = drain_hint_ns(jobs, drain_per_sec)
        else:
            hint = max(backlog_ns - max_queue_ns, MIN_RETRY_HINT_NS)
        return ("queue-full", hint)
    return None

def admit_deadline_drain(deadline_ns, cost_ns, backlog_ns, queued_depth,
                         drain_per_sec):
    """Port of DeadlineShed::admit_with_drain."""
    measured = drain_per_sec > 0.0
    if deadline_would_shed(cost_ns, backlog_ns, deadline_ns):
        excess = max(sat_add(backlog_ns, cost_ns) - deadline_ns, 0)
        if measured:
            total = max(sat_add(backlog_ns, cost_ns), 1)
            jobs = max(-(-(max(queued_depth, 1) * excess) // total), 1)
            hint = drain_hint_ns(jobs, drain_per_sec)
        else:
            hint = max(excess, MIN_RETRY_HINT_NS)
        return ("deadline-unmeetable", hint)
    return None

# drain=0 reproduces the plain ports bit for bit across the same grid.
drain_checked = 0
for s, cost in costs_ns.items():
    for depth in range(25):
        backlog = depth * (cost + QUEUED_OVERHEAD_NS)
        for max_inflight, max_queue in [(0, U64_MAX), (8, U64_MAX), (1000, 384_000)]:
            assert admit_bounded_drain(max_inflight, max_queue, cost, backlog,
                                       depth, depth, 0.0) \
                == admit_bounded(max_inflight, max_queue, cost, backlog, depth)
            drain_checked += 1
        for deadline in [1, cost, 200_000, 2_000_000, U64_MAX]:
            assert admit_deadline_drain(deadline, cost, backlog, depth, 0.0) \
                == admit_deadline(deadline, cost, backlog)
            drain_checked += 1

# The worked examples pinned by the Rust unit tests (admission.rs
# measured_drain_* tests): 1000 jobs/s makes hints easy to read.
#  - inflight limb: 3 jobs over the cap at 1000/s -> 3 ms.
assert admit_bounded_drain(4, 100_000, 10_000, 50_000, 6, 5, 1000.0) \
    == ("queue-full", 3_000_000)
#  - backlog limb: 50k ns over budget / 30k ns per queued job -> 2 jobs -> 2 ms.
assert admit_bounded_drain(64, 100_000, 10_000, 150_000, 1, 5, 1000.0) \
    == ("queue-full", 2_000_000)
#  - deadline limb: 4 queued * 50k excess / 250k total = 1 job at 1e6/s,
#    floored to MIN_RETRY_HINT_NS.
assert admit_deadline_drain(200_000, 150_000, 100_000, 4, 1e6) \
    == ("deadline-unmeetable", MIN_RETRY_HINT_NS)
#  - same state at a slow 10/s rate -> 1 job / 10 per sec = 100 ms.
assert admit_deadline_drain(200_000, 150_000, 100_000, 4, 10.0) \
    == ("deadline-unmeetable", 100_000_000)
# Decisions never change with the rate, only hints.
for s, cost in costs_ns.items():
    for depth in range(25):
        backlog = depth * (cost + QUEUED_OVERHEAD_NS)
        for rate in [0.0, 1.0, 250.0, 1e6]:
            plain = admit_bounded(8, 384_000, cost, backlog, depth)
            drained = admit_bounded_drain(8, 384_000, cost, backlog, depth,
                                          depth, rate)
            assert (plain is None) == (drained is None)
            drain_checked += 1

print(f"OK: measured-drain hints match the Rust contract ({drain_checked} "
      f"states; drain=0 reproduces the gauge formulas bit for bit)")

# ---- CPU GEMM variant-family knob check -------------------------------------
# Toolchain-free check of rust/src/engine/cpu: parse the CPU_TILINGS
# literal straight out of the source, recompute the 24-variant cross
# product with the same index encoding (tiling*8 + loop*4 + micro*2 +
# threading) and naming scheme, and assert the family is distinct and
# covers every declared knob axis.
import os
import re

CPU_MOD = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       os.pardir, "rust", "src", "engine", "cpu", "mod.rs")
with open(CPU_MOD) as f:
    cpu_src = f.read()

tiling_re = re.compile(
    r'Tiling\s*\{\s*name:\s*"(\w+)",\s*mc:\s*(\d+),\s*kc:\s*(\d+),'
    r'\s*nc:\s*(\d+),\s*mr:\s*(\d+),\s*nr:\s*(\d+)\s*\}')
tilings_block = cpu_src.split("CPU_TILINGS")[1].split("];")[0]
tilings = [dict(name=m[0], mc=int(m[1]), kc=int(m[2]), nc=int(m[3]),
                mr=int(m[4]), nr=int(m[5]))
           for m in tiling_re.findall(tilings_block)]
assert len(tilings) == 3, f"expected 3 tilings in CPU_TILINGS, parsed {len(tilings)}"
assert len({t["name"] for t in tilings}) == 3, "tiling names must be distinct"
for t in tilings:
    assert t["mc"] % t["mr"] == 0 and t["nc"] % t["nr"] == 0, \
        f"tiling {t['name']}: cache blocks must be micro-tile multiples"

LOOP_TAGS = ["pa", "pb"]
MICRO_TAGS = ["sc", "vec"]
THREAD_TAGS = ["t1", "tp"]
variants = {}
for ti, t in enumerate(tilings):
    for li, loop in enumerate(LOOP_TAGS):
        for mi, micro in enumerate(MICRO_TAGS):
            for hi, thr in enumerate(THREAD_TAGS):
                index = ti * 8 + li * 4 + mi * 2 + hi
                name = f"cpu_{t['name']}_{loop}_{micro}_{thr}"
                knobs = (t["name"], loop, micro, thr)
                assert index not in variants, f"index collision at {index}"
                variants[index] = (name, knobs)

assert len(variants) == 24, f"expected 24 variants, built {len(variants)}"
assert sorted(variants) == list(range(24)), "indices must be dense 0..24"
names = [v[0] for v in variants.values()]
knob_tuples = [v[1] for v in variants.values()]
assert len(set(names)) == 24, "variant names must be distinct"
assert len(set(knob_tuples)) == 24, "knob assignments must be distinct"
# Axis coverage: every knob value appears, and each axis splits the
# family evenly (8 per tiling, 12 per binary knob).
for axis, values, share in [(0, [t["name"] for t in tilings], 8),
                            (1, LOOP_TAGS, 12), (2, MICRO_TAGS, 12),
                            (3, THREAD_TAGS, 12)]:
    for val in values:
        got = sum(1 for kt in knob_tuples if kt[axis] == val)
        assert got == share, f"axis {axis} value {val}: {got} != {share}"
# The source must declare the same family size and naming scheme.
assert "NUM_CPU_VARIANTS: usize = CPU_TILINGS.len() * 2 * 2 * 2" in cpu_src
assert '"cpu_{}_{}_{}_{}"' in cpu_src
print(f"OK: CPU variant family — {len(tilings)} tilings x 2 loop orders x "
      f"2 micro-kernels x 2 threading modes = 24 distinct variants, dense "
      f"indices, every axis covered")

# ---- Weighted-fair tenant quota check ---------------------------------------
# Port of rust/src/coordinator/tenant.rs::{reserved_shares,
# quota_would_admit} — the pure admission predicate behind the
# multi-tenant quota layer — checked on an exhaustive small grid plus the
# deterministic burst scenario pinned by the server.rs unit tests.

def reserved_shares(weights, quota_slots):
    total = sum(weights)
    if total == 0:
        return [0] * len(weights)
    return [quota_slots * w // total for w in weights]

def quota_would_admit(weight, tenant_inflight, tenant_reserved,
                      total_inflight, others_reserved_free, quota_slots):
    if weight == 0:
        return False
    if quota_slots == 0:
        return True
    if tenant_inflight < tenant_reserved:
        return True
    return total_inflight + others_reserved_free < quota_slots

# Share arithmetic: floor division, remainder left shared, zero-sum safe.
assert reserved_shares([1, 1, 1, 1], 12) == [3, 3, 3, 3]
assert reserved_shares([2, 1, 1], 16) == [8, 4, 4]
assert reserved_shares([3, 1], 10) == [7, 2]
assert reserved_shares([0, 0], 8) == [0, 0]
for weights in ([1], [1, 2], [5, 3, 1], [2, 2, 2, 2]):
    for slots in range(0, 20):
        shares = reserved_shares(weights, slots)
        assert sum(shares) <= slots, (weights, slots, shares)
        assert all(a <= b for a, b in
                   zip(shares, reserved_shares(weights, slots + 1))), \
            "shares must grow monotonically with capacity"

# Predicate invariants on an exhaustive grid.
quota_checked = 0
for weight in (0, 1, 3):
    for mine in range(0, 6):
        for reserved in range(0, 4):
            for total in range(0, 14):
                for others_free in range(0, 10):
                    for slots in (0, 4, 12):
                        got = quota_would_admit(weight, mine, reserved,
                                                total, others_free, slots)
                        if weight == 0:
                            assert not got, "weight 0 must always reject"
                        elif slots == 0:
                            assert got, "quota off must always admit"
                        elif mine < reserved:
                            assert got, "below reserve is guaranteed"
                        else:
                            assert got == (total + others_free < slots)
                        quota_checked += 1

# The deterministic burst pinned by server.rs: 4 equal tenants, 12 slots
# (reserved 3 each). A 40-deep flood from tenant 1 with no completions
# admits exactly its 3 reserved slots — slot 4 would eat a peer's idle
# reservation (3 + 9 = 12, not < 12) — and every peer still admits its
# full reserve afterwards.
shares = reserved_shares([1, 1, 1, 1], 12)
flood_admitted = 0
for _ in range(40):
    if quota_would_admit(1, flood_admitted, shares[0], flood_admitted,
                         sum(shares[1:]), 12):
        flood_admitted += 1
assert flood_admitted == 3, flood_admitted
inflight = [flood_admitted, 0, 0, 0]
for peer in (1, 2, 3):
    for _ in range(shares[peer]):
        others_free = sum(max(0, shares[j] - inflight[j])
                          for j in range(4) if j != peer)
        assert quota_would_admit(1, inflight[peer], shares[peer],
                                 sum(inflight), others_free, 12), \
            f"peer {peer} denied its reserved slot"
        inflight[peer] += 1
assert inflight == [3, 3, 3, 3]

print(f"OK: weighted-fair quota predicate — reserved shares floor-divide "
      f"and stay monotone, {quota_checked} grid points match the Rust "
      f"contract, hostile burst capped at its 3-slot reserve")

# ---- Variant quarantine + retry-budget check --------------------------------
# Port of rust/src/coordinator/quarantine.rs::VariantHealth — the pure
# per-variant circuit breaker behind variant quarantine (windowed failure
# tracking, cooloff, half-open probation, promotion) — and
# rust/src/coordinator/admission.rs::{retry_budget_after_failure,
# retry_budget_after_success, retry_allowed}, the token-bucket arithmetic
# that sheds retries first under load. Pinned against the worked examples
# the Rust unit tests encode, plus a seeded invariant sweep.

HEALTHY, QUARANTINED, PROBATION = "healthy", "quarantined", "probation"

QUARANTINE_DEFAULTS = {
    "window": 16,
    "trip_failures": 8,
    "cooloff": 32,
    "probe_every": 8,
    "promote_successes": 3,
}

def window_mask(cfg):
    w = min(max(cfg["window"], 1), 64)
    return MASK if w >= 64 else (1 << w) - 1

class VariantHealth:
    def __init__(self):
        self.state = HEALTHY
        self.recent = 0
        self.seen = 0
        self.cooloff_left = 0
        self.probe_tick = 0
        self.probe_successes = 0

    def observe(self, ok, cfg):
        if self.state == HEALTHY:
            self.recent = ((self.recent << 1) | (0 if ok else 1)) & window_mask(cfg)
            self.seen = min(self.seen + 1, min(max(cfg["window"], 1), 64))
            if bin(self.recent).count("1") >= max(cfg["trip_failures"], 1):
                self.trip(cfg)
                return "tripped"
            return None
        if self.state == QUARANTINED:
            return None  # stragglers from pre-trip batches: nothing to learn
        if ok:
            self.probe_successes += 1
            if self.probe_successes >= max(cfg["promote_successes"], 1):
                self.__init__()
                return "restored"
            return "probed"
        self.trip(cfg)
        return "tripped"

    def screen(self, cfg):
        if self.state == HEALTHY:
            return (True, False)
        if self.state == QUARANTINED:
            self.cooloff_left = max(self.cooloff_left - 1, 0)
            if self.cooloff_left == 0:
                self.state = PROBATION
                self.probe_tick = 0
                self.probe_successes = 0
            return (False, False)
        fire = self.probe_tick % max(cfg["probe_every"], 1) == 0
        self.probe_tick = (self.probe_tick + 1) & 0xFFFFFFFF
        return (fire, fire)

    def blocked(self):
        return self.state != HEALTHY

    def trip(self, cfg):
        self.state = QUARANTINED
        self.recent = 0
        self.seen = 0
        self.cooloff_left = max(cfg["cooloff"], 1)
        self.probe_tick = 0
        self.probe_successes = 0

qcfg = dict(QUARANTINE_DEFAULTS)

# Trip threshold: 7 windowed failures hold, the 8th trips.
vh = VariantHealth()
for _ in range(7):
    assert vh.observe(False, qcfg) is None
assert vh.state == HEALTHY and not vh.blocked()
assert vh.observe(False, qcfg) == "tripped"
assert vh.state == QUARANTINED and vh.blocked()

# Sliding window: failures that fall out of the 16-outcome window never
# accumulate to a trip, no matter how many in total.
vh = VariantHealth()
for _ in range(50):
    assert vh.observe(False, qcfg) is None, "spaced failures must not trip"
    for _ in range(16):
        assert vh.observe(True, qcfg) is None
assert vh.state == HEALTHY

# Full lifecycle walk: trip -> 32 cooloff screens -> probation with probes
# sampled every 8th screen -> 3 probe successes promote back to Healthy.
vh = VariantHealth()
for _ in range(8):
    vh.observe(False, qcfg)
assert vh.state == QUARANTINED
for i in range(32):
    assert vh.screen(qcfg) == (False, False), f"cooloff screen {i}"
assert vh.state == PROBATION, "32nd screen must end the cooloff"
probe_pattern = [vh.screen(qcfg) for _ in range(17)]
fired = [i for i, (sel, probe) in enumerate(probe_pattern) if sel]
assert fired == [0, 8, 16], fired
assert all(sel == probe for sel, probe in probe_pattern)
assert vh.observe(True, qcfg) == "probed"
assert vh.observe(True, qcfg) == "probed"
assert vh.observe(True, qcfg) == "restored"
assert vh.state == HEALTHY and vh.screen(qcfg) == (True, False)

# A failed probe re-trips and restarts the full cooloff.
vh = VariantHealth()
for _ in range(8):
    vh.observe(False, qcfg)
for _ in range(32):
    vh.screen(qcfg)
assert vh.state == PROBATION
assert vh.observe(True, qcfg) == "probed"
assert vh.observe(False, qcfg) == "tripped"
assert vh.state == QUARANTINED and vh.cooloff_left == 32

# Seeded invariant sweep: random outcome/screen interleavings can only
# probe during probation, only restore after promote_successes straight
# probe successes, and never leave counters inconsistent.
rng = Rng(0xC1BC)
sweep_trips = sweep_probes = sweep_restores = 0
for _ in range(4):
    vh = VariantHealth()
    streak = 0
    for _ in range(4000):
        if rng.next_u64() & 1:
            was = vh.state
            sel, probe = vh.screen(qcfg)
            assert probe == (was == PROBATION and sel)
            if was == QUARANTINED:
                assert not sel
            if was == HEALTHY:
                assert sel and not probe
        else:
            was = vh.state
            ok = rng.next_u64() % 1000 >= 300
            t = vh.observe(ok, qcfg)
            if was == QUARANTINED:
                assert t is None
            if t == "tripped":
                sweep_trips += 1
                streak = 0
                assert vh.state == QUARANTINED
                assert vh.cooloff_left == qcfg["cooloff"]
            elif t == "probed":
                sweep_probes += 1
                streak += 1
                assert was == PROBATION and ok
                assert streak < qcfg["promote_successes"]
            elif t == "restored":
                sweep_restores += 1
                assert was == PROBATION and ok
                assert streak == qcfg["promote_successes"] - 1
                assert vh.state == HEALTHY
                streak = 0
            elif was == PROBATION:
                assert False, "probation observe must report a transition"
assert sweep_trips > 0 and sweep_probes > 0 and sweep_restores > 0

# Retry token bucket (milli-token arithmetic; capacity in whole tokens).
RETRY_TOKEN_MILLI = 1000

def retry_budget_after_failure(tokens_milli):
    return max(tokens_milli - RETRY_TOKEN_MILLI, 0)

def retry_budget_after_success(tokens_milli, capacity, refill_permille):
    return min(tokens_milli + refill_permille, capacity * RETRY_TOKEN_MILLI)

def retry_allowed(tokens_milli, capacity):
    return tokens_milli > capacity * RETRY_TOKEN_MILLI // 2

assert retry_budget_after_failure(8_000) == 7_000
assert retry_budget_after_failure(500) == 0
assert retry_budget_after_success(7_950, 8, 100) == 8_000, "refill caps at capacity"
assert retry_budget_after_success(4_000, 8, 100) == 4_100
assert retry_allowed(4_001, 8)
assert not retry_allowed(4_000, 8), "half-empty bucket sheds retries"

# The default bucket (8 tokens, full) funds exactly 4 consecutive retries;
# the 5th is refused at the half-capacity floor, and one refill of
# successes buys the next retry back.
tokens, spends = 8 * RETRY_TOKEN_MILLI, 0
while retry_allowed(tokens, 8):
    tokens = retry_budget_after_failure(tokens)
    spends += 1
assert spends == 4 and tokens == 4_000
tokens = retry_budget_after_success(tokens, 8, 100)
assert retry_allowed(tokens, 8)

print(f"OK: quarantine breaker + retry bucket — trip at 8/16 windowed "
      f"failures, 32-screen cooloff, probes every 8th screen, 3-success "
      f"promotion; sweep saw {sweep_trips} trips / {sweep_probes} probes / "
      f"{sweep_restores} restores; full bucket funds 4 retries then sheds")

# ---- Exploration probe-budget admit predicate check --------------------------
# Port of rust/src/tuning/explore.rs::{probe_draw, probe_pick,
# probe_would_admit} — the pure epsilon-schedule and the probe admission
# predicate behind live exploration. The key contract: probe admission is
# STRICTLY tighter than BoundedQueue admission (probes need a near-idle
# shard and must leave half of every bounded budget untouched), so probes
# shed to zero strictly before the policy starts rejecting in-quota work.
# Mirrors the Rust unit test probe_admit_is_strictly_tighter_than_
# bounded_admission on the same gauge grid.

PROBE_MAX_QUEUE_DEPTH = 2          # explore.rs PROBE_MAX_QUEUE_DEPTH
PROBE_MAX_BACKLOG_NS = 1_000_000   # explore.rs PROBE_MAX_BACKLOG_NS

def probe_draw(seed, ordinal, eps_permille):
    """Port of explore::probe_draw — pure in (seed, ordinal)."""
    if eps_permille == 0:
        return False
    return Rng(seed).fork(ordinal).below(1000) < eps_permille

def probe_pick(seed, ordinal, n_candidates):
    """Port of explore::probe_pick — continues probe_draw's stream
    (the gate draw is consumed first)."""
    rng = Rng(seed).fork(ordinal)
    rng.below(1000)
    return rng.below(max(n_candidates, 1))

def probe_would_admit(backlog_ns, queued_depth, inflight,
                      max_inflight, max_queue_ns):
    """Port of explore::probe_would_admit (0 = that budget uncapped)."""
    if queued_depth > PROBE_MAX_QUEUE_DEPTH \
            or backlog_ns > PROBE_MAX_BACKLOG_NS:
        return False
    if max_inflight > 0 and (inflight + 1) * 2 > max_inflight:
        return False
    if max_queue_ns > 0 and backlog_ns * 2 > max_queue_ns:
        return False
    return True

# Epsilon schedule: deterministic, seed-sensitive, eps=0 inert, and the
# fire rate over 10k ordinals lands within 3 sigma of eps/1000.
sched_a = [probe_draw(11, i, 50) for i in range(4096)]
assert sched_a == [probe_draw(11, i, 50) for i in range(4096)], \
    "same seed must replay the same schedule"
assert sched_a != [probe_draw(12, i, 50) for i in range(4096)], \
    "different seed must give a different schedule"
assert not any(probe_draw(42, i, 0) for i in range(1000)), "eps=0 is inert"
fired = sum(1 for i in range(10_000) if probe_draw(42, i, 50))
expect, sigma = 10_000 * 0.05, math.sqrt(10_000 * 0.05 * 0.95)
assert abs(fired - expect) <= 3 * sigma, (fired, expect)

# Candidate pick: in range, deterministic, every candidate reachable, and
# the gate draw is consumed first (the pick equals the stream's SECOND
# below() — shifting the candidate count never perturbs the gate).
for n in (1, 2, 3, 17):
    for i in range(256):
        p = probe_pick(42, i, n)
        assert 0 <= p < n and p == probe_pick(42, i, n)
assert probe_pick(42, 0, 0) == 0, "degenerate candidate count must not throw"
assert {probe_pick(42, i, 3) for i in range(256)} == {0, 1, 2}
for i in range(64):
    stream = Rng(42).fork(i)
    gate = stream.below(1000)
    assert probe_draw(42, i, 1000) and gate < 1000
    assert probe_pick(42, i, 7) == stream.below(7)

# Idle-shard limbs pinned by the Rust probe_admit_requires_idle_shard test.
assert probe_would_admit(0, 0, 0, 0, 0)
assert not probe_would_admit(0, PROBE_MAX_QUEUE_DEPTH + 1, 0, 0, 0)
assert not probe_would_admit(PROBE_MAX_BACKLOG_NS + 1, 0, 0, 0, 0)
# Half-budget limbs: a probe may use at most half of a bounded budget.
assert probe_would_admit(0, 0, 3, 8, 0)       # (3+1)*2 = 8 <= 8
assert not probe_would_admit(0, 0, 4, 8, 0)   # (4+1)*2 = 10 > 8
assert probe_would_admit(500_000, 0, 0, 0, 1_000_000)
assert not probe_would_admit(500_001, 0, 0, 0, 1_000_000)

# The stricter-than-admission sweep (mirrors the Rust unit test grid):
# wherever the probe predicate admits, BoundedQueue admission with the
# same budgets must admit too — at any measured drain rate, since the
# decision is rate-independent.
probe_checked = probe_admits = 0
for max_inflight in (2, 4, 8, 64):
    for max_queue_ns in (100_000, 1_000_000, 10_000_000):
        for inflight in range(max_inflight + 3):
            for backlog_ns in (0, 40_000, 60_000, 500_000, 999_999,
                               1_000_001, 20_000_000):
                for depth in (0, 1, 2, 3, 50):
                    probe_checked += 1
                    if not probe_would_admit(backlog_ns, depth, inflight,
                                             max_inflight, max_queue_ns):
                        continue
                    probe_admits += 1
                    for rate in (0.0, 1000.0):
                        verdict = admit_bounded_drain(
                            max_inflight, max_queue_ns, 1, backlog_ns,
                            inflight, depth, rate)
                        assert verdict is None, \
                            (backlog_ns, depth, inflight, max_inflight,
                             max_queue_ns, verdict)
assert probe_admits > 0, "sweep must exercise the admit side of the grid"

# Budget arithmetic: only ISSUED probes consume budget — sheds are free.
# Walk the epsilon schedule against an adversarial gauge that rejects
# every other probe attempt; the issue counter must stop exactly at the
# budget while shed attempts keep passing through unbilled.
budget, issued, sheds, attempt = 16, 0, 0, 0
for ordinal in range(50_000):
    if not probe_draw(7, ordinal, 100) or issued >= budget:
        continue
    attempt += 1
    backlog = 0 if attempt % 2 else 2 * PROBE_MAX_BACKLOG_NS
    if probe_would_admit(backlog, 0, 0, 0, 0):
        issued += 1
    else:
        sheds += 1
assert issued == budget, issued
assert sheds > 0 and attempt == issued + sheds > budget, \
    "shed probes must not consume budget"

print(f"OK: exploration probe predicates — deterministic seeded epsilon "
      f"schedule ({fired}/10000 fired at eps 50), probe admission strictly "
      f"tighter than BoundedQueue on {probe_checked} gauge states "
      f"({probe_admits} probe-admits, zero policy rejections), sheds "
      f"never billed against the {budget}-probe budget")
