//! Integration tests: the full tuning pipeline (simulate -> select ->
//! classify -> codegen) and the runtime/coordinator against real artifacts.

use std::path::PathBuf;

use kernelsel::classify::codegen::CompiledTree;
use kernelsel::classify::{ClassifierKind, KernelClassifier};
use kernelsel::coordinator::{Coordinator, PoolConfig, SelectorPolicy};
use kernelsel::dataset::{
    benchmark_shapes, config_by_name, GemmShape, Normalization, PerfDataset,
};
use kernelsel::devsim::{generate_dataset, profile_by_name};
use kernelsel::selection::{achievable_percent, achieved_percent, select, Method};
use kernelsel::util::fill_buffer;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn small_dataset(device: &str) -> PerfDataset {
    let shapes: Vec<GemmShape> = benchmark_shapes().into_iter().step_by(3).collect();
    generate_dataset(profile_by_name(device).unwrap(), &shapes)
}

#[test]
fn full_tuning_pipeline_simulate_select_classify_codegen() {
    let ds = small_dataset("r9-nano");
    let split = ds.split(0.8, 11);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);

    // Select.
    let deployed = select(Method::PcaKMeans, &train, Normalization::Standard, 8, 11);
    let oracle = achievable_percent(&test, &deployed);
    assert!(oracle > 80.0, "oracle only {oracle:.1}%");

    // Classify.
    let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &train, &deployed, 11);
    let achieved = achieved_percent(&test, &clf.choices(&test));
    assert!(achieved > 0.7 * oracle, "classifier {achieved:.1}% vs oracle {oracle:.1}%");

    // Codegen round-trip.
    let tree = CompiledTree::compile(&clf).unwrap();
    let text = tree.serialize();
    let back = CompiledTree::deserialize(&text).unwrap();
    for s in &test.shapes {
        assert_eq!(back.predict_config(&s.features()), clf.predict_config(&s.features()));
    }
}

#[test]
fn dataset_csv_roundtrip_through_disk() {
    let ds = small_dataset("hd530");
    let tmp = std::env::temp_dir().join("kernelsel_test_dataset.csv");
    ds.save(&tmp).unwrap();
    let back = PerfDataset::load("hd530", &tmp).unwrap();
    assert_eq!(back.shapes, ds.shapes);
    assert_eq!(back.n_shapes(), ds.n_shapes());
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn coordinator_serves_tuned_policy_on_executor_pool() {
    // Real artifacts when `make artifacts` has run; the synthetic
    // deployment (served by the SimBackend) otherwise — the test passes on
    // a clean machine either way.
    let manifest = kernelsel::runtime::Manifest::load_or_synthetic(&artifacts_dir());
    let ds = small_dataset("i7-6700k");
    let deployed: Vec<usize> = manifest
        .deployed
        .iter()
        .map(|n| config_by_name(n).unwrap().index())
        .collect();
    let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, 3);
    let policy = SelectorPolicy::Tree(CompiledTree::compile(&clf).unwrap());
    let coord = Coordinator::start_pool(
        artifacts_dir(),
        policy,
        PoolConfig { shards: 2, ..PoolConfig::default() },
    )
    .unwrap();

    let shapes = [
        GemmShape::new(128, 128, 128, 1),
        GemmShape::new(1024, 27, 64, 1),
        GemmShape::new(64, 2304, 128, 1),
    ];
    let mut rxs = Vec::new();
    for (i, s) in shapes.iter().enumerate() {
        let lhs = fill_buffer(i as u32, s.batch * s.m * s.k);
        let rhs = fill_buffer((i + 9) as u32, s.batch * s.k * s.n);
        rxs.push((*s, coord.submit(*s, lhs, rhs)));
    }
    for (s, rx) in rxs {
        let resp = rx.recv().expect("response");
        let out = resp.result.expect("result");
        assert_eq!(out.len(), s.batch * s.m * s.n, "{s:?}");
        // Tuned policy must be choosing deployed configs (or falling back
        // to another deployed config / the XLA comparator at that bucket).
        if let Some(cfg) = resp.config_used {
            assert!(deployed.contains(&cfg));
        }
    }
    let report = coord.stop_detailed();
    assert_eq!(report.per_shard.len(), 2);
    assert_eq!(report.total.requests, 3);
    assert_eq!(report.total.failures, 0);
}

#[test]
fn selection_quality_ordering_holds_on_both_paper_devices() {
    // The headline Fig 5/6 shape: ML selection at k=8 stays close to or
    // above TopN, and oracle percentages rise with k.
    for device in ["r9-nano", "i7-6700k"] {
        let ds = small_dataset(device);
        let split = ds.split(0.8, 5);
        let train = ds.subset(&split.train);
        let test = ds.subset(&split.test);
        let p4 = achievable_percent(
            &test,
            &select(Method::KMeans, &train, Normalization::Standard, 4, 5),
        );
        let p12 = achievable_percent(
            &test,
            &select(Method::KMeans, &train, Normalization::Standard, 12, 5),
        );
        assert!(p12 >= p4 - 1.5, "{device}: k=12 {p12:.1}% < k=4 {p4:.1}%");
        assert!(p12 > 85.0, "{device}: k=12 only {p12:.1}%");
    }
}

#[test]
fn deploy_json_emittable_and_reparseable() {
    // The select --emit-deploy flow: rust picks kernels, python consumes.
    let ds = small_dataset("mali-g71");
    let deployed = select(Method::KMeans, &ds, Normalization::Standard, 8, 1);
    let names: Vec<String> = deployed
        .iter()
        .map(|&c| {
            format!("\"{}\"", kernelsel::dataset::config_by_index(c).name())
        })
        .collect();
    let json = format!(
        "{{\"deployed\": [{}], \"single_best\": \"{}\"}}",
        names.join(","),
        kernelsel::dataset::config_by_index(
            kernelsel::selection::single_best(&ds)
        )
        .name()
    );
    let parsed = kernelsel::util::json::parse(&json).unwrap();
    let arr = parsed.get("deployed").unwrap().as_arr().unwrap();
    assert_eq!(arr.len(), 8);
    for v in arr {
        assert!(config_by_name(v.as_str().unwrap()).is_some());
    }
}
