//! Proves the warm cache-hit submit path performs **zero client-side heap
//! allocations per request** — the tentpole acceptance of the lock-light
//! submit rework.
//!
//! A counting global allocator tracks allocations per thread (thread-local
//! counters, so the executor shards' own allocations — result buffers,
//! telemetry cells — don't pollute the measurement). The test warms the
//! pool, pre-allocates every input buffer, then submits and waits on the
//! client thread with counting enabled: resolve hit (striped snapshot
//! `Arc` clone), cost hint (two relaxed atomics, telemetry re-read every
//! `COST_REFRESH_PERIOD`), routing (gauge loads), completion checkout
//! (free-list CAS), injector push (pre-reserved deque) and the parked wait
//! must all stay off the heap.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::path::PathBuf;

use kernelsel::coordinator::{
    AdmissionPolicy, Coordinator, PoolConfig, QuarantineConfig, SelectorPolicy, TraceConfig,
};
use kernelsel::dataset::GemmShape;
use kernelsel::engine::FaultPlan;
use kernelsel::util::fill_buffer;

thread_local! {
    // const-initialized Cells: reading them inside the allocator cannot
    // itself allocate (no lazy TLS init, no destructors).
    static TRACKING: Cell<bool> = const { Cell::new(false) };
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAllocator;

fn note_alloc() {
    let tracking = TRACKING.try_with(|t| t.get()).unwrap_or(false);
    if tracking {
        let _ = ALLOCS.try_with(|a| a.set(a.get() + 1));
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note_alloc();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note_alloc();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        note_alloc();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // Frees are not counted: responses allocated on worker threads are
        // legitimately dropped on the client thread.
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn start_pool() -> Coordinator {
    Coordinator::start_pool(
        PathBuf::from("/nonexistent-artifacts"),
        SelectorPolicy::Xla,
        PoolConfig { shards: 2, ..PoolConfig::default() },
    )
    .expect("coordinator start")
}

#[test]
fn warm_hit_path_submit_allocates_nothing_on_the_client_thread() {
    let coord = start_pool();
    let shape = GemmShape::new(64, 64, 64, 1);
    // Warm everything the hot path touches: the resolution-cache entry,
    // the telemetry cell (past min_samples, so the cost-hint refresh
    // takes the measured branch), the injector deque capacity, and this
    // thread's Thread handle/parker.
    for i in 0..40u32 {
        let lhs = fill_buffer(i, 64 * 64);
        let rhs = fill_buffer(i + 7, 64 * 64);
        let resp = coord.call(shape, lhs, rhs).expect("warm call");
        assert!(resp.result.is_ok());
    }
    // Materialize this thread's `Thread` handle (its first access
    // allocates lazily inside `park`'s registration path).
    let _ = std::thread::current();
    // Pre-build every input outside the measured region (the request
    // buffers themselves are the caller's payload, not dispatch overhead).
    let n = 96usize; // crosses the COST_REFRESH_PERIOD=32 refresh twice
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (fill_buffer(i as u32, 64 * 64), fill_buffer(i as u32 + 3, 64 * 64)))
        .collect();

    TRACKING.with(|t| t.set(true));
    ALLOCS.with(|a| a.set(0));
    for (lhs, rhs) in inputs {
        let ticket = coord.submit(shape, lhs, rhs);
        let resp = ticket.wait();
        assert!(resp.result.is_ok());
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOCS.with(|a| a.get());

    assert_eq!(
        allocs, 0,
        "warm hit-path submit+wait allocated {allocs} times over {n} requests; \
         the fast path must stay off the heap"
    );
    let metrics = coord.stop();
    assert_eq!(metrics.requests, 40 + n);
    assert_eq!(metrics.failures, 0);
}

#[test]
fn warm_submit_with_flight_recorder_on_allocates_nothing() {
    // Tracing must not cost the hot path its zero-alloc property: events
    // are written by value into the recorder's preallocated rings, so a
    // traced warm submit is the untraced one plus a few atomics and a
    // try-locked array write.
    let coord = Coordinator::start_pool(
        PathBuf::from("/nonexistent-artifacts"),
        SelectorPolicy::Xla,
        PoolConfig {
            shards: 2,
            trace: Some(TraceConfig::default()),
            ..PoolConfig::default()
        },
    )
    .expect("coordinator start");
    let shape = GemmShape::new(64, 64, 64, 1);
    for i in 0..40u32 {
        let lhs = fill_buffer(i, 64 * 64);
        let rhs = fill_buffer(i + 7, 64 * 64);
        let resp = coord.call(shape, lhs, rhs).expect("warm call");
        assert!(resp.result.is_ok());
    }
    let _ = std::thread::current();
    let n = 96usize;
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (fill_buffer(i as u32, 64 * 64), fill_buffer(i as u32 + 3, 64 * 64)))
        .collect();

    TRACKING.with(|t| t.set(true));
    ALLOCS.with(|a| a.set(0));
    for (lhs, rhs) in inputs {
        let ticket = coord.submit(shape, lhs, rhs);
        let resp = ticket.wait();
        assert!(resp.result.is_ok());
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOCS.with(|a| a.get());

    assert_eq!(
        allocs, 0,
        "traced warm submit+wait allocated {allocs} times over {n} requests; \
         the recorder must keep the fast path off the heap"
    );
    // The traffic really was traced — every request opened a chain and
    // the ring (default capacity) had room for all of it.
    let rec = coord.recorder().expect("tracing was enabled");
    assert_eq!(rec.chains(), (40 + n) as u64);
    assert_eq!(rec.dropped(), 0);
    let metrics = coord.stop();
    assert_eq!(metrics.requests, 40 + n);
    assert_eq!(metrics.failures, 0);
}

#[test]
fn warm_submit_with_quarantine_tracking_on_allocates_nothing() {
    // Quarantine tracking and the fault-injection canary must not cost
    // the hot path its zero-alloc property. The fault plan here is armed
    // (non-inert, so the shards wrap their backends and run the integrity
    // canary + per-result quarantine observation) but its onset is beyond
    // the horizon, so no fault ever fires: the client-side submit path —
    // including the cache's quarantine re-screen on every hit — must stay
    // off the heap.
    let armed_but_quiet =
        FaultPlan { transient_permille: 1, onset: u64::MAX, ..FaultPlan::default() };
    let coord = Coordinator::start_pool(
        PathBuf::from("/nonexistent-artifacts"),
        SelectorPolicy::Xla,
        PoolConfig {
            shards: 2,
            fault: Some(armed_but_quiet),
            quarantine: QuarantineConfig::default(),
            ..PoolConfig::default()
        },
    )
    .expect("coordinator start");
    let shape = GemmShape::new(64, 64, 64, 1);
    for i in 0..40u32 {
        let lhs = fill_buffer(i, 64 * 64);
        let rhs = fill_buffer(i + 7, 64 * 64);
        let resp = coord.call(shape, lhs, rhs).expect("warm call");
        assert!(resp.result.is_ok());
    }
    let _ = std::thread::current();
    let n = 96usize;
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (fill_buffer(i as u32, 64 * 64), fill_buffer(i as u32 + 3, 64 * 64)))
        .collect();

    TRACKING.with(|t| t.set(true));
    ALLOCS.with(|a| a.set(0));
    for (lhs, rhs) in inputs {
        let ticket = coord.submit(shape, lhs, rhs);
        let resp = ticket.wait();
        assert!(resp.result.is_ok());
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOCS.with(|a| a.get());

    assert_eq!(
        allocs, 0,
        "warm submit with quarantine tracking on allocated {allocs} times over {n} requests; \
         health screening must keep the fast path off the heap"
    );
    let metrics = coord.stop();
    assert_eq!(metrics.requests, 40 + n);
    assert_eq!(metrics.failures, 0);
    assert_eq!(metrics.quarantine_trips, 0, "a quiet plan must trip nothing");
}

#[test]
fn rejected_submits_allocate_nothing() {
    // A zero-capacity BoundedQueue rejects every submit deterministically.
    // The rejection path must cost nothing: no completion slot, no heap
    // allocation — the ticket is a slot-less Copy of the typed error.
    let coord = Coordinator::start_pool(
        PathBuf::from("/nonexistent-artifacts"),
        SelectorPolicy::Xla,
        PoolConfig {
            shards: 1,
            admission: AdmissionPolicy::BoundedQueue { max_inflight: 0, max_queue_ns: u64::MAX },
            ..PoolConfig::default()
        },
    )
    .expect("coordinator start");
    let shape = GemmShape::new(64, 64, 64, 1);
    // Warm the resolution cache (the resolve hit must precede admission
    // for the cost hint) — these warming submits are themselves rejected.
    for i in 0..8u32 {
        let ticket = coord.submit(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 1, 64 * 64));
        assert!(ticket.rejection().is_some());
    }
    let n = 64usize;
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (fill_buffer(i as u32, 64 * 64), fill_buffer(i as u32 + 3, 64 * 64)))
        .collect();

    TRACKING.with(|t| t.set(true));
    ALLOCS.with(|a| a.set(0));
    let mut rejected = 0usize;
    for (lhs, rhs) in inputs {
        let ticket = coord.submit(shape, lhs, rhs);
        if ticket.rejection().is_some() {
            rejected += 1;
        }
        // Dropping the unconsumed rejected ticket is a no-op (no slot).
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOCS.with(|a| a.get());

    assert_eq!(rejected, n, "a zero-capacity policy must reject everything");
    assert_eq!(
        allocs, 0,
        "rejected submits allocated {allocs} times over {n} requests; \
         admission refusals must stay off the heap"
    );
    let report = coord.stop_detailed();
    assert_eq!(report.total.rejected, 8 + n);
    assert_eq!(report.total.requests, 0);
}

#[test]
fn rejection_storms_leak_no_completion_slots() {
    // A minimum-size completion slab plus heavy mixed admit/reject
    // traffic: if a rejection ever checked out (and lost) a slot, the
    // 8-slot slab would drain and warm submits would silently fall back
    // to one-shot heap slots — which the zero-alloc assertion below
    // would catch immediately.
    let coord = Coordinator::start_pool(
        PathBuf::from("/nonexistent-artifacts"),
        SelectorPolicy::Xla,
        PoolConfig {
            shards: 1,
            completion_slots: 8, // the CompletionPool minimum (one per lane)
            admission: AdmissionPolicy::DeadlineShed { deadline_ns: 200_000 },
            ..PoolConfig::default()
        },
    )
    .expect("coordinator start");
    let shape = GemmShape::new(64, 64, 64, 1);
    // Warm sequentially (an idle gauge always admits under this deadline).
    for i in 0..40u32 {
        let resp = coord.call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 7, 64 * 64));
        assert!(resp.expect("warm call").result.is_ok());
    }
    // Hammer: async bursts where the deadline rejects most of the tail,
    // then drain. Every admitted ticket returns its slot; every rejected
    // ticket never had one.
    let mut rejected_total = 0usize;
    for round in 0..50u32 {
        // Prebuild the round's inputs so the submits land back-to-back —
        // far faster than the shard can drain a ~4-deep deadline budget.
        let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..10u32)
            .map(|i| {
                let seed = round * 16 + i;
                (fill_buffer(seed, 64 * 64), fill_buffer(seed + 3, 64 * 64))
            })
            .collect();
        let tickets: Vec<_> =
            inputs.into_iter().map(|(lhs, rhs)| coord.submit(shape, lhs, rhs)).collect();
        for ticket in tickets {
            if ticket.rejection().is_some() {
                rejected_total += 1;
            } else {
                assert!(ticket.wait().result.is_ok());
            }
        }
    }
    assert!(
        rejected_total > 0,
        "10-deep instantaneous bursts against a ~4-deep deadline budget must reject"
    );

    // The slab must be fully intact: warm sequential submits still take
    // pooled slots (a one-shot fallback would heap-allocate and fail the
    // zero-alloc assertion).
    let _ = std::thread::current();
    let n = 32usize;
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (fill_buffer(i as u32, 64 * 64), fill_buffer(i as u32 + 5, 64 * 64)))
        .collect();
    TRACKING.with(|t| t.set(true));
    ALLOCS.with(|a| a.set(0));
    for (lhs, rhs) in inputs {
        let ticket = coord.submit(shape, lhs, rhs);
        assert!(ticket.rejection().is_none(), "sequential traffic is always feasible");
        assert!(ticket.wait().result.is_ok());
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOCS.with(|a| a.get());
    assert_eq!(
        allocs, 0,
        "warm submits after a rejection storm allocated {allocs} times; \
         the slab must not have leaked slots to rejections"
    );
    coord.stop();
}

#[test]
fn submit_many_amortizes_client_allocations_across_the_batch() {
    let coord = start_pool();
    let shape = GemmShape::new(32, 32, 32, 1);
    for i in 0..40u32 {
        let lhs = fill_buffer(i, 32 * 32);
        let rhs = fill_buffer(i + 5, 32 * 32);
        assert!(coord.call(shape, lhs, rhs).expect("warm call").result.is_ok());
    }
    let _ = std::thread::current();
    let n = 64usize;
    let requests: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = (0..n)
        .map(|i| (shape, fill_buffer(i as u32, 32 * 32), fill_buffer(i as u32 + 9, 32 * 32)))
        .collect();

    TRACKING.with(|t| t.set(true));
    ALLOCS.with(|a| a.set(0));
    let tickets = coord.submit_many(requests);
    let mut ok = 0usize;
    for ticket in tickets {
        if ticket.wait().result.is_ok() {
            ok += 1;
        }
    }
    TRACKING.with(|t| t.set(false));
    let allocs = ALLOCS.with(|a| a.get());

    assert_eq!(ok, n);
    // The batch shares one resolution, one routing decision and a handful
    // of container allocations (tickets/jobs vectors, deque growth); the
    // per-request dispatch itself stays allocation-free, so the total must
    // sit far below one allocation per request.
    assert!(
        (allocs as usize) < n / 2,
        "submit_many allocated {allocs} times for {n} requests; batching must amortize"
    );
    coord.stop();
}
