//! Integration tests: the `kernelsel-telemetry-v1` snapshot wire format —
//! probe-provenance round-trips through the extended schema, and a golden
//! pre-extension fixture (written before the per-cell `probed` field
//! existed) still loads with the new field defaulted.

use std::path::PathBuf;

use kernelsel::dataset::GemmShape;
use kernelsel::tuning::{TelemetrySink, TelemetrySnapshot};
use kernelsel::util::json;

fn fixture(name: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()))
}

#[test]
fn pre_explore_v1_fixture_loads_with_probed_defaulted() {
    let doc = json::parse(&fixture("telemetry_v1_pre_explore.json")).expect("fixture parses");
    let snap = TelemetrySnapshot::from_json(&doc).expect("pre-extension v1 still loads");
    assert_eq!(snap.cells.len(), 3);
    for cell in &snap.cells {
        assert_eq!(cell.probed, 0, "absent provenance must default to zero, not fail");
    }
    let small = GemmShape::new(64, 64, 64, 1);
    let xla = snap.cell(&small, None).expect("xla cell");
    assert_eq!((xla.count, xla.mean_secs), (12, 0.00031));
    let cfg3 = snap.cell(&small, Some(3)).expect("config-3 cell");
    assert_eq!(cfg3.count, 5);

    // The restored cells behave exactly like natively recorded ones: a
    // warm sink prices them, and re-exporting writes the extended schema.
    let sink = TelemetrySink::new(3, 0.25);
    sink.absorb(&snap);
    let priced = sink.measured_cost_secs(&small, Some(3)).expect("5 samples price the cell");
    assert!((priced - 0.0002).abs() < 1e-9, "EWMA restored, got {priced}");
    let reexported = sink.snapshot().to_json().to_string();
    assert!(
        reexported.contains("\"probed\":0"),
        "re-export must carry the extended field: {reexported}"
    );
}

#[test]
fn extended_snapshot_roundtrips_probe_provenance() {
    let sink = TelemetrySink::new(1, 0.5);
    let shape = GemmShape::new(256, 256, 256, 1);
    sink.record(shape, Some(2), 1e-3);
    sink.record_probe(shape, Some(2), 1.2e-3);
    sink.record_probe(shape, Some(4), 2e-3);
    sink.record(shape, None, 3e-3);

    let wire = sink.snapshot().to_json().to_string();
    let back = TelemetrySnapshot::from_json(&json::parse(&wire).expect("wire parses"))
        .expect("extended snapshot loads");
    let mixed = back.cell(&shape, Some(2)).expect("mixed cell");
    assert_eq!((mixed.count, mixed.probed), (2, 1), "organic + probe provenance split");
    let pure = back.cell(&shape, Some(4)).expect("probe-only cell");
    assert_eq!((pure.count, pure.probed), (1, 1));
    let organic = back.cell(&shape, None).expect("organic cell");
    assert_eq!((organic.count, organic.probed), (1, 0));

    // Absorbing the restored snapshot into a fresh sink keeps provenance —
    // the warm-start path a redeployment takes.
    let fresh = TelemetrySink::new(1, 0.5);
    fresh.absorb(&back);
    let again = fresh.snapshot();
    assert_eq!(again.cell(&shape, Some(2)).unwrap().probed, 1);
    assert_eq!(again.cell(&shape, Some(4)).unwrap().probed, 1);
}
