//! Correctness suite for the native CPU GEMM variant family.
//!
//! Every variant is checked against two independent references: the f64
//! `linalg::Matrix::matmul` (on small-integer operands, where any
//! accumulation order is exact in both precisions) over an odd-shape grid
//! that exercises every micro-kernel tail edge, and the f32 `host_gemm`
//! (on arbitrary float operands, the *bitwise* accumulation-order claim).
//! Threaded variants must additionally be bit-identical across thread
//! budgets — the column-panel split may never change a single bit.

use kernelsel::dataset::GemmShape;
use kernelsel::engine::cpu::{cpu_variants, gemm_variant, NUM_CPU_VARIANTS};
use kernelsel::engine::sim::host_gemm;
use kernelsel::linalg::Matrix;
use kernelsel::util::fill_buffer;

/// Deterministic small-integer operand in [-4, 4]: every product and every
/// partial sum over the grid's k range is exactly representable in f32 and
/// f64 alike, so the f64 Matrix reference checks the f32 kernels exactly,
/// independent of accumulation order.
fn int_buffer(seed: u32, count: usize) -> Vec<f32> {
    let mut state = seed.wrapping_mul(2_654_435_761).wrapping_add(12_345);
    (0..count)
        .map(|_| {
            state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
            ((state >> 16) % 9) as f32 - 4.0
        })
        .collect()
}

/// Batch-by-batch f64 reference through `linalg::Matrix::matmul`.
fn matrix_reference(shape: &GemmShape, lhs: &[f32], rhs: &[f32]) -> Vec<f32> {
    let (m, k, n) = (shape.m, shape.k, shape.n);
    let mut out = Vec::with_capacity(shape.batch * m * n);
    for b in 0..shape.batch {
        let a = Matrix::from_rows(
            &(0..m)
                .map(|i| {
                    (0..k).map(|j| lhs[b * m * k + i * k + j] as f64).collect::<Vec<f64>>()
                })
                .collect::<Vec<_>>(),
        );
        let bm = Matrix::from_rows(
            &(0..k)
                .map(|i| {
                    (0..n).map(|j| rhs[b * k * n + i * n + j] as f64).collect::<Vec<f64>>()
                })
                .collect::<Vec<_>>(),
        );
        let c = a.matmul(&bm);
        for i in 0..m {
            for j in 0..n {
                out.push(c[(i, j)] as f32);
            }
        }
    }
    out
}

#[test]
fn every_variant_matches_f64_matrix_reference_on_odd_grid() {
    // The odd grid hits every tail edge of every tiling: dims below the
    // micro-tile (1, 3), just past it (17), exactly on panel boundaries
    // (64) and one past a power of two (129) — with batch 2 throughout so
    // the per-batch offsets are exercised too.
    let dims = [1usize, 3, 17, 64, 129];
    let variants = cpu_variants();
    assert_eq!(variants.len(), NUM_CPU_VARIANTS);
    for &m in &dims {
        for &k in &dims {
            for &n in &dims {
                let shape = GemmShape::new(m, k, n, 2);
                let seed = (m * 31 + k * 7 + n) as u32;
                let lhs = int_buffer(seed, shape.batch * m * k);
                let rhs = int_buffer(seed + 1, shape.batch * k * n);
                let want = matrix_reference(&shape, &lhs, &rhs);
                for v in &variants {
                    let got = gemm_variant(v, 3, &shape, &lhs, &rhs)
                        .unwrap_or_else(|e| panic!("{} on {m}x{k}x{n}: {e}", v.name()));
                    assert_eq!(
                        got,
                        want,
                        "variant {} diverges from the f64 reference on {m}x{k}x{n}b2",
                        v.name()
                    );
                }
            }
        }
    }
}

#[test]
fn every_variant_bitwise_equals_host_gemm_on_float_operands() {
    // The stronger claim on arbitrary floats: every variant accumulates
    // each output element in the same strictly ascending k order as the
    // reference host GEMM, so the f32 results match bit for bit — packing,
    // blocking, loop order, vector width and threading included.
    let shapes = [
        GemmShape::new(17, 129, 3, 2),
        GemmShape::new(64, 64, 64, 1),
        GemmShape::new(33, 65, 47, 2),
        GemmShape::new(129, 17, 64, 1),
    ];
    for (si, shape) in shapes.iter().enumerate() {
        let lhs = fill_buffer(si as u32 * 2 + 1, shape.batch * shape.m * shape.k);
        let rhs = fill_buffer(si as u32 * 2 + 2, shape.batch * shape.k * shape.n);
        let want = host_gemm(shape, &lhs, &rhs).unwrap();
        for v in cpu_variants() {
            let got = gemm_variant(&v, 4, shape, &lhs, &rhs).unwrap();
            assert_eq!(
                got.len(),
                want.len(),
                "variant {} output length on shape {si}",
                v.name()
            );
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    g.to_bits() == w.to_bits(),
                    "variant {} differs from host_gemm at element {i} of shape {si}: \
                     {g} vs {w}",
                    v.name()
                );
            }
        }
    }
}

#[test]
fn threaded_variants_are_deterministic_across_thread_budgets() {
    // The column-panel split assigns disjoint output panels, so the result
    // must be bit-identical whatever the worker count — including budgets
    // that do not divide the panel count evenly.
    let shape = GemmShape::new(67, 33, 101, 2);
    let lhs = fill_buffer(11, shape.batch * shape.m * shape.k);
    let rhs = fill_buffer(12, shape.batch * shape.k * shape.n);
    let threaded: Vec<_> = cpu_variants()
        .into_iter()
        .filter(|v| v.name().ends_with("_tp"))
        .collect();
    assert_eq!(threaded.len(), NUM_CPU_VARIANTS / 2, "half the family is threaded");
    for v in &threaded {
        let base = gemm_variant(v, 1, &shape, &lhs, &rhs).unwrap();
        for threads in [2usize, 4, 7] {
            let wide = gemm_variant(v, threads, &shape, &lhs, &rhs).unwrap();
            assert_eq!(
                base,
                wide,
                "variant {} changed bits between 1 and {threads} threads",
                v.name()
            );
        }
    }
}

#[test]
fn batches_are_independent_per_variant() {
    // A batch-3 call must equal three batch-1 calls concatenated, bitwise,
    // for a representative variant of each tiling.
    let (m, k, n) = (17, 29, 13);
    let lhs = fill_buffer(21, 3 * m * k);
    let rhs = fill_buffer(22, 3 * k * n);
    let batched = GemmShape::new(m, k, n, 3);
    let single = GemmShape::new(m, k, n, 1);
    for v in cpu_variants().iter().step_by(5) {
        let got = gemm_variant(v, 2, &batched, &lhs, &rhs).unwrap();
        let mut want = Vec::with_capacity(3 * m * n);
        for b in 0..3 {
            want.extend(
                gemm_variant(
                    v,
                    2,
                    &single,
                    &lhs[b * m * k..(b + 1) * m * k],
                    &rhs[b * k * n..(b + 1) * k * n],
                )
                .unwrap(),
            );
        }
        assert_eq!(got, want, "variant {} mixes batches", v.name());
    }
}
