//! Compile-time stub of the `xla` PJRT bindings.
//!
//! The offline build environment ships no native XLA, so the `pjrt` cargo
//! feature of `kernelsel` links against this crate instead: the API surface
//! the runtime uses exists and type-checks, and every entry point fails at
//! runtime with a clear message. To run against real PJRT, point the `xla`
//! path dependency in `rust/Cargo.toml` at the actual bindings — the
//! signatures below mirror the subset of that API the runtime calls.

use std::fmt;

/// Error type matching the `Display + Debug` bound the runtime relies on.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} unavailable — this binary was built against the \
         in-tree xla stub; point rust/Cargo.toml's `xla` path dependency at \
         real PJRT bindings to enable native execution"
    ))
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub: uninhabited behavior, constructible type).
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A PJRT device buffer (stub).
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (stub).
pub struct Literal {}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(stub_err("Literal::to_vec"))
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute_b"))
    }
}

/// A PJRT client (stub: creation always fails, so no other method runs).
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_buffer"))
    }
}
