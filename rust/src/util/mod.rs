//! Cross-cutting substrates: deterministic RNG, the Python-mirrored buffer
//! generator, a JSON codec, console tables and timing statistics.
//!
//! These exist because the offline build environment vendors no serde/clap/
//! criterion-style crates — and because the paper's pipeline must be fully
//! reproducible from a single seed.

pub mod fill;
pub mod json;
pub mod rng;
pub mod table;
pub mod timing;

pub use fill::fill_buffer;
pub use json::Json;
pub use rng::Rng;
pub use table::Table;
pub use timing::Stats;
