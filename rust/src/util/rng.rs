//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! Everything stochastic in the library (k-means init, forests, MLP init,
//! devsim noise, train/test splits) flows through this generator so whole
//! experiment pipelines are reproducible from a single seed.

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box-Muller pair.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Expand a single `u64` seed into the four-lane state via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-tree RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The next raw 64-bit output of the xoshiro256++ core.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 top bits -> [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal deviate (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices sampled without replacement from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
            buckets[(u * 10.0) as usize] += 1;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        for b in buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.02, "bucket frac {frac}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut rng = Rng::new(9);
        for n in [1usize, 2, 3, 17, 640] {
            for _ in 0..200 {
                assert!(rng.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(15);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
