//! Minimal JSON parser/writer (no external deps are vendored for this).
//!
//! Supports the full JSON grammar minus surrogate-pair escapes; numbers are
//! f64 (integers round-trip exactly up to 2^53, far beyond anything the
//! manifest needs). Used for `artifacts/manifest.json`, deploy files and
//! experiment result dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (keys emit in sorted order).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null` (also the serialization of non-finite numbers).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number; integers are exact up to 2^53.
    Num(f64),
    /// A string (escapes already decoded).
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object, keyed in sorted order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    /// Object member by key; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a `usize`, if this is a non-negative integer `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// True for JSON `null`.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// `obj["a"]["b"][2]`-style path access: `json.path(&["a", "b", "2"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = match cur {
                Json::Obj(m) => m.get(*k)?,
                Json::Arr(v) => v.get(k.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // -- constructors ------------------------------------------------------

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn arr_num<I: IntoIterator<Item = f64>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }

    /// Build an array of strings.
    pub fn arr_str<I: IntoIterator<Item = String>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Str).collect())
    }

    // -- serialization -----------------------------------------------------

    /// Serialize to compact JSON text (sorted object keys, no whitespace).
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser.
// ---------------------------------------------------------------------------

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        match self.bump() {
            Some(got) if got == b => Ok(()),
            got => Err(format!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos.saturating_sub(1),
                got.map(|g| g as char)
            )),
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, got {:?}",
                        self.pos.saturating_sub(1),
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, got {:?}",
                        self.pos.saturating_sub(1),
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or("bad hex in \\u escape")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.bytes.len());
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path(&["a", "0"]).unwrap().as_f64(), Some(1.0));
        assert!(v.path(&["a", "2", "b"]).unwrap().is_null());
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_manifest_like() {
        let src = r#"{
            "version": 1,
            "artifacts": [
                {"path": "matmul/mm.hlo.txt", "m": 512, "flops": 411041792,
                 "config": null, "inputs": [[1, 512, 784], [1, 784, 512]]}
            ]
        }"#;
        let v = parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("m").unwrap().as_usize(), Some(512));
        assert!(a.get("config").unwrap().is_null());
        assert_eq!(
            a.path(&["inputs", "1", "2"]).unwrap().as_usize(),
            Some(512)
        );
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let s = Json::Str("a\"b\\c\n".into()).to_string();
        assert_eq!(parse(&s).unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn integer_exactness() {
        let v = parse("9007199254740992").unwrap(); // 2^53
        assert_eq!(v.as_f64(), Some(9007199254740992.0));
        assert_eq!(Json::Num(123456789.0).to_string(), "123456789");
    }

    #[test]
    fn obj_builder() {
        let v = Json::obj(vec![
            ("name", Json::Str("x".into())),
            ("vals", Json::arr_num([1.0, 2.5])),
        ]);
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
