//! Plain-text table rendering for the experiment harness: every figure/table
//! of the paper is reproduced as an aligned console table (plus CSV dump).

/// A simple column-aligned table with a title and optional notes.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Title line rendered as `== title ==` above the header row.
    pub title: String,
    /// Column headers; every row must match their count.
    pub headers: Vec<String>,
    /// Row cells, outer index = row, inner index = column.
    pub rows: Vec<Vec<String>>,
    /// Free-form footnotes rendered as `note: ...` lines.
    pub notes: Vec<String>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a row; panics if the cell count differs from the headers.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Append a footnote line.
    pub fn note(&mut self, s: &str) -> &mut Self {
        self.notes.push(s.to_string());
        self
    }

    /// Render with per-column alignment (numbers right, text left).
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let numeric: Vec<bool> = (0..ncols)
            .map(|i| {
                self.rows.iter().all(|r| {
                    r[i].is_empty()
                        || r[i].trim_start_matches(['-', '+']).starts_with(|c: char| {
                            c.is_ascii_digit() || c == '.'
                        })
                })
            })
            .collect();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, cell) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                if numeric[i] && !self.rows.is_empty() {
                    out.push_str(&format!("{:>width$}", cell, width = widths[i]));
                } else {
                    out.push_str(&format!("{:<width$}", cell, width = widths[i]));
                }
            }
            out.push('\n');
        };
        fmt_row(&self.headers, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        for note in &self.notes {
            out.push_str(&format!("note: {note}\n"));
        }
        out
    }

    /// CSV rendering for machine consumption (results/ directory).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals, trimming noise.
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "gflops"]);
        t.row(vec!["a".into(), "3.10".into()]);
        t.row(vec!["longer".into(), "13.00".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        // numeric column right-aligned: "3.10" padded to width of "gflops".
        assert!(s.lines().any(|l| l.ends_with("  3.10")));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["v,1".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"v,1\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(3.14159, 2), "3.14");
        assert_eq!(fnum(90.0, 1), "90.0");
    }
}
