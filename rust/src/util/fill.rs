//! Deterministic synthetic-buffer generator, bit-identical to
//! `python/compile/model.py::fill_buffer`.
//!
//! The Rust runtime and the Python build pipeline both need the *same*
//! synthetic weights/inputs so that numerics can be cross-checked between a
//! layer artifact executed via PJRT and the JAX reference — without shipping
//! hundreds of megabytes of weight files.

/// xorshift32 stream seeded per-buffer; values uniform in [-0.5, 0.5).
pub fn fill_buffer(seed: u32, count: usize) -> Vec<f32> {
    let mut state = (seed as u64).wrapping_mul(2654435761) as u32;
    if state == 0 {
        state = 88172645;
    }
    let mut out = Vec::with_capacity(count);
    let mut x = state;
    for _ in 0..count {
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        out.push((x as f64 / 4294967296.0 - 0.5) as f32);
    }
    out
}

/// Synthetic layer weights matching `model.py::layer_weights`: `fill_buffer`
/// scaled by 2/sqrt(fan_in); bias unscaled from `seed + 1`.
pub fn layer_weights(seed: u32, fan_in: usize, fan_out: usize) -> (Vec<f32>, Vec<f32>) {
    let scale = 2.0 / (fan_in as f32).sqrt();
    let w = fill_buffer(seed, fan_in * fan_out)
        .into_iter()
        .map(|v| v * scale)
        .collect();
    let b = fill_buffer(seed.wrapping_add(1), fan_out);
    (w, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_values_match_python() {
        // Mirrors python/tests/test_model.py::test_fill_buffer_golden.
        let buf = fill_buffer(7, 4);
        let mut x: u32 = ((7u64 * 2654435761) % 4294967296) as u32;
        let mut want = Vec::new();
        for _ in 0..4 {
            x ^= x << 13;
            x ^= x >> 17;
            x ^= x << 5;
            want.push((x as f64 / 4294967296.0 - 0.5) as f32);
        }
        assert_eq!(buf, want);
    }

    #[test]
    fn deterministic_and_bounded() {
        let a = fill_buffer(123, 1000);
        assert_eq!(a, fill_buffer(123, 1000));
        assert!(a.iter().all(|&v| (-0.5..0.5).contains(&v)));
        let std = {
            let mean: f32 = a.iter().sum::<f32>() / 1000.0;
            (a.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / 1000.0).sqrt()
        };
        assert!(std > 0.2, "std={std}");
    }

    #[test]
    fn zero_seed_not_stuck() {
        let buf = fill_buffer(0, 8);
        assert!(buf.iter().any(|&v| v != buf[0]));
    }

    #[test]
    fn layer_weights_scaled() {
        let (w, b) = layer_weights(7, 100, 10);
        assert_eq!(w.len(), 1000);
        assert_eq!(b.len(), 10);
        // 2/sqrt(100) = 0.2 scale keeps |w| < 0.1.
        assert!(w.iter().all(|&v| v.abs() <= 0.1 + 1e-6));
        assert!(b.iter().any(|&v| v.abs() > 0.1));
    }
}
