//! Measurement utilities shared by the bench harness and the experiment
//! drivers: robust summary statistics over repeated timings.

use std::time::{Duration, Instant};

/// Summary statistics over a set of per-iteration timings.
#[derive(Clone, Debug, PartialEq)]
pub struct Stats {
    /// Number of samples summarized.
    pub n: usize,
    /// Arithmetic mean, seconds.
    pub mean: f64,
    /// Population standard deviation, seconds.
    pub std: f64,
    /// Smallest sample, seconds.
    pub min: f64,
    /// Largest sample, seconds.
    pub max: f64,
    /// Median (50th percentile), seconds.
    pub p50: f64,
    /// 95th percentile, seconds.
    pub p95: f64,
    /// 99th percentile, seconds.
    pub p99: f64,
}

impl Stats {
    /// Summarize per-iteration timings (seconds); panics on an empty slice.
    pub fn from_secs(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "Stats::from_secs on empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            sorted[idx]
        };
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.5),
            p95: pct(0.95),
            p99: pct(0.99),
        }
    }

    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean * 1e3
    }

    /// Mean in microseconds.
    pub fn mean_us(&self) -> f64 {
        self.mean * 1e6
    }
}

/// Time one closure invocation in seconds.
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// The paper's measurement protocol (§3.1): a few warmup runs, then batches
/// of iterations timed together until `budget` wall-clock is spent, yielding
/// a per-iteration mean per batch.
pub fn measure<F: FnMut()>(mut f: F, warmup: usize, budget: Duration) -> Stats {
    for _ in 0..warmup {
        f();
    }
    // Calibrate batch size so one batch is ~budget/10.
    let once = time_once(&mut f).max(1e-9);
    let per_batch = ((budget.as_secs_f64() / 10.0 / once).ceil() as usize).clamp(1, 10_000);
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.is_empty() {
        let t0 = Instant::now();
        for _ in 0..per_batch {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / per_batch as f64);
        if samples.len() >= 200 {
            break;
        }
    }
    Stats::from_secs(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_secs(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p99, 5.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn measure_counts_iterations() {
        let mut count = 0u64;
        let stats = measure(
            || {
                count += 1;
                std::hint::black_box(count);
            },
            3,
            Duration::from_millis(20),
        );
        assert!(count > 3);
        assert!(stats.mean >= 0.0);
        assert!(stats.n >= 1);
    }

    #[test]
    #[should_panic]
    fn empty_stats_panics() {
        Stats::from_secs(&[]);
    }
}
