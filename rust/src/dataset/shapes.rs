//! GEMM shape extraction from the three networks the paper benchmarks
//! (§3: VGG, ResNet, MobileNet — "overall these gave 300 different sets of
//! sizes for the input matrices").
//!
//! Convolutions map to im2col GEMMs: M = out_h*out_w, K = kh*kw*cin,
//! N = cout; fully-connected layers are (1 x K) x (K x N).

/// One benchmarked GEMM problem: out = lhs (b, m, k) x rhs (b, k, n).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmShape {
    /// Output rows (im2col: out_h * out_w).
    pub m: usize,
    /// Reduction depth (im2col: kh * kw * cin).
    pub k: usize,
    /// Output cols (im2col: cout).
    pub n: usize,
    /// Independent GEMMs sharing the shape (leading batch dimension).
    pub batch: usize,
}

impl GemmShape {
    /// Construct a shape from its four dimensions.
    pub fn new(m: usize, k: usize, n: usize, batch: usize) -> GemmShape {
        GemmShape { m, k, n, batch }
    }

    /// Total floating-point work: 2 * batch * m * k * n.
    pub fn flops(&self) -> f64 {
        2.0 * self.batch as f64 * self.m as f64 * self.k as f64 * self.n as f64
    }

    /// Feature vector for the runtime classifier / decision-tree clusterer.
    /// Log-scaled dims plus shape-ratio features (aspect + reduction depth).
    pub fn features(&self) -> Vec<f64> {
        let (m, k, n, b) = (self.m as f64, self.k as f64, self.n as f64, self.batch as f64);
        vec![
            m.log2(),
            k.log2(),
            n.log2(),
            b.log2(),
            (m * n * b).log2(),          // output volume -> parallelism
            (m * k * n * b).log2(),      // total work
            (m / n).log2(),              // output aspect
            (k / (m * n).sqrt()).log2(), // reduction depth vs output size
        ]
    }

    /// Compact display/file label, e.g. `m512k784n512b16`.
    pub fn label(&self) -> String {
        format!("m{}k{}n{}b{}", self.m, self.k, self.n, self.batch)
    }
}

/// Names of [`GemmShape::features`] components, index-aligned.
pub const FEATURE_NAMES: [&str; 8] = [
    "log2_m",
    "log2_k",
    "log2_n",
    "log2_batch",
    "log2_out_volume",
    "log2_flops",
    "log2_aspect",
    "log2_depth_ratio",
];

fn conv(hw_in: usize, kernel: usize, stride: usize, pad: usize, cin: usize, cout: usize) -> (usize, GemmShape) {
    let hw_out = (hw_in + 2 * pad - kernel) / stride + 1;
    (hw_out, GemmShape::new(hw_out * hw_out, kernel * kernel * cin, cout, 1))
}

/// VGG16 (paper §6): 13 3x3 convs + 3 FC layers at 224x224.
pub fn vgg16_gemms() -> Vec<GemmShape> {
    let mut out = Vec::new();
    let stages: [(usize, usize); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut hw = 224;
    let mut cin = 3;
    for (cout, reps) in stages {
        for _ in 0..reps {
            let (_, g) = conv(hw, 3, 1, 1, cin, cout);
            out.push(g);
            cin = cout;
        }
        hw /= 2;
    }
    out.push(GemmShape::new(1, hw * hw * cin, 4096, 1)); // fc6
    out.push(GemmShape::new(1, 4096, 4096, 1)); // fc7
    out.push(GemmShape::new(1, 4096, 1000, 1)); // fc8
    out
}

/// ResNet-50 bottleneck GEMMs (stem, 1x1 reduce / 3x3 / 1x1 expand per
/// block, downsample projections, final FC).
pub fn resnet50_gemms() -> Vec<GemmShape> {
    let mut out = Vec::new();
    // Stem: 7x7/2 then the first 3x3 of each stage may stride.
    let (hw, stem) = conv(224, 7, 2, 3, 3, 64);
    out.push(stem);
    let hw = hw / 2; // 3x3/2 max pool -> 56

    // (blocks, mid_channels, out_channels); input channels tracked.
    let stages: [(usize, usize, usize); 4] =
        [(3, 64, 256), (4, 128, 512), (6, 256, 1024), (3, 512, 2048)];
    let mut cin = 64;
    let mut s = hw;
    for (stage_idx, (blocks, mid, cout)) in stages.iter().enumerate() {
        let stride = if stage_idx == 0 { 1 } else { 2 };
        for b in 0..*blocks {
            let blk_stride = if b == 0 { stride } else { 1 };
            let s_out = s / blk_stride;
            // 1x1 reduce (applied before stride in the 3x3 per torchvision).
            out.push(GemmShape::new(s * s, cin, *mid, 1));
            // 3x3 (stride on the first block of the stage).
            let (_, g) = conv(s, 3, blk_stride, 1, *mid, *mid);
            out.push(g);
            // 1x1 expand.
            out.push(GemmShape::new(s_out * s_out, *mid, *cout, 1));
            if b == 0 {
                // Projection shortcut.
                out.push(GemmShape::new(s_out * s_out, cin, *cout, 1));
            }
            cin = *cout;
            s = s_out;
        }
    }
    out.push(GemmShape::new(1, 2048, 1000, 1)); // fc
    out
}

/// MobileNetV2 pointwise GEMMs (expansion + projection 1x1 convs; depthwise
/// convolutions are not GEMMs and are computed by dedicated kernels, as in
/// SYCL-DNN).
pub fn mobilenetv2_gemms() -> Vec<GemmShape> {
    let mut out = Vec::new();
    let (hw, stem) = conv(224, 3, 2, 1, 3, 32);
    out.push(stem);
    // (expansion t, cout, repeats, stride) per the MobileNetV2 paper.
    let blocks: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32;
    let mut s = hw;
    for (t, cout, reps, stride) in blocks {
        for r in 0..reps {
            let blk_stride = if r == 0 { stride } else { 1 };
            let hidden = cin * t;
            if t != 1 {
                // Expansion 1x1 at the input resolution.
                out.push(GemmShape::new(s * s, cin, hidden, 1));
            }
            let s_out = s / blk_stride; // depthwise 3x3 handles the stride
            // Projection 1x1 at the output resolution.
            out.push(GemmShape::new(s_out * s_out, hidden, cout, 1));
            cin = cout;
            s = s_out;
        }
    }
    // Final 1x1 to 1280 and classifier.
    out.push(GemmShape::new(s * s, cin, 1280, 1));
    out.push(GemmShape::new(1, 1280, 1000, 1));
    out
}

/// Weight-gradient GEMM of a forward im2col GEMM: dW = dOut^T x patches is
/// (cout x hw^2) x (hw^2 x 9cin) — the paper's tall-skinny pathological
/// class (e.g. m=32, k=12321, n=27 is the MobileNet stem's weight grad).
pub fn wgrad_of(g: &GemmShape) -> GemmShape {
    GemmShape::new(g.n, g.m, g.k, g.batch)
}

/// The paper's full benchmark suite: all three networks' GEMMs (forward
/// im2col plus conv weight-gradient orientations) crossed with batch sizes
/// {1, 4, 16}, deduplicated (~300 distinct size sets — repeated blocks
/// inside each network share shapes, matching the paper's "300 different
/// sets of sizes" from the same three networks).
pub fn benchmark_shapes() -> Vec<GemmShape> {
    let mut all = Vec::new();
    let base: Vec<GemmShape> = vgg16_gemms()
        .into_iter()
        .chain(resnet50_gemms())
        .chain(mobilenetv2_gemms())
        .collect();
    for batch in [1usize, 4, 16] {
        for g in &base {
            all.push(GemmShape::new(g.m, g.k, g.n, batch));
            if g.m > 1 {
                let w = wgrad_of(g);
                all.push(GemmShape::new(w.m, w.k, w.n, batch));
            }
        }
    }
    // The paper's three Figure-1 example size sets, verbatim (§3.2).
    all.push(GemmShape::new(512, 784, 512, 16));
    all.push(GemmShape::new(512, 4608, 784, 1));
    all.push(GemmShape::new(32, 12321, 27, 1));
    dedupe(all)
}

fn dedupe(shapes: Vec<GemmShape>) -> Vec<GemmShape> {
    let mut seen = std::collections::HashSet::new();
    shapes.into_iter().filter(|s| seen.insert(*s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_shape_count_and_range() {
        let g = vgg16_gemms();
        assert_eq!(g.len(), 16);
        // Paper §6.2 territory: M spans 50176 (conv1) down to the FC tails.
        assert!(g.iter().any(|s| s.m == 224 * 224 && s.n == 64));
        assert!(g.iter().any(|s| s.m == 112 * 112 && s.n == 128));
        assert!(g.iter().any(|s| s.m == 196 && s.k == 4608 && s.n == 512));
        assert_eq!(g[0].k, 27); // 3x3x3 stem
        assert_eq!(g.last().unwrap().n, 1000);
    }

    #[test]
    fn resnet50_structure() {
        let g = resnet50_gemms();
        // 1 stem + 16 blocks x 3 + 4 projections + 1 fc = 54.
        assert_eq!(g.len(), 54);
        assert!(g.iter().any(|s| s.m == 56 * 56 && s.k == 64 && s.n == 64));
        assert!(g.iter().any(|s| s.m == 49 && s.k == 512 && s.n == 2048));
    }

    #[test]
    fn mobilenetv2_structure() {
        let g = mobilenetv2_gemms();
        // Expansion layers exist for t=6 blocks and shapes look pointwise.
        assert!(g.iter().any(|s| s.k == 32 && s.n == 192)); // 32 -> 192 expand? (t=6 of 32)
        assert!(g.iter().any(|s| s.n == 1280));
        assert!(g.len() > 25);
    }

    #[test]
    fn benchmark_suite_around_300() {
        let shapes = benchmark_shapes();
        assert!(
            (250..=350).contains(&shapes.len()),
            "expected ~300 size sets, got {}",
            shapes.len()
        );
        // All distinct.
        let set: std::collections::HashSet<_> = shapes.iter().collect();
        assert_eq!(set.len(), shapes.len());
        // Contains the paper's shape classes: the wgrad of VGG's conv4
        // block ((512, 196*?, ...) territory) and tall-skinny wgrads of the
        // low-channel stems.
        assert!(shapes.iter().any(|s| s.m == 512 && s.k == 784 && s.n == 4608));
        assert!(shapes.iter().any(|s| s.n == 27 && s.k > 10_000 && s.m <= 64));
    }

    #[test]
    fn features_finite_and_distinct() {
        let shapes = benchmark_shapes();
        for s in &shapes {
            let f = s.features();
            assert_eq!(f.len(), FEATURE_NAMES.len());
            assert!(f.iter().all(|v| v.is_finite()), "{s:?}");
        }
        let a = shapes[0].features();
        let b = shapes[1].features();
        assert_ne!(a, b);
    }

    #[test]
    fn flops_positive_monotone_in_batch() {
        let s1 = GemmShape::new(64, 64, 64, 1);
        let s16 = GemmShape::new(64, 64, 64, 16);
        assert_eq!(s1.flops() * 16.0, s16.flops());
    }
}
