//! The benchmark performance dataset: a (size-sets x 640 configs) matrix of
//! GFLOP/s measurements for one device, plus split/evaluation helpers and a
//! CSV codec for caching simulator output and real-CPU measurements.

use crate::dataset::config::{all_configs, NUM_CONFIGS};
use crate::dataset::normalize::Normalization;
use crate::dataset::shapes::GemmShape;
use crate::linalg::stats::argmax;
use crate::linalg::Matrix;
use crate::util::Rng;

/// One device's benchmark matrix: GFLOP/s for every (size set, config)
/// pair — the substrate of selection (§4) and classification (§5).
#[derive(Clone, Debug)]
pub struct PerfDataset {
    /// Device label the measurements came from (profile name or host tag).
    pub device: String,
    /// The size sets (rows of the matrix), in measurement order.
    pub shapes: Vec<GemmShape>,
    /// Raw GFLOP/s: gflops[(shape_idx, config_idx)].
    pub gflops: Matrix,
}

/// A train/test split as index lists into `PerfDataset::shapes`.
#[derive(Clone, Debug)]
pub struct Split {
    /// Row indices in the training fold.
    pub train: Vec<usize>,
    /// Row indices in the held-out fold.
    pub test: Vec<usize>,
}

impl PerfDataset {
    /// Wrap a measured matrix; panics unless it is shapes x NUM_CONFIGS.
    pub fn new(device: &str, shapes: Vec<GemmShape>, gflops: Matrix) -> PerfDataset {
        assert_eq!(gflops.rows, shapes.len());
        assert_eq!(gflops.cols, NUM_CONFIGS);
        PerfDataset { device: device.to_string(), shapes, gflops }
    }

    /// Number of size sets (matrix rows).
    pub fn n_shapes(&self) -> usize {
        self.shapes.len()
    }

    /// Normalized copy of the performance matrix.
    pub fn normalized(&self, norm: Normalization) -> Matrix {
        norm.apply(&self.gflops)
    }

    /// Best configuration index for a size set.
    pub fn best_config(&self, shape_idx: usize) -> usize {
        argmax(self.gflops.row(shape_idx))
    }

    /// GFLOP/s of the best configuration for a size set.
    pub fn best_gflops(&self, shape_idx: usize) -> f64 {
        self.gflops.row(shape_idx)[self.best_config(shape_idx)]
    }

    /// Relative performance (0..1) of `config` on `shape_idx`.
    pub fn relative(&self, shape_idx: usize, config: usize) -> f64 {
        let best = self.best_gflops(shape_idx);
        if best <= 0.0 {
            0.0
        } else {
            self.gflops[(shape_idx, config)] / best
        }
    }

    /// How many size sets each configuration wins (Figure 2).
    pub fn winner_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; NUM_CONFIGS];
        for r in 0..self.n_shapes() {
            counts[self.best_config(r)] += 1;
        }
        counts
    }

    /// Feature matrix (n_shapes x n_features) for classifiers/trees.
    pub fn features(&self) -> Matrix {
        Matrix::from_rows(&self.shapes.iter().map(|s| s.features()).collect::<Vec<_>>())
    }

    /// Deterministic shuffled split; `train_frac` in (0, 1).
    pub fn split(&self, train_frac: f64, seed: u64) -> Split {
        assert!((0.0..1.0).contains(&train_frac) && train_frac > 0.0);
        let mut idx: Vec<usize> = (0..self.n_shapes()).collect();
        Rng::new(seed).shuffle(&mut idx);
        let n_train = ((self.n_shapes() as f64) * train_frac).round() as usize;
        let n_train = n_train.clamp(1, self.n_shapes() - 1);
        Split { train: idx[..n_train].to_vec(), test: idx[n_train..].to_vec() }
    }

    /// Restrict to a subset of size sets (e.g. the train rows).
    pub fn subset(&self, indices: &[usize]) -> PerfDataset {
        let shapes = indices.iter().map(|&i| self.shapes[i]).collect();
        let rows: Vec<Vec<f64>> =
            indices.iter().map(|&i| self.gflops.row(i).to_vec()).collect();
        PerfDataset {
            device: self.device.clone(),
            shapes,
            gflops: Matrix::from_rows(&rows),
        }
    }

    // -- CSV codec ----------------------------------------------------------

    /// Serialize as CSV: an `m,k,n,batch` prefix plus one column per
    /// config in canonical name order.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str("m,k,n,batch");
        for cfg in all_configs() {
            out.push(',');
            out.push_str(&cfg.name());
        }
        out.push('\n');
        for (i, s) in self.shapes.iter().enumerate() {
            out.push_str(&format!("{},{},{},{}", s.m, s.k, s.n, s.batch));
            for v in self.gflops.row(i) {
                out.push_str(&format!(",{v:.4}"));
            }
            out.push('\n');
        }
        out
    }

    /// Parse the [`Self::to_csv`] format, validating the header against
    /// the canonical config space (order included).
    pub fn from_csv(device: &str, text: &str) -> Result<PerfDataset, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty csv")?;
        let cols: Vec<&str> = header.split(',').collect();
        if cols.len() != 4 + NUM_CONFIGS {
            return Err(format!(
                "expected {} columns, got {}",
                4 + NUM_CONFIGS,
                cols.len()
            ));
        }
        // Validate config-name order matches the canonical space.
        for (cfg, col) in all_configs().iter().zip(&cols[4..]) {
            if cfg.name() != *col {
                return Err(format!("config column mismatch: {col} != {}", cfg.name()));
            }
        }
        let mut shapes = Vec::new();
        let mut rows = Vec::new();
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split(',').collect();
            if fields.len() != 4 + NUM_CONFIGS {
                return Err(format!("line {}: wrong field count", lineno + 2));
            }
            let parse_usize = |s: &str| -> Result<usize, String> {
                s.parse().map_err(|_| format!("line {}: bad int {s}", lineno + 2))
            };
            shapes.push(GemmShape::new(
                parse_usize(fields[0])?,
                parse_usize(fields[1])?,
                parse_usize(fields[2])?,
                parse_usize(fields[3])?,
            ));
            let mut row = Vec::with_capacity(NUM_CONFIGS);
            for f in &fields[4..] {
                row.push(
                    f.parse::<f64>()
                        .map_err(|_| format!("line {}: bad float {f}", lineno + 2))?,
                );
            }
            rows.push(row);
        }
        if rows.is_empty() {
            return Err("no data rows".into());
        }
        Ok(PerfDataset::new(device, shapes, Matrix::from_rows(&rows)))
    }

    /// Write the CSV form to `path`.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }

    /// Read a dataset back from a [`Self::save`]d CSV file.
    pub fn load(device: &str, path: &std::path::Path) -> Result<PerfDataset, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        PerfDataset::from_csv(device, &text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dataset(n_shapes: usize, seed: u64) -> PerfDataset {
        let mut rng = Rng::new(seed);
        let shapes: Vec<GemmShape> = (0..n_shapes)
            .map(|i| GemmShape::new(32 << (i % 4), 64, 32, 1 + (i % 3)))
            .collect();
        let rows: Vec<Vec<f64>> = (0..n_shapes)
            .map(|_| (0..NUM_CONFIGS).map(|_| rng.uniform() * 1000.0).collect())
            .collect();
        PerfDataset::new("test", shapes, Matrix::from_rows(&rows))
    }

    #[test]
    fn best_and_relative() {
        let ds = tiny_dataset(5, 1);
        for r in 0..5 {
            let best = ds.best_config(r);
            assert_eq!(ds.relative(r, best), 1.0);
            for c in 0..NUM_CONFIGS {
                assert!(ds.relative(r, c) <= 1.0);
            }
        }
    }

    #[test]
    fn winner_counts_sum_to_rows() {
        let ds = tiny_dataset(20, 2);
        let counts = ds.winner_counts();
        assert_eq!(counts.iter().sum::<usize>(), 20);
    }

    #[test]
    fn split_partitions() {
        let ds = tiny_dataset(10, 3);
        let split = ds.split(0.7, 42);
        assert_eq!(split.train.len() + split.test.len(), 10);
        let mut all: Vec<usize> =
            split.train.iter().chain(&split.test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        // Deterministic.
        let again = ds.split(0.7, 42);
        assert_eq!(split.train, again.train);
    }

    #[test]
    fn subset_preserves_rows() {
        let ds = tiny_dataset(6, 4);
        let sub = ds.subset(&[4, 1]);
        assert_eq!(sub.n_shapes(), 2);
        assert_eq!(sub.shapes[0], ds.shapes[4]);
        assert_eq!(sub.gflops.row(1), ds.gflops.row(1));
    }

    #[test]
    fn csv_roundtrip() {
        let ds = tiny_dataset(4, 5);
        let csv = ds.to_csv();
        let back = PerfDataset::from_csv("test", &csv).unwrap();
        assert_eq!(back.shapes, ds.shapes);
        for r in 0..4 {
            for c in 0..NUM_CONFIGS {
                assert!((back.gflops[(r, c)] - ds.gflops[(r, c)]).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn csv_rejects_malformed() {
        assert!(PerfDataset::from_csv("x", "").is_err());
        assert!(PerfDataset::from_csv("x", "m,k,n,batch,onlyonecfg\n").is_err());
        let ds = tiny_dataset(2, 6);
        let mut csv = ds.to_csv();
        csv.push_str("1,2,3\n"); // short row
        assert!(PerfDataset::from_csv("x", &csv).is_err());
    }

    #[test]
    fn normalized_rows_peak_at_one() {
        let ds = tiny_dataset(5, 7);
        let norm = ds.normalized(Normalization::Standard);
        for r in 0..5 {
            let max = norm.row(r).iter().cloned().fold(0.0f64, f64::max);
            assert!((max - 1.0).abs() < 1e-12);
        }
    }
}
