//! The benchmark dataset substrate: the kernel configuration space, the
//! GEMM shape suite derived from VGG16/ResNet50/MobileNetV2 (paper §3), the
//! four normalization schemes (§3.4) and the performance-matrix container.

pub mod config;
pub mod data;
pub mod normalize;
pub mod shapes;

pub use config::{
    all_configs, config_by_index, config_by_name, KernelConfig, NUM_CONFIGS,
};
pub use data::{PerfDataset, Split};
pub use normalize::{Normalization, ALL_NORMALIZATIONS};
pub use shapes::{benchmark_shapes, GemmShape, FEATURE_NAMES};
