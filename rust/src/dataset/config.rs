//! The 640-point kernel configuration space (paper §3), mirroring
//! `python/compile/kernels/config.py` exactly — index order, names, block
//! geometry and the VMEM-footprint estimate. A golden test pins the two
//! implementations together via the artifact manifest.

/// Legal per-axis micro-tile parameter values (paper §3: powers of two
/// up to 8 on each of the three accumulator axes).
pub const TILE_SIZES: [usize; 4] = [1, 2, 4, 8];

/// The ten legal work-group pairings of the paper.
pub const WORKGROUPS: [(usize, usize); 10] = [
    (1, 64),
    (1, 128),
    (8, 8),
    (8, 16),
    (8, 32),
    (16, 8),
    (16, 16),
    (32, 8),
    (64, 1),
    (128, 1),
];

/// One unit of K-chunk depth per unit of the A tile parameter (must match
/// `config.py::K_UNIT`).
pub const K_UNIT: usize = 32;

/// Size of the full configuration space: 4^3 tile triples x 10 legal
/// work-group pairings = 640 (the paper's kernel count).
pub const NUM_CONFIGS: usize = TILE_SIZES.len().pow(3) * WORKGROUPS.len();

/// One point in the kernel configuration space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct KernelConfig {
    /// Micro-tile rows accumulated per work-item (`rows` in the paper).
    pub acc_r: usize,
    /// K-depth tile parameter; one unit is [`K_UNIT`] elements of K.
    pub acc_a: usize,
    /// Micro-tile cols accumulated per work-item (`cols` in the paper).
    pub acc_c: usize,
    /// Work-group rows (first element of the legal [`WORKGROUPS`] pair).
    pub wg_r: usize,
    /// Work-group cols (second element of the legal [`WORKGROUPS`] pair).
    pub wg_c: usize,
}

impl KernelConfig {
    /// Rows of the HBM->VMEM output block (work-group x micro-tile).
    pub fn block_m(&self) -> usize {
        self.acc_r * self.wg_r
    }

    /// Cols of the HBM->VMEM output block.
    pub fn block_n(&self) -> usize {
        self.acc_c * self.wg_c
    }

    /// Depth of one K step of the VMEM pipeline.
    pub fn k_chunk(&self) -> usize {
        self.acc_a * K_UNIT
    }

    /// Canonical name, e.g. `r4a8c4_wg16x16` — the artifact/manifest key.
    pub fn name(&self) -> String {
        format!(
            "r{}a{}c{}_wg{}x{}",
            self.acc_r, self.acc_a, self.acc_c, self.wg_r, self.wg_c
        )
    }

    /// Stable index in `all_configs()` ordering.
    pub fn index(&self) -> usize {
        let ti = tile_pos(self.acc_r) * 16 + tile_pos(self.acc_a) * 4 + tile_pos(self.acc_c);
        let wi = WORKGROUPS
            .iter()
            .position(|&(r, c)| r == self.wg_r && c == self.wg_c)
            .expect("illegal work-group pairing");
        ti * WORKGROUPS.len() + wi
    }

    /// Estimated VMEM working set (bytes): lhs/rhs K-chunk strips + f32 acc.
    pub fn vmem_bytes(&self, dtype_bytes: usize) -> usize {
        let lhs = self.block_m() * self.k_chunk() * dtype_bytes;
        let rhs = self.k_chunk() * self.block_n() * dtype_bytes;
        let acc = self.block_m() * self.block_n() * 4;
        lhs + rhs + acc
    }

    /// Work-group size (number of "work-items" in SYCL terms).
    pub fn wg_size(&self) -> usize {
        self.wg_r * self.wg_c
    }
}

fn tile_pos(t: usize) -> usize {
    TILE_SIZES
        .iter()
        .position(|&x| x == t)
        .expect("tile size not in {1,2,4,8}")
}

/// Config for a stable index (inverse of `KernelConfig::index`).
pub fn config_by_index(idx: usize) -> KernelConfig {
    assert!(idx < NUM_CONFIGS, "config index {idx} out of range");
    let (ti, wi) = (idx / WORKGROUPS.len(), idx % WORKGROUPS.len());
    let ri = ti / 16;
    let ai = (ti / 4) % 4;
    let ci = ti % 4;
    let (wg_r, wg_c) = WORKGROUPS[wi];
    KernelConfig {
        acc_r: TILE_SIZES[ri],
        acc_a: TILE_SIZES[ai],
        acc_c: TILE_SIZES[ci],
        wg_r,
        wg_c,
    }
}

/// The full space in stable index order.
pub fn all_configs() -> Vec<KernelConfig> {
    (0..NUM_CONFIGS).map(config_by_index).collect()
}

/// Look a configuration up by its canonical name (`r4a8c4_wg16x16`).
pub fn config_by_name(name: &str) -> Option<KernelConfig> {
    // Parse rXaYcZ_wgWxV.
    let rest = name.strip_prefix('r')?;
    let (r, rest) = split_num(rest)?;
    let rest = rest.strip_prefix('a')?;
    let (a, rest) = split_num(rest)?;
    let rest = rest.strip_prefix('c')?;
    let (c, rest) = split_num(rest)?;
    let rest = rest.strip_prefix("_wg")?;
    let (wr, rest) = split_num(rest)?;
    let rest = rest.strip_prefix('x')?;
    let (wc, rest) = split_num(rest)?;
    if !rest.is_empty() {
        return None;
    }
    let cfg = KernelConfig { acc_r: r, acc_a: a, acc_c: c, wg_r: wr, wg_c: wc };
    if TILE_SIZES.contains(&r)
        && TILE_SIZES.contains(&a)
        && TILE_SIZES.contains(&c)
        && WORKGROUPS.contains(&(wr, wc))
    {
        Some(cfg)
    } else {
        None
    }
}

fn split_num(s: &str) -> Option<(usize, &str)> {
    let end = s.find(|c: char| !c.is_ascii_digit()).unwrap_or(s.len());
    if end == 0 {
        return None;
    }
    Some((s[..end].parse().ok()?, &s[end..]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_size() {
        assert_eq!(NUM_CONFIGS, 640);
        assert_eq!(all_configs().len(), 640);
    }

    #[test]
    fn index_roundtrip() {
        for (i, cfg) in all_configs().iter().enumerate() {
            assert_eq!(cfg.index(), i);
            assert_eq!(config_by_index(i), *cfg);
        }
    }

    #[test]
    fn names_unique_and_parseable() {
        let mut names = std::collections::HashSet::new();
        for cfg in all_configs() {
            let name = cfg.name();
            assert!(names.insert(name.clone()), "duplicate name {name}");
            assert_eq!(config_by_name(&name), Some(cfg));
        }
        assert_eq!(config_by_name("r3a1c1_wg8x8"), None);
        assert_eq!(config_by_name("r4a8c4_wg5x5"), None);
        assert_eq!(config_by_name("bogus"), None);
    }

    #[test]
    fn python_parity_spot_checks() {
        // Mirrors test values verified against python in test_config.py.
        let c = config_by_name("r4a8c4_wg16x16").unwrap();
        assert_eq!(c.block_m(), 64);
        assert_eq!(c.block_n(), 64);
        assert_eq!(c.k_chunk(), 256);
        let first = config_by_index(0);
        assert_eq!(first.name(), "r1a1c1_wg1x64");
        let last = config_by_index(639);
        assert_eq!(last.name(), "r8a8c8_wg128x1");
    }

    #[test]
    fn vmem_estimate() {
        let c = config_by_name("r4a1c4_wg8x8").unwrap(); // bm=32, bn=32, kc=32
        assert_eq!(c.vmem_bytes(4), 32 * 32 * 4 + 32 * 32 * 4 + 32 * 32 * 4);
    }
}
