//! The four data-normalization schemes of paper §3.4.
//!
//! Each maps a row of raw per-configuration GFLOP/s values to [0, 1] with
//! the best kernel at 1.0:
//!   * `Standard`  — divide by the row max.
//!   * `RawCutoff` — standard, then clamp values under 0.9 to 0 (sparsity
//!                   without distorting the survivors).
//!   * `Cutoff`    — clamp under 0.9 then rescale the survivors to [0, 1].
//!   * `Sigmoid`   — f(x) = 1 / (1 + exp(50 (0.85 - x))) on the standard
//!                   values: 85% maps to 0.5, below 80% to < 0.1.

use crate::linalg::Matrix;

/// One of the paper's §3.4 row-normalization schemes (module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Normalization {
    /// Divide by the row max; the best kernel maps to exactly 1.0.
    Standard,
    /// Standard, then clamp values under [`CUTOFF`] to 0 (no rescale).
    RawCutoff,
    /// Clamp under [`CUTOFF`] then rescale the survivors to [0, 1].
    Cutoff,
    /// Steep logistic on the standard values: 85% of peak maps to 0.5.
    Sigmoid,
}

/// Every scheme, in the paper's presentation order (sweep helper).
pub const ALL_NORMALIZATIONS: [Normalization; 4] = [
    Normalization::Standard,
    Normalization::RawCutoff,
    Normalization::Cutoff,
    Normalization::Sigmoid,
];

/// Relative-performance threshold of the two cutoff schemes (paper: 0.9).
pub const CUTOFF: f64 = 0.9;

impl Normalization {
    /// Stable CLI/JSON name (`standard`, `raw-cutoff`, `cutoff`, `sigmoid`).
    pub fn name(&self) -> &'static str {
        match self {
            Normalization::Standard => "standard",
            Normalization::RawCutoff => "raw-cutoff",
            Normalization::Cutoff => "cutoff",
            Normalization::Sigmoid => "sigmoid",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn by_name(name: &str) -> Option<Normalization> {
        ALL_NORMALIZATIONS.iter().copied().find(|n| n.name() == name)
    }

    /// Normalize one row of raw GFLOP/s values in place.
    pub fn apply_row(&self, row: &mut [f64]) {
        let max = row.iter().cloned().fold(0.0f64, f64::max);
        if max <= 0.0 {
            for v in row.iter_mut() {
                *v = 0.0;
            }
            return;
        }
        for v in row.iter_mut() {
            *v /= max;
        }
        match self {
            Normalization::Standard => {}
            Normalization::RawCutoff => {
                for v in row.iter_mut() {
                    if *v < CUTOFF {
                        *v = 0.0;
                    }
                }
            }
            Normalization::Cutoff => {
                for v in row.iter_mut() {
                    *v = if *v < CUTOFF { 0.0 } else { (*v - CUTOFF) / (1.0 - CUTOFF) };
                }
            }
            Normalization::Sigmoid => {
                for v in row.iter_mut() {
                    *v = 1.0 / (1.0 + (50.0 * (0.85 - *v)).exp());
                }
            }
        }
    }

    /// Normalize every row of a (sizes x configs) performance matrix.
    pub fn apply(&self, raw: &Matrix) -> Matrix {
        let mut out = raw.clone();
        for r in 0..out.rows {
            self.apply_row(out.row_mut(r));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Vec<f64> {
        vec![100.0, 95.0, 89.0, 50.0, 1.0]
    }

    #[test]
    fn standard_preserves_ratios() {
        let mut r = row();
        Normalization::Standard.apply_row(&mut r);
        assert_eq!(r[0], 1.0);
        assert!((r[1] - 0.95).abs() < 1e-12);
        assert!((r[4] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn raw_cutoff_clamps_without_rescale() {
        let mut r = row();
        Normalization::RawCutoff.apply_row(&mut r);
        assert_eq!(r[0], 1.0);
        assert!((r[1] - 0.95).abs() < 1e-12); // survivor unchanged
        assert_eq!(r[2], 0.0); // 0.89 < 0.9 clamped
        assert_eq!(r[3], 0.0);
    }

    #[test]
    fn cutoff_rescales_survivors() {
        let mut r = row();
        Normalization::Cutoff.apply_row(&mut r);
        assert_eq!(r[0], 1.0);
        assert!((r[1] - 0.5).abs() < 1e-9); // 0.95 -> (0.95-0.9)/0.1
        assert_eq!(r[2], 0.0);
    }

    #[test]
    fn sigmoid_landmarks() {
        // 85% -> 0.5; below 80% -> < 0.1; 100% -> ~1.
        let mut r = vec![100.0, 85.0, 79.9];
        Normalization::Sigmoid.apply_row(&mut r);
        assert!(r[0] > 0.99);
        assert!((r[1] - 0.5).abs() < 1e-9);
        assert!(r[2] < 0.1);
    }

    #[test]
    fn all_outputs_in_unit_interval() {
        for norm in ALL_NORMALIZATIONS {
            let mut r = vec![3160.0, 2000.0, 13.0, 0.0];
            norm.apply_row(&mut r);
            assert!(
                r.iter().all(|&v| (0.0..=1.0).contains(&v)),
                "{:?}: {r:?}",
                norm
            );
            // Sigmoid maps the best kernel to ~0.999 rather than exactly 1.
            assert!(r[0] > 0.99, "{norm:?} best = {}", r[0]);
        }
    }

    #[test]
    fn zero_row_stays_zero() {
        for norm in ALL_NORMALIZATIONS {
            let mut r = vec![0.0, 0.0];
            norm.apply_row(&mut r);
            assert_eq!(r, vec![0.0, 0.0], "{norm:?}");
        }
    }

    #[test]
    fn names_roundtrip() {
        for norm in ALL_NORMALIZATIONS {
            assert_eq!(Normalization::by_name(norm.name()), Some(norm));
        }
        assert_eq!(Normalization::by_name("nope"), None);
    }
}
