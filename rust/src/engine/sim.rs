//! Pure-Rust execution backend: correctness from a naive host GEMM,
//! timing from the `devsim` analytical device model.
//!
//! This is the backend that makes the serving stack run everywhere the
//! tuning pipeline runs: no PJRT, no artifacts on disk (paths in the
//! manifest are treated as opaque cache keys). "Compilation" is simulated —
//! first touch of an artifact counts a compile, later touches count cache
//! hits — so the coordinator's shape-affinity routing has the same cache
//! locality story as the native backend it stands in for.

use std::collections::HashSet;

use crate::dataset::{config_by_index, config_by_name, GemmShape, KernelConfig};
use crate::devsim::{profile_by_name, simulate, DeviceProfile};
use crate::engine::{Backend, BackendStats};
use crate::runtime::{ArtifactKind, ArtifactMeta};

/// Pure-Rust simulation backend: a naive host GEMM for correctness plus
/// the devsim analytical model for simulated device timing (optionally
/// paced, so wall latency tracks predicted kernel quality).
pub struct SimBackend {
    profile: &'static DeviceProfile,
    /// The devsim space only covers the Pallas configs; the XLA-dot
    /// comparator artifact is timed as this well-rounded proxy config.
    xla_proxy: KernelConfig,
    /// Pacing factor: each execute sleeps `permille/1000 x` the simulated
    /// device time, so wall latency tracks kernel quality. 0 = no pacing.
    pace_permille: u32,
    compiled: HashSet<String>,
    stats: BackendStats,
}

impl SimBackend {
    /// An unpaced backend simulating the named devsim device profile.
    pub fn new(profile_name: &str) -> Result<SimBackend, String> {
        SimBackend::with_pacing(profile_name, 0)
    }

    /// A SimBackend whose executes sleep `permille/1000 x` the simulated
    /// device time (1000 = real-time pacing, 20000 = 20x amplification for
    /// benches where the paced sleep must dominate host-GEMM wall time).
    pub fn with_pacing(profile_name: &str, pace_permille: u32) -> Result<SimBackend, String> {
        let profile = profile_by_name(profile_name)
            .ok_or_else(|| format!("unknown device profile {profile_name:?}"))?;
        Ok(SimBackend {
            profile,
            xla_proxy: config_by_name("r4a4c4_wg16x16").expect("proxy config"),
            pace_permille,
            compiled: HashSet::new(),
            stats: BackendStats::default(),
        })
    }

    /// Name of the simulated device profile.
    pub fn profile_name(&self) -> &'static str {
        self.profile.name
    }

    /// Device-seconds the analytical model predicts for this dispatch.
    fn simulated_secs(&self, meta: &ArtifactMeta, shape: &GemmShape) -> f64 {
        self.simulated_secs_on(self.profile, meta, shape)
    }

    /// [`SimBackend::simulated_secs`] priced on an arbitrary profile —
    /// the per-domain timing the coordinator's tenant device pinning
    /// asks for through [`Backend::execute_timed_for`].
    fn simulated_secs_on(
        &self,
        profile: &'static DeviceProfile,
        meta: &ArtifactMeta,
        shape: &GemmShape,
    ) -> f64 {
        let cfg = meta
            .config_index
            .map(config_by_index)
            .unwrap_or(self.xla_proxy);
        let gflops = simulate(profile, shape, &cfg).max(1e-3);
        shape.flops() / (gflops * 1e9)
    }
}

/// Reference batched GEMM: out(b, m, n) = lhs(b, m, k) x rhs(b, k, n).
pub fn host_gemm(
    shape: &GemmShape,
    lhs: &[f32],
    rhs: &[f32],
) -> Result<Vec<f32>, String> {
    let (b, m, k, n) = (shape.batch, shape.m, shape.k, shape.n);
    if lhs.len() != b * m * k {
        return Err(format!(
            "sim gemm: lhs has {} elements, want {} for {:?}",
            lhs.len(),
            b * m * k,
            shape
        ));
    }
    if rhs.len() != b * k * n {
        return Err(format!(
            "sim gemm: rhs has {} elements, want {} for {:?}",
            rhs.len(),
            b * k * n,
            shape
        ));
    }
    let mut out = vec![0.0f32; b * m * n];
    for bi in 0..b {
        let (lo, ro, oo) = (bi * m * k, bi * k * n, bi * m * n);
        for i in 0..m {
            let lhs_row = &lhs[lo + i * k..lo + (i + 1) * k];
            let out_row = &mut out[oo + i * n..oo + (i + 1) * n];
            for (kk, &a) in lhs_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let rhs_row = &rhs[ro + kk * n..ro + (kk + 1) * n];
                for (o, &r) in out_row.iter_mut().zip(rhs_row) {
                    *o += a * r;
                }
            }
        }
    }
    Ok(out)
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn prepare(&mut self, meta: &ArtifactMeta) -> Result<(), String> {
        if self.compiled.insert(meta.path.clone()) {
            self.stats.compiles += 1;
        } else {
            self.stats.cache_hits += 1;
        }
        Ok(())
    }

    fn execute(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<Vec<f32>, String> {
        if meta.kind != ArtifactKind::Matmul {
            return Err(format!("sim backend: {} is not a matmul artifact", meta.path));
        }
        if !self.compiled.contains(&meta.path) {
            self.prepare(meta)?;
        }
        let t0 = std::time::Instant::now();
        let out = host_gemm(shape, lhs, rhs)?;
        let predicted = self.simulated_secs(meta, shape);
        if self.pace_permille > 0 {
            let sleep = predicted * self.pace_permille as f64 / 1000.0;
            std::thread::sleep(std::time::Duration::from_secs_f64(sleep));
        }
        self.stats.executions += 1;
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        self.stats.simulated_secs += predicted;
        Ok(out)
    }

    /// The measured time of a simulated execution is the analytical
    /// model's device time — the host GEMM's wall clock measures this
    /// machine, not the simulated device.
    fn execute_timed(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<(Vec<f32>, f64), String> {
        let out = self.execute(meta, shape, lhs, rhs)?;
        Ok((out, self.simulated_secs(meta, shape)))
    }

    /// Same execution (bit-identical results, same pacing, same stats),
    /// but the reported device time is priced on the pinned `device`
    /// profile when one is given — a per-tenant retune domain simulating
    /// a heterogeneous device inside one pool. An unknown profile name
    /// falls back to the backend's own profile.
    fn execute_timed_for(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
        device: Option<&'static str>,
    ) -> Result<(Vec<f32>, f64), String> {
        let profile = device.and_then(profile_by_name).unwrap_or(self.profile);
        let out = self.execute(meta, shape, lhs, rhs)?;
        Ok((out, self.simulated_secs_on(profile, meta, shape)))
    }

    fn stats(&self) -> BackendStats {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::util::fill_buffer;

    fn backend() -> SimBackend {
        SimBackend::new("i7-6700k").unwrap()
    }

    fn meta_for(m: &Manifest, cfg: Option<usize>, shape: &GemmShape) -> ArtifactMeta {
        m.find_matmul(cfg, shape.m, shape.k, shape.n, shape.batch)
            .expect("synthetic artifact")
            .clone()
    }

    #[test]
    fn identity_matmul_exact() {
        let shape = GemmShape::new(4, 4, 4, 1);
        let mut eye = vec![0.0f32; 16];
        for i in 0..4 {
            eye[i * 4 + i] = 1.0;
        }
        let rhs: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let out = host_gemm(&shape, &eye, &rhs).unwrap();
        assert_eq!(out, rhs);
    }

    #[test]
    fn batched_gemm_matches_per_batch() {
        let shape = GemmShape::new(3, 5, 2, 2);
        let lhs = fill_buffer(1, 2 * 3 * 5);
        let rhs = fill_buffer(2, 2 * 5 * 2);
        let out = host_gemm(&shape, &lhs, &rhs).unwrap();
        let single = GemmShape::new(3, 5, 2, 1);
        let out0 = host_gemm(&single, &lhs[..15], &rhs[..10]).unwrap();
        let out1 = host_gemm(&single, &lhs[15..], &rhs[10..]).unwrap();
        assert_eq!(&out[..6], &out0[..]);
        assert_eq!(&out[6..], &out1[..]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let shape = GemmShape::new(4, 4, 4, 1);
        assert!(host_gemm(&shape, &[0.0; 3], &[0.0; 16]).is_err());
        assert!(host_gemm(&shape, &[0.0; 16], &[0.0; 3]).is_err());
    }

    #[test]
    fn executes_synthetic_artifacts_with_cache_accounting() {
        let manifest = Manifest::synthetic();
        let mut be = backend();
        let shape = GemmShape::new(64, 64, 64, 1);
        let meta = meta_for(&manifest, None, &shape);
        let lhs = fill_buffer(1, 64 * 64);
        let rhs = fill_buffer(2, 64 * 64);
        let out = be.execute(&meta, &shape, &lhs, &rhs).unwrap();
        assert_eq!(out.len(), 64 * 64);
        assert!(out.iter().all(|v| v.is_finite()));
        be.prepare(&meta).unwrap();
        let stats = be.stats();
        assert_eq!(stats.compiles, 1);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.executions, 1);
        assert!(stats.simulated_secs > 0.0);
    }

    #[test]
    fn execute_timed_reports_simulated_device_time() {
        let manifest = Manifest::synthetic();
        let mut be = backend();
        let shape = GemmShape::new(64, 64, 64, 1);
        let meta = meta_for(&manifest, None, &shape);
        let lhs = fill_buffer(1, 64 * 64);
        let rhs = fill_buffer(2, 64 * 64);
        let (out, measured) = be.execute_timed(&meta, &shape, &lhs, &rhs).unwrap();
        assert_eq!(out.len(), 64 * 64);
        // The reported time is the analytical model's device time, exactly
        // what one execute accumulated into the stats.
        assert!((measured - be.stats().simulated_secs).abs() < 1e-15);
        assert!(measured > 0.0);
    }

    #[test]
    fn execute_timed_for_prices_on_the_pinned_profile() {
        let manifest = Manifest::synthetic();
        let mut be = backend(); // i7-6700k
        let shape = GemmShape::new(64, 64, 64, 1);
        let meta = meta_for(&manifest, None, &shape);
        let lhs = fill_buffer(1, 64 * 64);
        let rhs = fill_buffer(2, 64 * 64);
        let (out_own, own) = be.execute_timed(&meta, &shape, &lhs, &rhs).unwrap();
        let (out_none, none) =
            be.execute_timed_for(&meta, &shape, &lhs, &rhs, None).unwrap();
        let (out_gpu, gpu) =
            be.execute_timed_for(&meta, &shape, &lhs, &rhs, Some("r9-nano")).unwrap();
        // Results are bit-identical regardless of the pricing profile.
        assert_eq!(out_own, out_none);
        assert_eq!(out_own, out_gpu);
        // No pin (and an unknown pin) price on the backend's own profile.
        assert!((own - none).abs() < 1e-15);
        let (_, unknown) =
            be.execute_timed_for(&meta, &shape, &lhs, &rhs, Some("not-a-device")).unwrap();
        assert!((own - unknown).abs() < 1e-15);
        // A real pin prices on that device: a different simulated time.
        assert!(gpu > 0.0 && (gpu - own).abs() > 1e-12, "own={own} gpu={gpu}");
    }

    #[test]
    fn paced_backend_sleeps_at_least_the_scaled_time() {
        let manifest = Manifest::synthetic();
        let mut be = SimBackend::with_pacing("r9-nano", 1000).unwrap();
        let shape = GemmShape::new(32, 32, 32, 1);
        let meta = meta_for(&manifest, None, &shape);
        let lhs = fill_buffer(1, 32 * 32);
        let rhs = fill_buffer(2, 32 * 32);
        let t0 = std::time::Instant::now();
        let (_, predicted) = be.execute_timed(&meta, &shape, &lhs, &rhs).unwrap();
        assert!(
            t0.elapsed().as_secs_f64() >= predicted,
            "paced execute must sleep the simulated time"
        );
    }

    #[test]
    fn pallas_and_xla_artifacts_agree_numerically() {
        let manifest = Manifest::synthetic();
        let mut be = backend();
        let shape = GemmShape::new(32, 32, 32, 1);
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let lhs = fill_buffer(3, 32 * 32);
        let rhs = fill_buffer(4, 32 * 32);
        let xla = be
            .execute(&meta_for(&manifest, None, &shape), &shape, &lhs, &rhs)
            .unwrap();
        let pallas = be
            .execute(&meta_for(&manifest, Some(best), &shape), &shape, &lhs, &rhs)
            .unwrap();
        assert_eq!(xla, pallas);
    }
}
