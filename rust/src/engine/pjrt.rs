//! Native execution backend: PJRT via [`crate::runtime::Runtime`].
//!
//! A thin adapter — the `Runtime` keeps its executable cache and stats, the
//! trait impl just maps artifact metadata onto load/execute calls. Not
//! `Send`: the coordinator constructs one per shard thread from the
//! Send-able [`EngineKind`](crate::engine::EngineKind) spec.

use std::path::Path;

use crate::dataset::GemmShape;
use crate::engine::{Backend, BackendStats};
use crate::runtime::{ArtifactKind, ArtifactMeta, Runtime};

/// Native execution of the shipped HLO artifacts through the PJRT
/// runtime (`pjrt` cargo feature).
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// A backend over the PJRT runtime rooted at `artifacts_dir`.
    pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend, String> {
        Ok(PjrtBackend { rt: Runtime::new(artifacts_dir)? })
    }

    /// Borrow the underlying runtime (e.g. for VGG layer chaining).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&mut self, meta: &ArtifactMeta) -> Result<(), String> {
        self.rt.load(&meta.path).map(|_| ())
    }

    fn execute(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<Vec<f32>, String> {
        if meta.kind != ArtifactKind::Matmul {
            return Err(format!("pjrt backend: {} is not a matmul artifact", meta.path));
        }
        let exe = self.rt.load(&meta.path)?;
        let (b, m, k, n) = (shape.batch, shape.m, shape.k, shape.n);
        self.rt
            .execute_f32(&exe, &[(lhs, &[b, m, k]), (rhs, &[b, k, n])])
    }

    fn stats(&self) -> BackendStats {
        let s = self.rt.stats();
        BackendStats {
            compiles: s.compiles,
            cache_hits: s.cache_hits,
            executions: s.executions,
            execute_secs: s.execute_secs,
            simulated_secs: 0.0,
        }
    }
}
