//! Execution engine backends: the layer between the coordinator and
//! whatever actually multiplies matrices.
//!
//! The paper's library must serve any shape a user throws at it from a
//! small deployed kernel set; the serving stack here must equally run the
//! ML selection pipeline anywhere — a laptop with no native XLA, CI, or a
//! machine with a real PJRT plugin. The [`Backend`] trait captures the
//! three obligations of an execution substrate (load/compile an AOT
//! artifact, execute it for a [`GemmShape`], report stats), and the
//! coordinator's executor shards each own one backend instance:
//!
//! * [`SimBackend`] — pure Rust: a naive f32 GEMM for correctness plus the
//!   `devsim` analytical model for simulated device timing. Always
//!   available; this is what `cargo test` exercises.
//! * [`CpuBackend`] — native host execution through the parametrized
//!   GEMM variant family in [`cpu`]: real measured performance with real
//!   input-dependent crossover between kernel configurations. Always
//!   compiled, no external deps.
//! * [`PjrtBackend`] — wraps the PJRT [`crate::runtime::Runtime`]; only
//!   compiled with the `pjrt` cargo feature.
//! * [`FaultyBackend`] — a seeded, deterministic fault-injecting wrapper
//!   over any of the above, driven by a [`FaultPlan`]; the substrate of
//!   the chaos harness that proves quarantine and shard supervision.
//!
//! Backends are deliberately `!Send`-friendly: PJRT handles are `Rc`-based
//! and must stay on one thread, so shards receive a Send-able
//! [`EngineKind`] *spec* and construct their backend on their own thread.

pub mod cpu;
pub mod faulty;
pub mod sim;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use cpu::CpuBackend;
pub use faulty::{FaultPlan, FaultyBackend};
pub use sim::SimBackend;

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use std::path::Path;

use crate::dataset::GemmShape;
use crate::runtime::ArtifactMeta;

/// Counters every backend reports (mirrors the old `RuntimeStats`).
#[derive(Clone, Debug, Default)]
pub struct BackendStats {
    /// Artifacts compiled/loaded for the first time.
    pub compiles: usize,
    /// `prepare` calls satisfied by the executable cache — the currency of
    /// the coordinator's shape-affinity routing.
    pub cache_hits: usize,
    /// GEMM executions performed.
    pub executions: usize,
    /// Wall-clock seconds spent executing.
    pub execute_secs: f64,
    /// Device-seconds predicted by the analytical model (SimBackend only;
    /// zero for native backends).
    pub simulated_secs: f64,
}

/// An execution substrate for AOT GEMM artifacts.
pub trait Backend {
    /// Stable backend label (reports, flags).
    fn name(&self) -> &'static str;

    /// Load/compile the artifact so later `execute` calls are warm.
    /// Idempotent; the second call for the same artifact is a cache hit.
    fn prepare(&mut self, meta: &ArtifactMeta) -> Result<(), String>;

    /// Execute one GEMM: `lhs` is (b, m, k), `rhs` is (b, k, n), row-major.
    fn execute(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<Vec<f32>, String>;

    /// Execute one GEMM and report its measured execution time in seconds
    /// — the telemetry signal online retuning learns from, and the
    /// `measured_ns` the flight recorder stamps on `execute` trace events
    /// (against the predictor's `predicted_ns`). The default
    /// wraps [`Backend::execute`] in a wall clock; the SimBackend
    /// overrides it to report the analytical model's device time (its
    /// host GEMM wall time says nothing about the simulated kernel).
    fn execute_timed(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<(Vec<f32>, f64), String> {
        let t0 = std::time::Instant::now();
        let out = self.execute(meta, shape, lhs, rhs)?;
        Ok((out, t0.elapsed().as_secs_f64()))
    }

    /// [`Backend::execute_timed`] for a per-tenant retune *domain* pinned
    /// to a device profile. Native backends ignore `device` — their
    /// measured wall time is the truth regardless of which domain the
    /// sample feeds — so the default delegates. The SimBackend overrides
    /// it to price the simulated time on the pinned profile instead of
    /// its own, which is what lets one pool's shards feed telemetry
    /// domains that behave like heterogeneous devices. Results are
    /// bit-identical to [`Backend::execute_timed`]; only the reported
    /// seconds may differ.
    fn execute_timed_for(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
        device: Option<&'static str>,
    ) -> Result<(Vec<f32>, f64), String> {
        let _ = device;
        self.execute_timed(meta, shape, lhs, rhs)
    }

    /// Lifetime counters of this backend instance.
    fn stats(&self) -> BackendStats;
}

/// A Send-able spec for constructing a [`Backend`] on a shard thread.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Analytical-model execution on a named `devsim` device profile.
    Sim {
        /// The `devsim` device profile to simulate.
        profile: &'static str,
    },
    /// Like [`EngineKind::Sim`], but each execute also sleeps
    /// `permille/1000 x` the simulated device time, so end-to-end wall
    /// latency tracks predicted kernel quality — what the
    /// `retune_convergence` bench measures.
    SimPaced {
        /// The `devsim` device profile to simulate.
        profile: &'static str,
        /// Pacing factor in permille (1000 = real-time device pacing).
        permille: u32,
    },
    /// Native CPU execution through the parametrized GEMM variant family
    /// in [`cpu`]. Always available; the only backend whose telemetry is
    /// real measured time on every build.
    Cpu {
        /// Worker-thread budget for the thread-parallel variants; 0 means
        /// one worker per available core (the pool divides cores among
        /// shards at startup).
        threads: usize,
    },
    /// Native PJRT execution of the HLO artifacts.
    #[cfg(feature = "pjrt")]
    Pjrt,
}

impl Default for EngineKind {
    fn default() -> EngineKind {
        EngineKind::Sim { profile: "i7-6700k" }
    }
}

impl EngineKind {
    /// Instantiate the backend. Called on the owning shard thread because
    /// the result is not necessarily `Send`.
    pub fn create(&self, _artifacts_dir: &Path) -> Result<Box<dyn Backend>, String> {
        match self {
            EngineKind::Sim { profile } => Ok(Box::new(SimBackend::new(profile)?)),
            EngineKind::SimPaced { profile, permille } => {
                Ok(Box::new(SimBackend::with_pacing(profile, *permille)?))
            }
            EngineKind::Cpu { threads } => Ok(Box::new(CpuBackend::new(*threads))),
            #[cfg(feature = "pjrt")]
            EngineKind::Pjrt => Ok(Box::new(PjrtBackend::new(_artifacts_dir)?)),
        }
    }

    /// Stable engine label (flags, reports).
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Sim { .. } => "sim",
            EngineKind::SimPaced { .. } => "sim-paced",
            EngineKind::Cpu { .. } => "cpu",
            #[cfg(feature = "pjrt")]
            EngineKind::Pjrt => "pjrt",
        }
    }

    /// Parse a `--backend` style flag value.
    pub fn by_name(name: &str) -> Option<EngineKind> {
        match name {
            "sim" => Some(EngineKind::default()),
            "cpu" => Some(EngineKind::Cpu { threads: 0 }),
            #[cfg(feature = "pjrt")]
            "pjrt" => Some(EngineKind::Pjrt),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_engine_is_sim_and_creates() {
        let kind = EngineKind::default();
        assert_eq!(kind.name(), "sim");
        let backend = kind.create(Path::new("/nonexistent")).unwrap();
        assert_eq!(backend.name(), "sim");
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(EngineKind::by_name("sim"), Some(EngineKind::default()));
        assert_eq!(EngineKind::by_name("bogus"), None);
    }

    #[test]
    fn paced_engine_creates_and_names() {
        let kind = EngineKind::SimPaced { profile: "r9-nano", permille: 1000 };
        assert_eq!(kind.name(), "sim-paced");
        let backend = kind.create(Path::new("/nonexistent")).unwrap();
        assert_eq!(backend.name(), "sim");
    }

    #[test]
    fn cpu_engine_creates_and_names() {
        let kind = EngineKind::by_name("cpu").unwrap();
        assert_eq!(kind, EngineKind::Cpu { threads: 0 });
        assert_eq!(kind.name(), "cpu");
        let backend = kind.create(Path::new("/nonexistent")).unwrap();
        assert_eq!(backend.name(), "cpu");
    }

    #[test]
    fn sim_rejects_unknown_profile() {
        assert!(EngineKind::Sim { profile: "not-a-device" }
            .create(Path::new("."))
            .is_err());
    }
}
