//! Native CPU backend: a family of real single-precision GEMM kernels
//! generated from orthogonal knobs.
//!
//! Where [`crate::engine::sim`] prices dispatches with an analytical
//! device model, this backend actually computes the GEMM on the host —
//! through [`NUM_CPU_VARIANTS`] distinct variants spanning:
//!
//! - **cache blocking** ([`Tiling`]): three committed MC/KC/NC panel
//!   schemes with MR x NR register micro-tiles, one per shape regime
//!   (small / skinny / large),
//! - **loop order** ([`LoopOrder`]): which packed panel stays resident in
//!   the outer loop,
//! - **inner-kernel style** ([`MicroKernel`]): scalar reference vs
//!   unrolled auto-vectorizable micro-kernel with tail handling,
//! - **threading** ([`Threading`]): single-threaded vs hand-rolled
//!   `std::thread` column-panel parallelism honoring the shard's budget.
//!
//! Each variant registers as a distinct kernel configuration: its
//! [`KernelMeta::index`] doubles as the `config_index` in artifact
//! manifests and as the column in a [`crate::dataset::PerfDataset`], so
//! the whole dataset -> subset selection -> classifier -> registry
//! pipeline runs unchanged on measured CPU numbers. Variants have real,
//! input-dependent crossover (small shapes favor small tiles and a single
//! thread; large shapes favor big panels and column-panel threads), which
//! is what makes runtime selection worth anything on this backend.
//!
//! All variants are bit-exact against a k-ordered reference GEMM — see
//! the invariant note in [`gemm`].

pub mod gemm;
pub mod grid;

use std::collections::HashSet;
use std::time::Instant;

use crate::dataset::GemmShape;
use crate::engine::sim::host_gemm;
use crate::engine::{Backend, BackendStats};
use crate::runtime::{ArtifactKind, ArtifactMeta};

pub use gemm::gemm_variant;
pub use grid::{collect_dataset, grid_cells, GridCell};

/// Cache-blocking scheme of one CPU GEMM variant: macro-panel sizes for
/// the three blocked loops plus the register micro-tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiling {
    /// Stable name used in variant names (also the shape regime it targets).
    pub name: &'static str,
    /// Rows of the packed lhs macro-panel (the MC loop).
    pub mc: usize,
    /// Depth of one packed k block (the KC loop).
    pub kc: usize,
    /// Columns of the packed rhs macro-panel (the NC loop).
    pub nc: usize,
    /// Rows of the register micro-tile (MR).
    pub mr: usize,
    /// Columns of the register micro-tile (NR).
    pub nr: usize,
}

/// The three committed tilings, one per shape regime. Kept as a plain
/// literal: `tools/devsim_check.py` parses this table to verify the
/// variant family covers every axis without duplicates.
pub const CPU_TILINGS: [Tiling; 3] = [
    Tiling { name: "small", mc: 32, kc: 64, nc: 64, mr: 4, nr: 4 },
    Tiling { name: "skinny", mc: 16, kc: 256, nc: 32, mr: 2, nr: 8 },
    Tiling { name: "large", mc: 128, kc: 128, nc: 256, mr: 8, nr: 8 },
];

/// Which packed panel the blocked GEMM keeps resident in its outer loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopOrder {
    /// The packed lhs panel is the outer-loop resident; rhs panels are
    /// repacked per (row-panel, k-block) pair.
    PackAOuter,
    /// BLIS-style: the packed rhs panel is the outer-loop resident; lhs
    /// panels are repacked per (column-panel, k-block) pair.
    PackBOuter,
}

impl LoopOrder {
    /// Short name fragment used in variant names.
    pub fn tag(&self) -> &'static str {
        match self {
            LoopOrder::PackAOuter => "pa",
            LoopOrder::PackBOuter => "pb",
        }
    }
}

/// Inner-kernel style of one CPU GEMM variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroKernel {
    /// One element at a time, sequential k chain — cannot vectorize.
    Scalar,
    /// Unrolled MR x NR register tile whose independent output lanes
    /// auto-vectorize; edge tiles fall back to the scalar tail path.
    Unrolled,
}

impl MicroKernel {
    /// Short name fragment used in variant names.
    pub fn tag(&self) -> &'static str {
        match self {
            MicroKernel::Scalar => "sc",
            MicroKernel::Unrolled => "vec",
        }
    }
}

/// Threading mode of one CPU GEMM variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Threading {
    /// Everything on the calling thread.
    Single,
    /// Disjoint column panels fanned out over scoped `std::thread`
    /// workers, bounded by the backend's thread budget.
    ColumnPanels,
}

impl Threading {
    /// Short name fragment used in variant names.
    pub fn tag(&self) -> &'static str {
        match self {
            Threading::Single => "t1",
            Threading::ColumnPanels => "tp",
        }
    }
}

/// Number of CPU GEMM variants: every combination of the knob axes.
pub const NUM_CPU_VARIANTS: usize = CPU_TILINGS.len() * 2 * 2 * 2;

/// Full knob assignment of one CPU GEMM variant — the CPU backend's
/// analogue of a `dataset::KernelConfig`. The `index` is the variant's
/// kernel-configuration index throughout the pipeline (manifest
/// `config_index`, dataset column, selector class).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelMeta {
    /// Kernel-configuration index of this variant (0..[`NUM_CPU_VARIANTS`]).
    pub index: usize,
    /// Cache-blocking scheme.
    pub tiling: Tiling,
    /// Packing loop order.
    pub loop_order: LoopOrder,
    /// Inner-kernel style.
    pub micro_kernel: MicroKernel,
    /// Threading mode.
    pub threading: Threading,
}

impl KernelMeta {
    /// Stable variant name, e.g. `cpu_small_pa_vec_t1`: tiling regime,
    /// loop order, micro-kernel, threading.
    pub fn name(&self) -> String {
        format!(
            "cpu_{}_{}_{}_{}",
            self.tiling.name,
            self.loop_order.tag(),
            self.micro_kernel.tag(),
            self.threading.tag()
        )
    }
}

/// Decode a kernel-configuration index into its CPU variant. Returns
/// `None` for indices outside the family (the CPU backend serves those
/// only through the reference-GEMM comparator, `config_index = None`).
///
/// Index layout: `tiling * 8 + loop_order * 4 + micro_kernel * 2 +
/// threading`, matching the iteration order of [`cpu_variants`].
pub fn variant_by_index(index: usize) -> Option<KernelMeta> {
    if index >= NUM_CPU_VARIANTS {
        return None;
    }
    let tiling = CPU_TILINGS[index / 8];
    let loop_order =
        if (index / 4) % 2 == 0 { LoopOrder::PackAOuter } else { LoopOrder::PackBOuter };
    let micro_kernel =
        if (index / 2) % 2 == 0 { MicroKernel::Scalar } else { MicroKernel::Unrolled };
    let threading = if index % 2 == 0 { Threading::Single } else { Threading::ColumnPanels };
    Some(KernelMeta { index, tiling, loop_order, micro_kernel, threading })
}

/// All CPU variants in index order.
pub fn cpu_variants() -> Vec<KernelMeta> {
    (0..NUM_CPU_VARIANTS).filter_map(variant_by_index).collect()
}

/// Analytic cost prior for one CPU dispatch, in seconds — the CPU
/// backend's substitute for the devsim pricing model. Used for admission
/// cost hints and for the retuner's prior on unmeasured cells; real
/// `execute_timed` telemetry overrides it as soon as cells warm up.
///
/// Total over every input: a `config` outside the variant family (or
/// `None`, the reference comparator) prices as the scalar reference GEMM.
/// Never panics, always returns a positive finite value.
pub fn predict_cpu_secs(shape: &GemmShape, config: Option<usize>) -> f64 {
    // Nominal single-core rates and memory/setup costs. Deliberately
    // coarse — this is a prior, not a model to be trusted once telemetry
    // exists — but shaped so the knobs trade off the way the real
    // kernels do (vector >> scalar, threads help only when the column
    // space amortizes spawn cost, tails and repacking tax bad tilings).
    const SCALAR_FLOPS: f64 = 1.2e9;
    const VECTOR_FLOPS: f64 = 7.0e9;
    const PACK_BYTES_PER_SEC: f64 = 8.0e9;
    const L2_BYTES: f64 = 1024.0 * 1024.0;
    const MODEL_THREADS: f64 = 4.0;
    const SPAWN_SECS: f64 = 25e-6;
    const CALL_SECS: f64 = 1.5e-6;

    let flops = shape.flops();
    let Some(v) = config.and_then(variant_by_index) else {
        return (flops / SCALAR_FLOPS + CALL_SECS).max(1e-9);
    };
    let t = v.tiling;
    let (m, k, n) = (shape.m as f64, shape.k as f64, shape.n as f64);
    let batch = shape.batch.max(1) as f64;

    // Fraction of micro-tile lanes doing useful work (tail waste).
    let pad = |dim: f64, tile: f64| (dim / tile).ceil().max(1.0) * tile;
    let tail_eff = (m * n) / (pad(m, t.mr as f64) * pad(n, t.nr as f64));
    let mut rate = match v.micro_kernel {
        MicroKernel::Scalar => SCALAR_FLOPS,
        MicroKernel::Unrolled => VECTOR_FLOPS,
    } * tail_eff.clamp(0.05, 1.0);

    // Packed working set spilling past L2 taxes the streaming rate.
    let working_set = (t.mc * t.kc + t.kc * t.nc) as f64 * 4.0;
    if working_set > L2_BYTES {
        rate *= 0.7;
    }

    let mut overhead = CALL_SECS;
    if v.threading == Threading::ColumnPanels {
        let workers = MODEL_THREADS.min((n / t.nr as f64).ceil()).max(1.0);
        overhead += batch * workers * SPAWN_SECS;
        rate *= workers * 0.9;
    }

    // Packing traffic: the non-resident panel is repacked once per
    // resident outer block.
    let repack_elems = match v.loop_order {
        LoopOrder::PackBOuter => k * n + m * k * (n / t.nc as f64).ceil(),
        LoopOrder::PackAOuter => m * k + k * n * (m / t.mc as f64).ceil(),
    };
    let pack_secs = batch * repack_elems * 4.0 / PACK_BYTES_PER_SEC;

    (flops / rate.max(1.0) + pack_secs + overhead).max(1e-9)
}

/// Native CPU backend executing batched f32 GEMM through the variant
/// family. Artifact `config_index` values map to [`variant_by_index`];
/// `None` runs the k-ordered reference GEMM (the comparator arm). The
/// wall-clock `execute_timed` default is exactly what this backend wants:
/// telemetry sees real measured time.
pub struct CpuBackend {
    threads: usize,
    compiled: HashSet<String>,
    stats: BackendStats,
}

impl CpuBackend {
    /// Build a backend with a worker budget for thread-parallel variants.
    /// `threads == 0` means one worker per available core.
    pub fn new(threads: usize) -> CpuBackend {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        CpuBackend { threads, compiled: HashSet::new(), stats: BackendStats::default() }
    }

    /// The resolved worker budget (never 0).
    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn prepare(&mut self, meta: &ArtifactMeta) -> Result<(), String> {
        if meta.kind != ArtifactKind::Matmul {
            return Err(format!("cpu backend only executes matmul artifacts, got {:?}", meta.kind));
        }
        if let Some(idx) = meta.config_index {
            if variant_by_index(idx).is_none() {
                return Err(format!("cpu backend: config index {idx} has no CPU variant"));
            }
        }
        if self.compiled.insert(meta.path.clone()) {
            self.stats.compiles += 1;
        } else {
            self.stats.cache_hits += 1;
        }
        Ok(())
    }

    fn execute(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<Vec<f32>, String> {
        if meta.kind != ArtifactKind::Matmul {
            return Err(format!("cpu backend only executes matmul artifacts, got {:?}", meta.kind));
        }
        if !self.compiled.contains(&meta.path) {
            self.prepare(meta)?;
        }
        let start = Instant::now();
        let out = match meta.config_index {
            None => host_gemm(shape, lhs, rhs)?,
            Some(idx) => {
                let v = variant_by_index(idx)
                    .ok_or_else(|| format!("cpu backend: config index {idx} has no CPU variant"))?;
                gemm_variant(&v, self.threads, shape, lhs, rhs)?
            }
        };
        let secs = start.elapsed().as_secs_f64();
        self.stats.executions += 1;
        self.stats.execute_secs += secs;
        Ok(out)
    }

    fn stats(&self) -> BackendStats {
        self.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fill_buffer;
    use std::collections::HashSet as Set;

    #[test]
    fn variant_family_is_complete_and_distinct() {
        let variants = cpu_variants();
        assert_eq!(variants.len(), NUM_CPU_VARIANTS);
        assert_eq!(NUM_CPU_VARIANTS, 24);
        let names: Set<String> = variants.iter().map(|v| v.name()).collect();
        assert_eq!(names.len(), NUM_CPU_VARIANTS, "variant names must be distinct");
        for (i, v) in variants.iter().enumerate() {
            assert_eq!(v.index, i);
            assert_eq!(variant_by_index(i).unwrap(), *v);
        }
        assert!(variant_by_index(NUM_CPU_VARIANTS).is_none());
        // Every axis value appears somewhere.
        assert_eq!(variants.iter().map(|v| v.tiling.name).collect::<Set<_>>().len(), 3);
        assert_eq!(variants.iter().map(|v| v.loop_order.tag()).collect::<Set<_>>().len(), 2);
        assert_eq!(variants.iter().map(|v| v.micro_kernel.tag()).collect::<Set<_>>().len(), 2);
        assert_eq!(variants.iter().map(|v| v.threading.tag()).collect::<Set<_>>().len(), 2);
    }

    #[test]
    fn predict_is_total_positive_and_finite() {
        let shapes = [
            GemmShape::new(1, 1, 1, 1),
            GemmShape::new(16, 2048, 16, 1),
            GemmShape::new(192, 192, 192, 4),
        ];
        for s in &shapes {
            for cfg in (0..NUM_CPU_VARIANTS).map(Some).chain([None, Some(9999)]) {
                let t = predict_cpu_secs(s, cfg);
                assert!(t.is_finite() && t > 0.0, "predict({s:?}, {cfg:?}) = {t}");
            }
        }
        // The prior must at least know vectorized beats scalar on a big
        // square shape, all else equal.
        let big = GemmShape::new(192, 192, 192, 1);
        assert!(predict_cpu_secs(&big, Some(22)) < predict_cpu_secs(&big, Some(20)));
    }

    #[test]
    fn backend_executes_variants_and_reference_with_cache_accounting() {
        let mut backend = CpuBackend::new(2);
        let shape = GemmShape::new(17, 9, 13, 2);
        let lhs = fill_buffer(3, shape.batch * shape.m * shape.k);
        let rhs = fill_buffer(4, shape.batch * shape.k * shape.n);
        let want = host_gemm(&shape, &lhs, &rhs).unwrap();

        let meta = |idx: Option<usize>, path: &str| ArtifactMeta {
            path: path.to_string(),
            kind: ArtifactKind::Matmul,
            config_index: idx,
            config_name: idx.and_then(variant_by_index).map(|v| v.name()),
            m: shape.m,
            k: shape.k,
            n: shape.n,
            b: shape.batch,
            flops: shape.flops(),
            network: None,
            layer: None,
            layer_index: None,
            pool: false,
            relu: false,
            inputs: vec![],
            output: vec![],
        };
        let got = backend.execute(&meta(Some(5), "cpu/v5"), &shape, &lhs, &rhs).unwrap();
        assert_eq!(got, want);
        let got = backend.execute(&meta(None, "cpu/ref"), &shape, &lhs, &rhs).unwrap();
        assert_eq!(got, want);
        // Re-executing a prepared artifact is a cache hit, not a compile.
        backend.execute(&meta(Some(5), "cpu/v5"), &shape, &lhs, &rhs).unwrap();
        backend.prepare(&meta(Some(5), "cpu/v5")).unwrap();
        let stats = backend.stats();
        assert_eq!(stats.compiles, 2);
        assert_eq!(stats.cache_hits, 1);
        assert_eq!(stats.executions, 3);
        assert!(stats.execute_secs > 0.0);
        // Out-of-family config indices are rejected, not silently served.
        assert!(backend.execute(&meta(Some(640), "cpu/bad"), &shape, &lhs, &rhs).is_err());
    }
}
