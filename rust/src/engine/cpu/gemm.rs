//! The blocked, packed, single-precision GEMM behind every CPU variant.
//!
//! One implementation, parameterized by the orthogonal knobs of
//! [`KernelMeta`]: the cache-blocking scheme ([`Tiling`]: MC/KC/NC panel
//! sizes plus the MR x NR register micro-tile), the packing loop order
//! ([`LoopOrder`]), the inner-kernel style ([`MicroKernel`]) and the
//! threading mode ([`Threading`]).
//!
//! # Bit-exactness invariant
//!
//! Every variant accumulates each output element in **strictly increasing
//! k order**: the micro-kernel loads the current C tile into its
//! accumulators, folds its k-block's contributions in ascending k, and
//! writes back, and the k-block (`pc`) loop always ascends. Packing only
//! copies values, vectorization in the unrolled micro-kernel runs across
//! *different* output elements (lanes), and the thread-parallel mode
//! splits the output into disjoint column panels each computed by exactly
//! one thread in the same order — so all variants, at any thread budget,
//! produce bit-identical results to a simple k-ordered reference GEMM.
//! The correctness tests in `rust/tests/cpu_gemm.rs` pin this down.

// Numeric kernels pass panels as (slice, offset, stride) tuples; grouping
// them into structs would obscure the indexing the micro-kernels live on.
#![allow(clippy::too_many_arguments)]

use crate::dataset::GemmShape;
use crate::engine::cpu::{KernelMeta, LoopOrder, MicroKernel, Threading, Tiling};

/// Execute one batched GEMM — `lhs` is (b, m, k), `rhs` is (b, k, n), both
/// row-major — through `variant`, using at most `threads` workers for the
/// thread-parallel variants (ignored by [`Threading::Single`]). Validates
/// buffer lengths like the reference GEMM; never panics on shape input.
pub fn gemm_variant(
    variant: &KernelMeta,
    threads: usize,
    shape: &GemmShape,
    lhs: &[f32],
    rhs: &[f32],
) -> Result<Vec<f32>, String> {
    let (b, m, k, n) = (shape.batch, shape.m, shape.k, shape.n);
    if lhs.len() != b * m * k {
        return Err(format!(
            "cpu gemm: lhs has {} elements, want {} for {:?}",
            lhs.len(),
            b * m * k,
            shape
        ));
    }
    if rhs.len() != b * k * n {
        return Err(format!(
            "cpu gemm: rhs has {} elements, want {} for {:?}",
            rhs.len(),
            b * k * n,
            shape
        ));
    }
    let mut out = vec![0.0f32; b * m * n];
    for bi in 0..b {
        let lhs_b = &lhs[bi * m * k..(bi + 1) * m * k];
        let rhs_b = &rhs[bi * k * n..(bi + 1) * k * n];
        let out_b = &mut out[bi * m * n..(bi + 1) * m * n];
        gemm_one(variant, threads, m, k, n, lhs_b, rhs_b, out_b);
    }
    Ok(out)
}

/// One (m, k, n) GEMM into a zero-initialized m x n output.
fn gemm_one(
    v: &KernelMeta,
    threads: usize,
    m: usize,
    k: usize,
    n: usize,
    lhs: &[f32],
    rhs: &[f32],
    out: &mut [f32],
) {
    let panels = match v.threading {
        Threading::Single => 1,
        // Never more workers than there are micro-column tiles to hand out.
        Threading::ColumnPanels => threads.clamp(1, n.div_ceil(v.tiling.nr).max(1)),
    };
    if panels <= 1 {
        gemm_panel(v, m, k, n, 0, n, lhs, rhs, out);
        return;
    }
    // Disjoint contiguous column panels, each a whole number of NR tiles so
    // only the last panel sees column tails. Each worker computes its panel
    // into a private buffer; every output element is produced by exactly
    // one worker in the same k order, so results are identical at any
    // thread budget.
    let nr = v.tiling.nr;
    let step = n.div_ceil(panels).div_ceil(nr) * nr;
    let jobs: Vec<(usize, usize)> =
        (0..n).step_by(step.max(1)).map(|j0| (j0, (n - j0).min(step))).collect();
    let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = jobs
            .iter()
            .map(|&(j0, nw)| {
                scope.spawn(move || {
                    let mut panel = vec![0.0f32; m * nw];
                    gemm_panel(v, m, k, n, j0, nw, lhs, rhs, &mut panel);
                    panel
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("gemm panel worker")).collect()
    });
    for ((j0, nw), panel) in jobs.into_iter().zip(results) {
        for i in 0..m {
            out[i * n + j0..i * n + j0 + nw].copy_from_slice(&panel[i * nw..(i + 1) * nw]);
        }
    }
}

/// The blocked core: columns [j0, j0+nw) of the logical output, written to
/// `out` (m x nw row-major, zero-initialized or holding partial k sums).
fn gemm_panel(
    v: &KernelMeta,
    m: usize,
    k: usize,
    n_total: usize,
    j0: usize,
    nw: usize,
    lhs: &[f32],
    rhs: &[f32],
    out: &mut [f32],
) {
    let Tiling { mc, kc, nc, .. } = v.tiling;
    let mut pack_a: Vec<f32> = Vec::with_capacity(mc * kc);
    let mut pack_b: Vec<f32> = Vec::with_capacity(kc * nc);
    match v.loop_order {
        // BLIS-style: the packed B panel is the outer-loop resident; the A
        // panel is repacked for every (jc, pc) block.
        LoopOrder::PackBOuter => {
            let mut jc = 0;
            while jc < nw {
                let ncw = nc.min(nw - jc);
                let mut pc = 0;
                while pc < k {
                    let kcw = kc.min(k - pc);
                    pack_rhs(&mut pack_b, rhs, n_total, pc, kcw, j0 + jc, ncw);
                    let mut ic = 0;
                    while ic < m {
                        let mcw = mc.min(m - ic);
                        pack_lhs(&mut pack_a, lhs, k, ic, mcw, pc, kcw);
                        macro_tile(v, &pack_a, &pack_b, mcw, kcw, ncw, out, nw, ic, jc);
                        ic += mc;
                    }
                    pc += kc;
                }
                jc += nc;
            }
        }
        // A-resident: the packed A panel is reused across the column sweep;
        // the B panel is repacked for every (ic, pc) block instead.
        LoopOrder::PackAOuter => {
            let mut ic = 0;
            while ic < m {
                let mcw = mc.min(m - ic);
                let mut pc = 0;
                while pc < k {
                    let kcw = kc.min(k - pc);
                    pack_lhs(&mut pack_a, lhs, k, ic, mcw, pc, kcw);
                    let mut jc = 0;
                    while jc < nw {
                        let ncw = nc.min(nw - jc);
                        pack_rhs(&mut pack_b, rhs, n_total, pc, kcw, j0 + jc, ncw);
                        macro_tile(v, &pack_a, &pack_b, mcw, kcw, ncw, out, nw, ic, jc);
                        jc += nc;
                    }
                    pc += kc;
                }
                ic += mc;
            }
        }
    }
}

/// Pack an mcw x kcw block of lhs (row stride k) contiguously.
fn pack_lhs(
    buf: &mut Vec<f32>,
    lhs: &[f32],
    k: usize,
    ic: usize,
    mcw: usize,
    pc: usize,
    kcw: usize,
) {
    buf.clear();
    for r in 0..mcw {
        buf.extend_from_slice(&lhs[(ic + r) * k + pc..][..kcw]);
    }
}

/// Pack a kcw x ncw block of rhs (row stride n_total) contiguously.
fn pack_rhs(
    buf: &mut Vec<f32>,
    rhs: &[f32],
    n_total: usize,
    pc: usize,
    kcw: usize,
    jc: usize,
    ncw: usize,
) {
    buf.clear();
    for r in 0..kcw {
        buf.extend_from_slice(&rhs[(pc + r) * n_total + jc..][..ncw]);
    }
}

/// Sweep the MR x NR micro-tiles of one packed (mcw x kcw) x (kcw x ncw)
/// block, accumulating into `out` at offset (io, jo), row stride
/// `out_stride`. Full tiles take the variant's micro-kernel; edge tiles
/// always take the scalar tail path (same per-element k order).
fn macro_tile(
    v: &KernelMeta,
    a: &[f32],
    b: &[f32],
    mcw: usize,
    kcw: usize,
    ncw: usize,
    out: &mut [f32],
    out_stride: usize,
    io: usize,
    jo: usize,
) {
    let (mr, nr) = (v.tiling.mr, v.tiling.nr);
    let mut ir = 0;
    while ir < mcw {
        let mrw = mr.min(mcw - ir);
        let mut jr = 0;
        while jr < ncw {
            let nrw = nr.min(ncw - jr);
            let a_tile = &a[ir * kcw..];
            let c_off = (io + ir) * out_stride + jo + jr;
            if mrw == mr && nrw == nr && v.micro_kernel == MicroKernel::Unrolled {
                match (mr, nr) {
                    (4, 4) => {
                        micro_unrolled::<4, 4>(kcw, a_tile, b, jr, ncw, out, c_off, out_stride)
                    }
                    (2, 8) => {
                        micro_unrolled::<2, 8>(kcw, a_tile, b, jr, ncw, out, c_off, out_stride)
                    }
                    (8, 8) => {
                        micro_unrolled::<8, 8>(kcw, a_tile, b, jr, ncw, out, c_off, out_stride)
                    }
                    // Tilings outside the committed micro-tile set still
                    // execute correctly through the scalar path.
                    _ => micro_scalar(kcw, a_tile, b, jr, ncw, mrw, nrw, out, c_off, out_stride),
                }
            } else {
                micro_scalar(kcw, a_tile, b, jr, ncw, mrw, nrw, out, c_off, out_stride);
            }
            jr += nr;
        }
        ir += mr;
    }
}

/// Unrolled MR x NR micro-kernel: C-resident accumulators, k ascending in
/// the outer loop, NR independent lanes in the inner loop — the inner loop
/// auto-vectorizes because the lanes are different output elements (no
/// reassociation of any single element's sum).
fn micro_unrolled<const MR: usize, const NR: usize>(
    kcw: usize,
    a: &[f32],
    b: &[f32],
    jr: usize,
    bstride: usize,
    out: &mut [f32],
    c_off: usize,
    cstride: usize,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (i, row) in acc.iter_mut().enumerate() {
        row.copy_from_slice(&out[c_off + i * cstride..][..NR]);
    }
    for kk in 0..kcw {
        let brow = &b[kk * bstride + jr..][..NR];
        for (i, row) in acc.iter_mut().enumerate() {
            let av = a[i * kcw + kk];
            for (x, &bv) in row.iter_mut().zip(brow) {
                *x += av * bv;
            }
        }
    }
    for (i, row) in acc.iter().enumerate() {
        out[c_off + i * cstride..][..NR].copy_from_slice(row);
    }
}

/// Scalar reference micro-kernel (also the tail path for edge tiles): one
/// element at a time, k ascending in a sequential dependency chain the
/// compiler cannot vectorize — the slow end of the inner-kernel axis.
fn micro_scalar(
    kcw: usize,
    a: &[f32],
    b: &[f32],
    jr: usize,
    bstride: usize,
    mrw: usize,
    nrw: usize,
    out: &mut [f32],
    c_off: usize,
    cstride: usize,
) {
    for i in 0..mrw {
        let a_row = &a[i * kcw..][..kcw];
        for j in 0..nrw {
            let mut acc = out[c_off + i * cstride + j];
            for (kk, &av) in a_row.iter().enumerate() {
                acc += av * b[kk * bstride + jr + j];
            }
            out[c_off + i * cstride + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cpu::{cpu_variants, variant_by_index};
    use crate::engine::sim::host_gemm;
    use crate::util::fill_buffer;

    #[test]
    fn every_variant_matches_reference_bitwise_on_mixed_shapes() {
        for shape in [
            GemmShape::new(7, 9, 5, 2),
            GemmShape::new(33, 65, 17, 1),
            GemmShape::new(64, 64, 64, 1),
        ] {
            let lhs = fill_buffer(11, shape.batch * shape.m * shape.k);
            let rhs = fill_buffer(12, shape.batch * shape.k * shape.n);
            let want = host_gemm(&shape, &lhs, &rhs).unwrap();
            for v in cpu_variants() {
                let got = gemm_variant(&v, 3, &shape, &lhs, &rhs).unwrap();
                assert_eq!(got, want, "variant {} diverged on {shape:?}", v.name());
            }
        }
    }

    #[test]
    fn buffer_length_mismatch_rejected() {
        let v = variant_by_index(0).unwrap();
        let shape = GemmShape::new(4, 4, 4, 1);
        assert!(gemm_variant(&v, 1, &shape, &[0.0; 3], &[0.0; 16]).is_err());
        assert!(gemm_variant(&v, 1, &shape, &[0.0; 16], &[0.0; 3]).is_err());
    }

    #[test]
    fn identity_exact_through_a_threaded_variant() {
        // A thread-parallel unrolled variant on an identity lhs must pass
        // rhs through untouched.
        let v = cpu_variants()
            .into_iter()
            .find(|v| v.name().ends_with("_vec_tp"))
            .unwrap();
        let shape = GemmShape::new(8, 8, 8, 1);
        let mut eye = vec![0.0f32; 64];
        for i in 0..8 {
            eye[i * 8 + i] = 1.0;
        }
        let rhs: Vec<f32> = (0..64).map(|x| x as f32 * 0.5 - 7.0).collect();
        let out = gemm_variant(&v, 4, &shape, &eye, &rhs).unwrap();
        assert_eq!(out, rhs);
    }
}
