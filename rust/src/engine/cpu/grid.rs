//! The CPU benchmark grid: measure every variant on regime-labelled GEMM
//! shapes and package the numbers as a [`PerfDataset`], so the existing
//! subset-selection and classifier pipeline trains on *measured* CPU
//! performance exactly the way it trains on devsim datasets.

use std::time::Instant;

use crate::dataset::{GemmShape, PerfDataset, NUM_CONFIGS};
use crate::linalg::Matrix;
use crate::util::fill_buffer;

use super::{cpu_variants, gemm_variant};

/// One benchmark grid cell: a GEMM shape plus the shape regime it
/// represents (`"small"`, `"skinny"` or `"large"`).
#[derive(Clone, Copy, Debug)]
pub struct GridCell {
    /// The GEMM problem measured in this cell.
    pub shape: GemmShape,
    /// Regime label, used by the bench's per-regime spread gates.
    pub regime: &'static str,
}

impl GridCell {
    fn new(m: usize, k: usize, n: usize, b: usize, regime: &'static str) -> GridCell {
        GridCell { shape: GemmShape::new(m, k, n, b), regime }
    }
}

/// The measurement grid. Smoke mode keeps two cells per regime (seconds
/// of wall clock in CI); full mode adds larger and batched cells.
pub fn grid_cells(smoke: bool) -> Vec<GridCell> {
    let mut cells = vec![
        GridCell::new(16, 16, 16, 1, "small"),
        GridCell::new(32, 32, 32, 2, "small"),
        GridCell::new(16, 2048, 16, 1, "skinny"),
        GridCell::new(32, 1024, 24, 1, "skinny"),
        GridCell::new(128, 128, 128, 1, "large"),
        GridCell::new(192, 192, 192, 1, "large"),
    ];
    if !smoke {
        cells.push(GridCell::new(24, 24, 24, 4, "small"));
        cells.push(GridCell::new(48, 48, 48, 1, "small"));
        cells.push(GridCell::new(8, 4096, 32, 1, "skinny"));
        cells.push(GridCell::new(64, 1536, 48, 2, "skinny"));
        cells.push(GridCell::new(256, 256, 256, 1, "large"));
        cells.push(GridCell::new(96, 384, 192, 2, "large"));
    }
    cells
}

/// Measure every CPU variant on every cell and return a [`PerfDataset`]
/// on device `"cpu-native"`: one row per cell, the first
/// [`super::NUM_CPU_VARIANTS`] of the [`NUM_CONFIGS`] columns holding
/// best-of-`reps` measured GFLOP/s (remaining columns stay 0, i.e.
/// unselectable). `threads` is the worker budget handed to the
/// thread-parallel variants.
pub fn collect_dataset(cells: &[GridCell], threads: usize, reps: usize) -> PerfDataset {
    let variants = cpu_variants();
    let mut rows: Vec<Vec<f64>> = vec![vec![0.0; NUM_CONFIGS]; cells.len()];
    for (ci, cell) in cells.iter().enumerate() {
        let s = cell.shape;
        let lhs = fill_buffer(ci as u32 * 7 + 1, s.batch * s.m * s.k);
        let rhs = fill_buffer(ci as u32 * 7 + 2, s.batch * s.k * s.n);
        for v in &variants {
            // Warm caches (and surface any variant bug loudly).
            let _ = gemm_variant(v, threads, &s, &lhs, &rhs).expect("cpu variant executes");
            let mut best = f64::INFINITY;
            for _ in 0..reps.max(1) {
                let start = Instant::now();
                let out = gemm_variant(v, threads, &s, &lhs, &rhs).expect("cpu variant executes");
                let secs = start.elapsed().as_secs_f64().max(1e-9);
                std::hint::black_box(&out);
                best = best.min(secs);
            }
            rows[ci][v.index] = s.flops() / best / 1e9;
        }
    }
    PerfDataset::new(
        "cpu-native",
        cells.iter().map(|c| c.shape).collect(),
        Matrix::from_rows(&rows),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cpu::NUM_CPU_VARIANTS;

    #[test]
    fn grid_covers_every_regime() {
        for smoke in [true, false] {
            let cells = grid_cells(smoke);
            for regime in ["small", "skinny", "large"] {
                assert!(
                    cells.iter().filter(|c| c.regime == regime).count() >= 2,
                    "regime {regime} underrepresented (smoke={smoke})"
                );
            }
        }
    }

    #[test]
    fn collect_dataset_fills_variant_columns() {
        // One tiny cell keeps this fast in debug test runs.
        let cells = vec![GridCell::new(8, 8, 8, 1, "small")];
        let ds = collect_dataset(&cells, 2, 1);
        assert_eq!(ds.n_shapes(), 1);
        for idx in 0..NUM_CPU_VARIANTS {
            assert!(ds.gflops[(0, idx)] > 0.0, "variant {idx} unmeasured");
        }
        assert_eq!(ds.gflops[(0, NUM_CPU_VARIANTS)], 0.0);
        assert_eq!(ds.device, "cpu-native");
    }
}
