//! Deterministic fault injection: a seeded wrapper over any [`Backend`].
//!
//! The fault-tolerance layer (variant quarantine, shard supervision,
//! submit retries) is only trustworthy if its failure modes can be
//! reproduced exactly, so faults here are a pure function of
//! `(plan.seed, shard, execution index)` — same plan, same workload, same
//! faults, every run. Four fault classes, each with an independent
//! permille rate inside the plan's onset window:
//!
//! * **transient** — the execute returns `Err`, the kind of intermittent
//!   failure quarantine's windowed tracker is built for;
//! * **corrupt** — the execute returns `Ok` with a silently wrong first
//!   element, which MUST be caught downstream (the pool's integrity
//!   canary) and never delivered as `Ok`;
//! * **spike** — the execute sleeps before delegating, a latency fault
//!   that perturbs batching and admission without failing anything;
//! * **panic** — one execution panics the worker thread, exercising the
//!   shard supervisor's respawn path.
//!
//! A pool configured without a plan never constructs this wrapper, and a
//! constructed wrapper whose plan has zero rates delegates untouched —
//! bit-identical to the unwrapped backend (asserted in the pool's
//! fault-plan-off identity test).

use crate::dataset::GemmShape;
use crate::runtime::ArtifactMeta;
use crate::util::Rng;

use super::{Backend, BackendStats};

/// A deterministic fault schedule for one pool run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for the per-shard fault stream (forked per shard id).
    pub seed: u64,
    /// Executions on a shard before faults start.
    pub onset: u64,
    /// Executions on a shard after which faults stop (`u64::MAX` =
    /// never); the window is `[onset, fault_until)`.
    pub fault_until: u64,
    /// Per-execution probability (permille) of a transient `Err`.
    pub transient_permille: u32,
    /// Per-execution probability (permille) of silent result corruption.
    pub corrupt_permille: u32,
    /// Per-execution probability (permille) of a latency spike.
    pub spike_permille: u32,
    /// Added latency of one spike, in nanoseconds.
    pub spike_ns: u64,
    /// Execution index (per shard) that panics the worker, if any.
    pub panic_at: Option<u64>,
    /// Restrict rate-based faults to this config index (`None` = every
    /// config). The chaos bench targets the deployed variant so
    /// quarantine — not luck — must restore goodput.
    pub target_config: Option<usize>,
    /// Restrict the whole plan to one shard (`None` = every shard).
    pub target_shard: Option<usize>,
}

impl Default for FaultPlan {
    /// The inert plan: zero rates, no panic, window open forever. A pool
    /// wrapped with it is bit-identical to the unwrapped pool.
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0,
            onset: 0,
            fault_until: u64::MAX,
            transient_permille: 0,
            corrupt_permille: 0,
            spike_permille: 0,
            spike_ns: 0,
            panic_at: None,
            target_config: None,
            target_shard: None,
        }
    }
}

impl FaultPlan {
    /// Parse a `--chaos seed,rate,kinds` flag value: `kinds` is a `+`
    /// separated subset of `transient`, `corrupt`, `spike`, `panic`, and
    /// `rate` (permille) applies to each rate-based kind chosen. The
    /// fault window and panic point are fixed so a smoke run injects
    /// early and leaves room to observe recovery: onset 32, end 160,
    /// panic (if chosen) at execution 48.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let parts: Vec<&str> = s.split(',').collect();
        let [seed, rate, kinds] = parts[..] else {
            return Err(format!("--chaos {s}: expected seed,rate,kinds"));
        };
        let seed: u64 = seed.trim().parse().map_err(|_| format!("--chaos seed: {seed}"))?;
        let rate: u32 = rate.trim().parse().map_err(|_| format!("--chaos rate: {rate}"))?;
        if rate > 1000 {
            return Err(format!("--chaos rate {rate}: permille must be <= 1000"));
        }
        let mut plan = FaultPlan {
            seed,
            onset: 32,
            fault_until: 160,
            spike_ns: 2_000_000,
            ..FaultPlan::default()
        };
        for kind in kinds.split('+') {
            match kind.trim() {
                "transient" => plan.transient_permille = rate,
                "corrupt" => plan.corrupt_permille = rate,
                "spike" => plan.spike_permille = rate,
                "panic" => plan.panic_at = Some(48),
                other => {
                    return Err(format!(
                        "--chaos kind {other:?}: expected transient|corrupt|spike|panic"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Does this plan apply to `shard` at all?
    pub fn applies_to_shard(&self, shard: usize) -> bool {
        self.target_shard.map_or(true, |s| s == shard)
    }

    /// True when the plan can never perturb an execution — the wrapper
    /// is skipped entirely for such plans.
    pub fn is_inert(&self) -> bool {
        self.transient_permille == 0
            && self.corrupt_permille == 0
            && self.spike_permille == 0
            && self.panic_at.is_none()
    }
}

/// One injected fault decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fault {
    None,
    Transient,
    Corrupt,
    Spike,
}

/// A seeded, deterministic fault-injecting wrapper over any [`Backend`].
///
/// Construct it on the shard thread with the shard's fork of the plan's
/// seed; the fault sequence is then a pure function of the execution
/// index, independent of wall clock and scheduling.
pub struct FaultyBackend {
    inner: Box<dyn Backend>,
    plan: FaultPlan,
    rng: Rng,
    executions: u64,
}

impl FaultyBackend {
    /// Wrap `inner` under `plan` for `shard`. The RNG stream is forked
    /// per shard so two shards under one plan draw independent faults.
    pub fn new(inner: Box<dyn Backend>, plan: FaultPlan, shard: usize) -> FaultyBackend {
        let rng = Rng::new(plan.seed).fork(shard as u64);
        FaultyBackend { inner, plan, rng, executions: 0 }
    }

    /// The fault decision for the next execution of `meta`. Advances the
    /// execution counter always; advances the RNG only inside the fault
    /// window for targeted configs, so untargeted traffic replays
    /// identically whether or not the plan is active.
    fn fault_for(&mut self, meta: &ArtifactMeta) -> Fault {
        let n = self.executions;
        self.executions += 1;
        if self.plan.panic_at == Some(n) {
            panic!("injected worker panic (FaultPlan seed {}, execution {n})", self.plan.seed);
        }
        if n < self.plan.onset || n >= self.plan.fault_until {
            return Fault::None;
        }
        if let Some(target) = self.plan.target_config {
            if meta.config_index != Some(target) {
                return Fault::None;
            }
        }
        // Fixed draw order (transient, corrupt, spike) keeps the stream
        // aligned across runs that vary only one rate.
        if self.plan.transient_permille > 0
            && self.rng.below(1000) < self.plan.transient_permille as usize
        {
            return Fault::Transient;
        }
        if self.plan.corrupt_permille > 0
            && self.rng.below(1000) < self.plan.corrupt_permille as usize
        {
            return Fault::Corrupt;
        }
        if self.plan.spike_permille > 0
            && self.rng.below(1000) < self.plan.spike_permille as usize
        {
            return Fault::Spike;
        }
        Fault::None
    }

    fn apply<T>(
        fault: Fault,
        spike_ns: u64,
        run: impl FnOnce() -> Result<T, String>,
        corrupt: impl FnOnce(&mut T),
    ) -> Result<T, String> {
        match fault {
            Fault::Transient => Err("injected transient execute fault".to_string()),
            Fault::Corrupt => {
                let mut out = run()?;
                corrupt(&mut out);
                Ok(out)
            }
            Fault::Spike => {
                std::thread::sleep(std::time::Duration::from_nanos(spike_ns));
                run()
            }
            Fault::None => run(),
        }
    }
}

impl Backend for FaultyBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn prepare(&mut self, meta: &ArtifactMeta) -> Result<(), String> {
        self.inner.prepare(meta)
    }

    fn execute(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<Vec<f32>, String> {
        let fault = self.fault_for(meta);
        let inner = &mut self.inner;
        FaultyBackend::apply(
            fault,
            self.plan.spike_ns,
            || inner.execute(meta, shape, lhs, rhs),
            |out| {
                if let Some(x) = out.first_mut() {
                    *x += 1.0;
                }
            },
        )
    }

    fn execute_timed(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<(Vec<f32>, f64), String> {
        let fault = self.fault_for(meta);
        let inner = &mut self.inner;
        FaultyBackend::apply(
            fault,
            self.plan.spike_ns,
            || inner.execute_timed(meta, shape, lhs, rhs),
            |(out, _)| {
                if let Some(x) = out.first_mut() {
                    *x += 1.0;
                }
            },
        )
    }

    fn execute_timed_for(
        &mut self,
        meta: &ArtifactMeta,
        shape: &GemmShape,
        lhs: &[f32],
        rhs: &[f32],
        device: Option<&'static str>,
    ) -> Result<(Vec<f32>, f64), String> {
        let fault = self.fault_for(meta);
        let inner = &mut self.inner;
        FaultyBackend::apply(
            fault,
            self.plan.spike_ns,
            || inner.execute_timed_for(meta, shape, lhs, rhs, device),
            |(out, _)| {
                if let Some(x) = out.first_mut() {
                    *x += 1.0;
                }
            },
        )
    }

    fn stats(&self) -> BackendStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineKind;
    use crate::runtime::Manifest;
    use std::path::Path;

    fn sim() -> Box<dyn Backend> {
        EngineKind::default().create(Path::new("/nonexistent")).unwrap()
    }

    /// The synthetic XLA-comparator artifact for `shape`, plus filled
    /// input buffers.
    fn fixture(shape: GemmShape) -> (ArtifactMeta, Vec<f32>, Vec<f32>) {
        let manifest = Manifest::synthetic();
        let meta = manifest
            .find_matmul(None, shape.m, shape.k, shape.n, shape.batch)
            .expect("synthetic shape")
            .clone();
        let lhs: Vec<f32> = (0..shape.batch * shape.m * shape.k)
            .map(|i| (i % 7) as f32 * 0.5)
            .collect();
        let rhs: Vec<f32> = (0..shape.batch * shape.k * shape.n)
            .map(|i| (i % 5) as f32 * 0.25)
            .collect();
        (meta, lhs, rhs)
    }

    #[test]
    fn parse_accepts_combined_kinds() {
        let plan = FaultPlan::parse("7,500,transient+corrupt").unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.transient_permille, 500);
        assert_eq!(plan.corrupt_permille, 500);
        assert_eq!(plan.spike_permille, 0);
        assert_eq!(plan.panic_at, None);
        assert_eq!(plan.onset, 32);
        assert!(!plan.is_inert());

        let plan = FaultPlan::parse("1,0,panic").unwrap();
        assert_eq!(plan.panic_at, Some(48));
        assert!(!plan.is_inert());
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(FaultPlan::parse("7,500").is_err());
        assert!(FaultPlan::parse("x,500,transient").is_err());
        assert!(FaultPlan::parse("7,1001,transient").is_err());
        assert!(FaultPlan::parse("7,500,meteor").is_err());
    }

    #[test]
    fn default_plan_is_inert_and_shard_untargeted() {
        let plan = FaultPlan::default();
        assert!(plan.is_inert());
        assert!(plan.applies_to_shard(0));
        assert!(plan.applies_to_shard(17));
        let targeted = FaultPlan { target_shard: Some(1), ..plan };
        assert!(!targeted.applies_to_shard(0));
        assert!(targeted.applies_to_shard(1));
    }

    #[test]
    fn inert_plan_is_bit_identical_to_unwrapped() {
        let shape = GemmShape::new(32, 32, 32, 1);
        let (meta, lhs, rhs) = fixture(shape);
        let mut plain = sim();
        let mut wrapped = FaultyBackend::new(sim(), FaultPlan::default(), 0);
        plain.prepare(&meta).unwrap();
        wrapped.prepare(&meta).unwrap();
        for _ in 0..64 {
            let a = plain.execute(&meta, &shape, &lhs, &rhs).unwrap();
            let b = wrapped.execute(&meta, &shape, &lhs, &rhs).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn faults_are_deterministic_and_windowed() {
        let plan = FaultPlan {
            seed: 42,
            onset: 4,
            fault_until: 20,
            transient_permille: 400,
            ..FaultPlan::default()
        };
        let shape = GemmShape::new(32, 32, 32, 1);
        let (meta, lhs, rhs) = fixture(shape);
        let run = |shard: usize| -> Vec<bool> {
            let mut b = FaultyBackend::new(sim(), plan, shard);
            b.prepare(&meta).unwrap();
            (0..32).map(|_| b.execute(&meta, &shape, &lhs, &rhs).is_ok()).collect()
        };
        let a = run(0);
        assert_eq!(a, run(0), "same seed+shard must replay identically");
        // Outside the window nothing fails.
        assert!(a[..4].iter().all(|&ok| ok));
        assert!(a[20..].iter().all(|&ok| ok));
        // Inside it, at 400 permille over 16 draws, some do.
        assert!(a[4..20].iter().any(|&ok| !ok));
        // Another shard draws an independent stream.
        assert_ne!(a, run(1), "shard fork must decorrelate fault streams");
    }

    #[test]
    fn corruption_perturbs_first_element_only() {
        let plan = FaultPlan {
            seed: 9,
            corrupt_permille: 1000,
            ..FaultPlan::default()
        };
        let shape = GemmShape::new(32, 32, 32, 1);
        let (meta, lhs, rhs) = fixture(shape);
        let mut plain = sim();
        plain.prepare(&meta).unwrap();
        let truth = plain.execute(&meta, &shape, &lhs, &rhs).unwrap();
        let mut b = FaultyBackend::new(sim(), plan, 0);
        b.prepare(&meta).unwrap();
        let out = b.execute(&meta, &shape, &lhs, &rhs).unwrap();
        assert_ne!(out[0], truth[0], "corruption must flip the canary element");
        assert_eq!(out[1..], truth[1..], "corruption must be silent elsewhere");
    }

    #[test]
    fn untargeted_config_is_never_faulted() {
        let plan = FaultPlan {
            seed: 3,
            transient_permille: 1000,
            corrupt_permille: 1000,
            target_config: Some(0),
            ..FaultPlan::default()
        };
        let shape = GemmShape::new(32, 32, 32, 1);
        // The XLA comparator has config_index None != Some(0): untouched.
        let (meta, lhs, rhs) = fixture(shape);
        let mut plain = sim();
        plain.prepare(&meta).unwrap();
        let truth = plain.execute(&meta, &shape, &lhs, &rhs).unwrap();
        let mut b = FaultyBackend::new(sim(), plan, 0);
        b.prepare(&meta).unwrap();
        for _ in 0..16 {
            assert_eq!(b.execute(&meta, &shape, &lhs, &rhs).unwrap(), truth);
        }
    }

    #[test]
    #[should_panic(expected = "injected worker panic")]
    fn panic_at_fires_on_exact_execution() {
        let plan = FaultPlan { panic_at: Some(2), ..FaultPlan::default() };
        let shape = GemmShape::new(32, 32, 32, 1);
        let (meta, lhs, rhs) = fixture(shape);
        let mut b = FaultyBackend::new(sim(), plan, 0);
        b.prepare(&meta).unwrap();
        for _ in 0..3 {
            let _ = b.execute(&meta, &shape, &lhs, &rhs);
        }
    }
}
