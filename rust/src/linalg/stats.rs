//! Summary statistics used across the selection/evaluation pipeline.

/// Arithmetic mean. Panics on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "mean of empty slice");
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    let mu = mean(xs);
    xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Geometric mean — the paper's aggregate for relative performance (§4.3).
/// Zero entries are clamped to `eps` so a single unusable kernel does not
/// annihilate the aggregate.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let eps = 1e-9;
    let log_sum: f64 = xs.iter().map(|&x| x.max(eps).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Index of the maximum value (first on ties). Panics on empty input.
pub fn argmax(xs: &[f64]) -> usize {
    assert!(!xs.is_empty(), "argmax of empty slice");
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Index of the minimum value (first on ties). Panics on empty input.
pub fn argmin(xs: &[f64]) -> usize {
    assert!(!xs.is_empty(), "argmin of empty slice");
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x < xs[best] {
            best = i;
        }
    }
    best
}

/// Max value. Panics on empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs[argmax(xs)]
}

/// Min value. Panics on empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs[argmin(xs)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert_eq!(variance(&xs), 4.0);
        assert_eq!(std_dev(&xs), 2.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        // Zero clamps instead of annihilating.
        assert!(geomean(&[0.0, 1.0]) > 0.0);
    }

    #[test]
    fn arg_extrema() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0];
        assert_eq!(argmax(&xs), 5);
        assert_eq!(argmin(&xs), 1);
        assert_eq!(max(&xs), 9.0);
        assert_eq!(min(&xs), 1.0);
        // First on ties.
        assert_eq!(argmax(&[1.0, 2.0, 2.0]), 1);
    }
}
