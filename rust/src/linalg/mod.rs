//! Dense linear algebra substrate for the ML modules.
//!
//! A deliberately small, well-tested core: row-major `Matrix`, the handful
//! of BLAS-1/2/3 operations the clustering and classification methods need,
//! a symmetric eigensolver (cyclic Jacobi) powering PCA and spectral
//! clustering, and summary statistics.

pub mod eigen;
pub mod stats;

pub use eigen::{eigh, Eigh};

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Elements in row-major order; `data[r * cols + c]` is `(r, c)`.
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a slice of equal-length rows. Panics on empty or
    /// ragged input.
    pub fn from_rows(rows_in: &[Vec<f64>]) -> Matrix {
        assert!(!rows_in.is_empty(), "Matrix::from_rows on empty input");
        let cols = rows_in[0].len();
        let mut data = Vec::with_capacity(rows_in.len() * cols);
        for r in rows_in {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Matrix { rows: rows_in.len(), cols, data }
    }

    /// Wrap an existing row-major buffer. Panics unless
    /// `data.len() == rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// The n x n identity matrix.
    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Row `r` as a contiguous slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable contiguous slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c`, copied out (columns are strided in row-major storage).
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// The transposed matrix (c x r), copied.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// self (r x k) * other (k x c) -> (r x c). Cache-friendly ikj loop.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// self (r x c) * v (c) -> (r).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|r| dot(self.row(r), v))
            .collect()
    }

    /// Mean of each column.
    pub fn col_means(&self) -> Vec<f64> {
        let mut means = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (m, &x) in means.iter_mut().zip(self.row(r)) {
                *m += x;
            }
        }
        for m in &mut means {
            *m /= self.rows as f64;
        }
        means
    }

    /// Subtract `mu` from every row (in place).
    pub fn center_rows(&mut self, mu: &[f64]) {
        assert_eq!(mu.len(), self.cols);
        for r in 0..self.rows {
            for (x, &m) in self.row_mut(r).iter_mut().zip(mu) {
                *x -= m;
            }
        }
    }

    /// Covariance of the rows (columns are variables): (Xc^T Xc) / (n-1).
    pub fn covariance(&self) -> Matrix {
        let mu = self.col_means();
        let mut centered = self.clone();
        centered.center_rows(&mu);
        let xt = centered.transpose();
        let mut cov = xt.matmul(&centered);
        let denom = (self.rows.max(2) - 1) as f64;
        for v in &mut cov.data {
            *v /= denom;
        }
        cov
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean (L2) norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance (no square root — the form clustering
/// inner loops want).
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Euclidean distance between two points.
#[inline]
pub fn euclidean(a: &[f64], b: &[f64]) -> f64 {
    sq_dist(a, b).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let b = Matrix::from_rows(&[vec![7.0, 8.0], vec![9.0, 10.0], vec![11.0, 12.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(2, 1)], 6.0);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let v = vec![5.0, 6.0];
        assert_eq!(a.matvec(&v), vec![17.0, 39.0]);
    }

    #[test]
    fn col_means_and_center() {
        let mut a = Matrix::from_rows(&[vec![1.0, 10.0], vec![3.0, 20.0]]);
        let mu = a.col_means();
        assert_eq!(mu, vec![2.0, 15.0]);
        a.center_rows(&mu);
        assert_eq!(a.col_means(), vec![0.0, 0.0]);
    }

    #[test]
    fn covariance_known() {
        // Two perfectly correlated columns.
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0],
            vec![2.0, 4.0],
            vec![3.0, 6.0],
        ]);
        let c = a.covariance();
        assert!((c[(0, 0)] - 1.0).abs() < 1e-12);
        assert!((c[(0, 1)] - 2.0).abs() < 1e-12);
        assert!((c[(1, 1)] - 4.0).abs() < 1e-12);
        // Symmetric.
        assert_eq!(c[(0, 1)], c[(1, 0)]);
    }

    #[test]
    fn distances() {
        let a = [0.0, 3.0];
        let b = [4.0, 0.0];
        assert_eq!(sq_dist(&a, &b), 25.0);
        assert_eq!(euclidean(&a, &b), 5.0);
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
    }
}
