//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Powers PCA (covariance matrices) and spectral clustering (graph
//! Laplacians). Jacobi is O(n^3) per sweep but unconditionally stable and
//! more than fast enough for the ≤ 640-dimensional problems here.

use super::Matrix;

/// Eigendecomposition result: `values[i]` corresponds to the column
/// `vectors[.., i]`; sorted by descending eigenvalue.
#[derive(Clone, Debug)]
pub struct Eigh {
    /// Eigenvalues in descending order.
    pub values: Vec<f64>,
    /// Column-eigenvector matrix: vectors[(r, i)] is component r of
    /// eigenvector i.
    pub vectors: Matrix,
}

/// Jacobi eigendecomposition of a symmetric matrix.
///
/// Panics if the matrix is not square; asymmetry is tolerated up to
/// round-off (the algorithm uses only the upper triangle).
pub fn eigh(m: &Matrix) -> Eigh {
    assert_eq!(m.rows, m.cols, "eigh requires a square matrix");
    let n = m.rows;
    if n == 0 {
        return Eigh { values: vec![], vectors: Matrix::zeros(0, 0) };
    }
    let mut a = m.clone();
    let mut v = Matrix::identity(n);

    let max_sweeps = 100;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // tan of the rotation angle, stable formula.
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // A <- J^T A J applied to rows/cols p and q.
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Sort by descending eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| a[(j, j)].partial_cmp(&a[(i, i)]).unwrap());
    let values: Vec<f64> = order.iter().map(|&i| a[(i, i)]).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            vectors[(r, new_c)] = v[(r, old_c)];
        }
    }
    Eigh { values, vectors }
}

/// The `k` eigenvectors with the *smallest* eigenvalues (for Laplacians),
/// as rows of points: returns (n x k) embedding matrix.
pub fn smallest_eigvec_embedding(m: &Matrix, k: usize) -> Matrix {
    let e = eigh(m);
    let n = m.rows;
    let k = k.min(n);
    let mut out = Matrix::zeros(n, k);
    for j in 0..k {
        let col = n - 1 - j; // ascending from the tail of the descending sort
        for r in 0..n {
            out[(r, j)] = e.vectors[(r, col)];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    fn reconstruct(e: &Eigh) -> Matrix {
        // V diag(w) V^T
        let n = e.values.len();
        let mut vd = e.vectors.clone();
        for c in 0..n {
            for r in 0..n {
                vd[(r, c)] *= e.values[c];
            }
        }
        vd.matmul(&e.vectors.transpose())
    }

    #[test]
    fn diagonal_matrix() {
        let m = Matrix::from_rows(&[
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ]);
        let e = eigh(&m);
        assert_eq!(e.values, vec![3.0, 2.0, 1.0]);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let m = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = eigh(&m);
        assert!((e.values[0] - 3.0).abs() < 1e-10);
        assert!((e.values[1] - 1.0).abs() < 1e-10);
        // Eigenvector for 3 is (1,1)/sqrt(2) up to sign.
        let v0 = e.vectors.col(0);
        assert!((v0[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
        assert!((v0[0] - v0[1]).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_and_orthonormality() {
        // Pseudo-random symmetric matrix.
        let n = 12;
        let mut m = Matrix::zeros(n, n);
        let mut rng = crate::util::Rng::new(3);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        let e = eigh(&m);
        let rec = reconstruct(&e);
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (rec[(i, j)] - m[(i, j)]).abs() < 1e-8,
                    "reconstruction mismatch at ({i},{j})"
                );
            }
        }
        // Columns orthonormal.
        for a in 0..n {
            for b in 0..n {
                let d = dot(&e.vectors.col(a), &e.vectors.col(b));
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-9, "V^T V [{a},{b}] = {d}");
            }
        }
        // Values descending.
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn psd_covariance_nonnegative() {
        let x = Matrix::from_rows(&[
            vec![1.0, 2.0, 0.5],
            vec![2.0, 3.9, 1.1],
            vec![3.0, 6.1, 1.4],
            vec![4.0, 8.0, 2.2],
        ]);
        let e = eigh(&x.covariance());
        for &w in &e.values {
            assert!(w > -1e-10, "negative eigenvalue {w} for PSD matrix");
        }
    }

    #[test]
    fn smallest_embedding_orientation() {
        // Block-diagonal Laplacian of two disconnected edges: the two
        // smallest eigenvalues are 0, eigenvectors constant per component.
        let m = Matrix::from_rows(&[
            vec![1.0, -1.0, 0.0, 0.0],
            vec![-1.0, 1.0, 0.0, 0.0],
            vec![0.0, 0.0, 1.0, -1.0],
            vec![0.0, 0.0, -1.0, 1.0],
        ]);
        let emb = smallest_eigvec_embedding(&m, 2);
        assert_eq!(emb.rows, 4);
        assert_eq!(emb.cols, 2);
        // Rows 0,1 identical and rows 2,3 identical in the 2-dim embedding.
        for c in 0..2 {
            assert!((emb[(0, c)] - emb[(1, c)]).abs() < 1e-8);
            assert!((emb[(2, c)] - emb[(3, c)]).abs() < 1e-8);
        }
    }
}
