//! Analytical device-performance simulator (DESIGN.md §3).
//!
//! The paper benchmarks 640 kernel configurations x ~300 GEMM shapes on real
//! OpenCL devices we do not have. The ML selection/classification pipeline
//! only consumes the resulting (shape x config) GFLOP/s matrix, so we
//! substitute a roofline-style analytical model that reproduces the
//! *structure* the paper reports:
//!
//!   * a compute roofline scaled by per-work-item ILP, arithmetic intensity,
//!     register-spill and vectorization efficiencies (micro-tile R/A/C),
//!   * a memory roofline from classic tiled-GEMM traffic (block reuse),
//!     with cache acceleration for small working sets,
//!   * a parallelism term that starves wide GPUs on tall-skinny shapes
//!     (the paper's pathological class) but saturates CPUs quickly,
//!   * work-group-granularity tail effects and edge-padding waste,
//!   * seeded multiplicative noise.
//!
//! Absolute numbers are calibrated per device profile to the paper's
//! landmarks (e.g. ~3160 GFLOP/s best / 13 GFLOP/s worst on the R9 Nano);
//! what matters downstream is who wins where, and by how much.

pub mod profiles;

pub use profiles::{all_profiles, profile_by_name, DeviceProfile};

use crate::dataset::{GemmShape, KernelConfig, PerfDataset, NUM_CONFIGS};
use crate::linalg::Matrix;
use crate::util::Rng;

/// Simulate the GFLOP/s a kernel configuration achieves on one GEMM shape.
pub fn simulate(profile: &DeviceProfile, shape: &GemmShape, cfg: &KernelConfig) -> f64 {
    let (m, k, n, b) = (
        shape.m as f64,
        shape.k as f64,
        shape.n as f64,
        shape.batch as f64,
    );
    let (r, a, c) = (cfg.acc_r as f64, cfg.acc_a as f64, cfg.acc_c as f64);
    let (wr, wc) = (cfg.wg_r as f64, cfg.wg_c as f64);

    // --- Work decomposition -------------------------------------------------
    let tiles_m = (m / r).ceil();
    let tiles_n = (n / c).ceil();
    let threads = b * tiles_m * tiles_n;
    let wgs_m = (tiles_m / wr).ceil();
    let wgs_n = (tiles_n / wc).ceil();
    let wgs = b * wgs_m * wgs_n;

    // Edge padding: full work-groups are executed even on ragged edges.
    let padded_m = wgs_m * wr * r;
    let padded_n = wgs_n * wc * c;
    let useful_flops = 2.0 * b * m * k * n;
    let padded_flops = 2.0 * b * padded_m * k * padded_n;

    // --- Per-work-item compute efficiency -----------------------------------
    // Registers: accumulator R*C + double-buffered A-deep loads.
    let regs = r * c + 2.0 * r * a + 2.0 * a * c + 8.0;
    let spill = if regs <= profile.regs_per_thread {
        1.0
    } else {
        (profile.regs_per_thread / regs).powf(profile.spill_exponent)
    };
    // Independent accumulators hide FMA latency.
    let ilp = (r * c / profile.ilp_for_peak).min(1.0).powf(0.5);
    // Flops per operand element touched in registers: R*C/(R+C).
    let intensity = r * c / (r + c);
    let intensity_eff = intensity / (intensity + profile.intensity_half);
    // Vector loads: the tile dims are the load widths (paper §3).
    let vec_eff = profile.vector_eff(a, c);

    let compute_rate =
        profile.peak_gflops * 1e9 * ilp * intensity_eff * spill * vec_eff;

    // --- Parallelism ---------------------------------------------------------
    let hw_threads = profile.compute_units * profile.threads_for_peak;
    let par = (threads / hw_threads).min(1.0);
    // Work-group scheduling tail: the last wave of WGs underfills the CUs.
    let waves = (wgs / profile.compute_units).ceil();
    let tail = (wgs / (waves * profile.compute_units)).clamp(0.05, 1.0);
    // Very large work-groups reduce scheduling flexibility slightly.
    let wg_fit = profile.wg_shape_eff(wr, wc);

    let rate = compute_rate * par * tail.powf(0.5) * wg_fit;

    let t_compute = padded_flops / rate.max(1.0);

    // --- Memory --------------------------------------------------------------
    // Classic tiled-GEMM traffic: each (block_m x k) strip of lhs is read
    // once per n-block and vice versa, plus the output write.
    let blocks_m = wgs_m;
    let blocks_n = wgs_n;
    let bytes = 4.0
        * b
        * (padded_m * k * blocks_n + k * padded_n * blocks_m + m * n);
    let working_set = 4.0 * b * (m * k + k * n + m * n);
    let bw = if working_set <= profile.cache_kb * 1024.0 {
        profile.cache_bw_gbs
    } else {
        profile.mem_bw_gbs
    } * 1e9;
    let bw_eff = profile.coalesce_eff(wr, wc, a, c);
    // Cache blocking: one work-group streams (block_m x k) + (k x block_n)
    // strips; when those overflow the per-CU cache slice, reuse degrades.
    // This couples work-group shape with the reduction depth, so different
    // shapes favour different work-groups (strongest on cache-heavy CPUs).
    let block_ws = 4.0 * (wr * r * k + k * wc * c);
    let cache_per_cu = profile.cache_kb * 1024.0 / profile.compute_units;
    let cache_eff = if block_ws <= cache_per_cu {
        1.0
    } else {
        (cache_per_cu / block_ws).powf(profile.cache_pressure)
    };
    let t_mem = bytes / (bw * bw_eff * cache_eff);

    // --- Overheads -----------------------------------------------------------
    let t_overhead = profile.kernel_launch_us * 1e-6
        + (wgs / profile.compute_units) * profile.wg_overhead_us * 1e-6;

    let t = t_compute.max(t_mem) + t_overhead;
    let mut gflops = useful_flops / t / 1e9;

    // --- Seeded noise ---------------------------------------------------------
    let seed = noise_seed(profile.name, shape, cfg);
    let eps = Rng::new(seed).normal();
    gflops *= (profile.noise_sigma * eps).exp();
    gflops.max(0.05)
}

fn noise_seed(device: &str, shape: &GemmShape, cfg: &KernelConfig) -> u64 {
    // FNV-1a over the identifying tuple.
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    };
    for byte in device.bytes() {
        eat(byte as u64);
    }
    for v in [shape.m, shape.k, shape.n, shape.batch, cfg.index()] {
        eat(v as u64);
    }
    h
}

/// Generate the full benchmark dataset for a device profile.
pub fn generate_dataset(profile: &DeviceProfile, shapes: &[GemmShape]) -> PerfDataset {
    let configs = crate::dataset::all_configs();
    let mut gflops = Matrix::zeros(shapes.len(), NUM_CONFIGS);
    for (si, shape) in shapes.iter().enumerate() {
        for (ci, cfg) in configs.iter().enumerate() {
            gflops[(si, ci)] = simulate(profile, shape, cfg);
        }
    }
    PerfDataset::new(profile.name, shapes.to_vec(), gflops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{benchmark_shapes, config_by_name};

    fn nano() -> &'static DeviceProfile {
        profile_by_name("r9-nano").unwrap()
    }

    fn cpu() -> &'static DeviceProfile {
        profile_by_name("i7-6700k").unwrap()
    }

    #[test]
    fn deterministic() {
        let s = GemmShape::new(512, 784, 512, 16);
        let cfg = config_by_name("r8a4c4_wg16x16").unwrap();
        assert_eq!(simulate(nano(), &s, &cfg), simulate(nano(), &s, &cfg));
    }

    #[test]
    fn square_beats_tall_skinny_on_gpu() {
        let square = GemmShape::new(512, 784, 512, 16);
        let skinny = GemmShape::new(32, 12321, 27, 1);
        let cfg = config_by_name("r8a4c4_wg16x16").unwrap();
        let gs = simulate(nano(), &square, &cfg);
        let gk = simulate(nano(), &skinny, &cfg);
        assert!(
            gs > 10.0 * gk,
            "square {gs:.0} vs skinny {gk:.0} GFLOP/s"
        );
    }

    #[test]
    fn gpu_landmarks_roughly_match_paper() {
        // Paper §3.2: best (8,4,4)@(16,16) on (512,784,512,16) ~ 3160
        // GFLOP/s; worst (1,8,1)@(8,8) on (32,12321,27,1) ~ 13 GFLOP/s.
        let best = simulate(
            nano(),
            &GemmShape::new(512, 784, 512, 16),
            &config_by_name("r8a4c4_wg16x16").unwrap(),
        );
        let worst = simulate(
            nano(),
            &GemmShape::new(32, 12321, 27, 1),
            &config_by_name("r1a8c1_wg8x8").unwrap(),
        );
        assert!(
            (1500.0..=5000.0).contains(&best),
            "best-case landmark {best:.0} GFLOP/s"
        );
        assert!((2.0..=80.0).contains(&worst), "worst-case landmark {worst:.0}");
        assert!(best / worst > 50.0, "dynamic range {}", best / worst);
    }

    #[test]
    fn large_tiles_win_on_big_square_small_tiles_lose() {
        let s = GemmShape::new(512, 784, 512, 16);
        let big = simulate(nano(), &s, &config_by_name("r8a4c4_wg16x16").unwrap());
        let small = simulate(nano(), &s, &config_by_name("r1a1c1_wg8x8").unwrap());
        assert!(big > 2.0 * small, "big {big:.0} vs small {small:.0}");
    }

    #[test]
    fn cpu_more_consistent_than_gpu() {
        // Relative std of the best-config perf across shapes must be lower
        // on the CPU (paper §4.3: "this device was more consistent").
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(13).collect();
        let spread = |p: &DeviceProfile| {
            let ds = generate_dataset(p, &shapes);
            let best: Vec<f64> =
                (0..ds.n_shapes()).map(|i| ds.best_gflops(i) / p.peak_gflops).collect();
            crate::linalg::stats::std_dev(&best) / crate::linalg::stats::mean(&best)
        };
        let gpu_spread = spread(nano());
        let cpu_spread = spread(cpu());
        assert!(
            cpu_spread < gpu_spread,
            "cpu {cpu_spread:.3} vs gpu {gpu_spread:.3}"
        );
    }

    #[test]
    fn winner_diversity_long_tail() {
        // Figure 2's long tail: many configs win at least one shape.
        let shapes = benchmark_shapes();
        let ds = generate_dataset(nano(), &shapes);
        let counts = ds.winner_counts();
        let winners = counts.iter().filter(|&&c| c > 0).count();
        assert!(
            winners >= 20,
            "only {winners} distinct winning configs — no long tail"
        );
        let top = counts.iter().max().unwrap();
        assert!(*top >= 5, "top winner too weak: {top}");
    }

    #[test]
    fn all_profiles_produce_sane_numbers() {
        let s = GemmShape::new(256, 256, 256, 4);
        for p in all_profiles() {
            for cfg_name in ["r1a1c1_wg8x8", "r4a4c4_wg8x16", "r8a8c8_wg16x16"] {
                let g = simulate(p, &s, &config_by_name(cfg_name).unwrap());
                assert!(
                    g > 0.0 && g < p.peak_gflops,
                    "{}/{cfg_name}: {g} GFLOP/s vs peak {}",
                    p.name,
                    p.peak_gflops
                );
            }
        }
    }
}
