//! Device profiles for the analytical simulator: the two benchmark devices
//! of paper §3.1 plus the two extra §6 deployment targets.
//!
//! Numbers are public datasheet figures where available (peak GFLOP/s,
//! bandwidth, compute units); the efficiency knobs (ILP, intensity_half,
//! register budget, overheads) are calibrated so the simulated datasets hit
//! the paper's qualitative landmarks (see devsim::tests).

/// Broad device class; switches which efficiency heuristics apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceKind {
    /// Dedicated-memory GPU (paper's R9 Nano class).
    DiscreteGpu,
    /// Host CPU running SIMD kernels (paper's i7-6700K class).
    Cpu,
    /// GPU sharing system memory with the host (HD 530 class).
    IntegratedGpu,
    /// Power-constrained mobile GPU (Mali G71 class).
    MobileGpu,
}

/// One simulated device: datasheet figures plus calibrated efficiency
/// knobs consumed by the analytical cost model in [`crate::devsim`].
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    /// Stable profile name (`--profile` flag, dataset device label).
    pub name: &'static str,
    /// Device class; selects the per-kind efficiency heuristics.
    pub kind: DeviceKind,
    /// Parallel compute units (CUs / cores / EUs).
    pub compute_units: f64,
    /// Peak f32 throughput in GFLOP/s.
    pub peak_gflops: f64,
    /// Sustained main-memory bandwidth in GB/s.
    pub mem_bw_gbs: f64,
    /// Effective bandwidth when the whole working set fits in cache.
    pub cache_bw_gbs: f64,
    /// Last-level cache size in KiB.
    pub cache_kb: f64,
    /// Resident work-items per CU needed to hide latency at peak.
    pub threads_for_peak: f64,
    /// Per-work-item register budget before spilling.
    pub regs_per_thread: f64,
    /// Severity of the performance cliff once registers spill.
    pub spill_exponent: f64,
    /// Independent accumulators needed per work-item for full FMA pipe.
    pub ilp_for_peak: f64,
    /// Arithmetic-intensity half-saturation point (R*C/(R+C) units).
    pub intensity_half: f64,
    /// Preferred f32 vector width for loads.
    pub vec_width: f64,
    /// Fixed kernel-launch latency in microseconds.
    pub kernel_launch_us: f64,
    /// Per-work-group scheduling overhead in microseconds.
    pub wg_overhead_us: f64,
    /// Exponent of the cache-overflow bandwidth penalty (0 disables).
    pub cache_pressure: f64,
    /// Lognormal measurement-noise sigma applied to simulated timings.
    pub noise_sigma: f64,
}

impl DeviceProfile {
    /// Efficiency of the (A, C)-wide vector loads against the device's
    /// preferred width. GPUs prefer narrow-to-medium vectors (coalescing
    /// does the widening); CPUs want the full SIMD width.
    pub fn vector_eff(&self, a: f64, c: f64) -> f64 {
        let pref = self.vec_width;
        let one = |w: f64| -> f64 {
            if w <= pref {
                // Under-wide: partially filled vector units.
                (0.55 + 0.45 * (w / pref)).min(1.0)
            } else {
                // Over-wide: split loads, slight penalty.
                1.0 - 0.08 * (w / pref - 1.0)
            }
        };
        (one(a) * one(c)).clamp(0.2, 1.0)
    }

    /// Work-group shape efficiency: degenerate 1-wide groups lose the
    /// cooperative-reuse advantage on GPUs; CPUs barely care.
    pub fn wg_shape_eff(&self, wr: f64, wc: f64) -> f64 {
        match self.kind {
            DeviceKind::Cpu => 1.0 - 0.02 * ((wr * wc) / 256.0),
            _ => {
                let aspect = (wr / wc).max(wc / wr); // 1 for square, 128 worst
                (1.0 - 0.035 * aspect.log2()).clamp(0.6, 1.0)
            }
        }
    }

    /// Memory-coalescing efficiency of the work-group's collective loads.
    /// GPU: threads along the wg row load consecutive rhs columns — wider
    /// rows coalesce better; the per-thread C-wide vector also helps.
    /// CPU: contiguous A/C-wide vector loads approaching SIMD width win.
    pub fn coalesce_eff(&self, wr: f64, wc: f64, a: f64, c: f64) -> f64 {
        match self.kind {
            DeviceKind::Cpu => {
                let width = (a.max(c) * 4.0) / (self.vec_width * 4.0);
                (0.5 + 0.5 * width.min(1.0)).clamp(0.3, 1.0)
            }
            _ => {
                let row_span = (wc * c).min(64.0) / 64.0; // 64 lanes ~ wavefront
                let col_pen = 1.0 - 0.1 * (wr / (wr + 16.0));
                (0.35 + 0.65 * row_span) * col_pen
            }
        }
    }
}

/// AMD R9 Nano (Fiji): 64 CUs, 8.19 TFLOP/s fp32, 512 GB/s HBM.
const fn r9_nano() -> DeviceProfile {
    DeviceProfile {
        name: "r9-nano",
        kind: DeviceKind::DiscreteGpu,
        compute_units: 64.0,
        peak_gflops: 8192.0,
        mem_bw_gbs: 512.0,
        cache_bw_gbs: 1024.0,
        cache_kb: 2048.0,
        threads_for_peak: 512.0,
        regs_per_thread: 160.0,
        spill_exponent: 1.6,
        ilp_for_peak: 16.0,
        intensity_half: 1.15,
        vec_width: 2.0,
        kernel_launch_us: 8.0,
        wg_overhead_us: 0.10,
        cache_pressure: 0.18,
        noise_sigma: 0.055,
    }
}

/// Intel i7-6700K (Skylake, 4c/8t @ 4.0 GHz, AVX2 FMA): ~512 GFLOP/s fp32,
/// ~34 GB/s DDR4.
const fn i7_6700k() -> DeviceProfile {
    DeviceProfile {
        name: "i7-6700k",
        kind: DeviceKind::Cpu,
        compute_units: 4.0,
        peak_gflops: 512.0,
        mem_bw_gbs: 34.0,
        cache_bw_gbs: 300.0,
        cache_kb: 8192.0,
        threads_for_peak: 16.0,
        regs_per_thread: 224.0,
        spill_exponent: 0.8,
        ilp_for_peak: 8.0,
        intensity_half: 0.7,
        vec_width: 8.0,
        kernel_launch_us: 25.0,
        wg_overhead_us: 0.4,
        cache_pressure: 0.5,
        noise_sigma: 0.06,
    }
}

/// Intel HD Graphics 530 (Gen9, 24 EUs): ~440 GFLOP/s, shared ~34 GB/s.
const fn hd530() -> DeviceProfile {
    DeviceProfile {
        name: "hd530",
        kind: DeviceKind::IntegratedGpu,
        compute_units: 24.0,
        peak_gflops: 441.0,
        mem_bw_gbs: 30.0,
        cache_bw_gbs: 120.0,
        cache_kb: 768.0,
        threads_for_peak: 56.0,
        regs_per_thread: 128.0,
        spill_exponent: 1.4,
        ilp_for_peak: 10.0,
        intensity_half: 1.0,
        vec_width: 4.0,
        kernel_launch_us: 15.0,
        wg_overhead_us: 0.25,
        cache_pressure: 0.3,
        noise_sigma: 0.035,
    }
}

/// ARM Mali G71 (Bifrost, ~8 cores): ~265 GFLOP/s, ~15 GB/s LPDDR4.
const fn mali_g71() -> DeviceProfile {
    DeviceProfile {
        name: "mali-g71",
        kind: DeviceKind::MobileGpu,
        compute_units: 8.0,
        peak_gflops: 265.0,
        mem_bw_gbs: 14.9,
        cache_bw_gbs: 50.0,
        cache_kb: 512.0,
        threads_for_peak: 96.0,
        regs_per_thread: 96.0,
        spill_exponent: 1.8,
        ilp_for_peak: 6.0,
        intensity_half: 0.9,
        vec_width: 4.0,
        kernel_launch_us: 40.0,
        wg_overhead_us: 0.8,
        cache_pressure: 0.35,
        noise_sigma: 0.045,
    }
}

/// The four shipped profiles: the paper's two benchmark devices plus the
/// two §6 deployment targets, in presentation order.
pub fn all_profiles() -> &'static [DeviceProfile] {
    static PROFILES: [DeviceProfile; 4] = [r9_nano(), i7_6700k(), hd530(), mali_g71()];
    &PROFILES
}

/// Look a profile up by its stable [`DeviceProfile::name`].
pub fn profile_by_name(name: &str) -> Option<&'static DeviceProfile> {
    all_profiles().iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_profiles_unique_names() {
        let names: Vec<&str> = all_profiles().iter().map(|p| p.name).collect();
        assert_eq!(names, vec!["r9-nano", "i7-6700k", "hd530", "mali-g71"]);
    }

    #[test]
    fn lookup() {
        assert!(profile_by_name("r9-nano").is_some());
        assert!(profile_by_name("rtx-4090").is_none());
    }

    #[test]
    fn efficiencies_bounded() {
        for p in all_profiles() {
            for a in [1.0, 2.0, 4.0, 8.0] {
                for c in [1.0, 2.0, 4.0, 8.0] {
                    let v = p.vector_eff(a, c);
                    assert!((0.2..=1.0).contains(&v), "{} vec {v}", p.name);
                }
            }
            for (wr, wc) in crate::dataset::config::WORKGROUPS {
                let w = p.wg_shape_eff(wr as f64, wc as f64);
                assert!((0.5..=1.0).contains(&w), "{} wg {w}", p.name);
                let ce = p.coalesce_eff(wr as f64, wc as f64, 4.0, 4.0);
                assert!((0.25..=1.0).contains(&ce), "{} coalesce {ce}", p.name);
            }
        }
    }

    #[test]
    fn cpu_prefers_wide_vectors_gpu_indifferent() {
        let cpu = profile_by_name("i7-6700k").unwrap();
        assert!(cpu.vector_eff(8.0, 8.0) > cpu.vector_eff(1.0, 1.0));
        let gpu = profile_by_name("r9-nano").unwrap();
        // GPU: widening beyond pref must not *improve* things much.
        assert!(gpu.vector_eff(8.0, 8.0) <= gpu.vector_eff(2.0, 2.0) + 0.05);
    }
}
