//! Decision-tree serialization and code generation.
//!
//! The paper's deployment story (§5.1): a decision tree "can be implemented
//! as a series of nested if statements within the kernel launcher". Two
//! forms are provided:
//!
//! * [`CompiledTree`] — a flat, allocation-free table the coordinator
//!   evaluates on the request hot path (a few compares per lookup),
//! * [`to_rust_source`] — generated Rust nested-if source, ready to paste
//!   into a library that wants zero runtime data files.

use crate::classify::{KernelClassifier, Standardizer};
use crate::dataset::shapes::FEATURE_NAMES;
use crate::ml::decision_tree::{FLAT_LEAF, FlatTree, TreeClassifier};

/// Leaf marker in the flattened `feat` array — the shared
/// [`FlatTree::into_parts`] wire contract, under the module's historical
/// local name.
const LEAF: u32 = FLAT_LEAF;

/// Flat decision-tree selector in structure-of-arrays layout: node
/// features, destandardized thresholds and child pairs live in three
/// parallel arrays, and descent indexes the child pair with the comparison
/// result instead of branching — the branch-predictable walk the submit
/// path runs on every cache miss and the retuner runs when scoring
/// candidate deployments. Features are pre-standardized at build time so
/// the hot path needs no allocation and no division.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledTree {
    /// Split feature per node; `LEAF` marks a leaf.
    feat: Vec<u32>,
    /// Destandardized split threshold per node (0.0 at leaves).
    thr: Vec<f64>,
    /// `[left, right]` child indices; at a leaf, `[class, class]`.
    kids: Vec<[u32; 2]>,
    /// Deployed configuration indices; classes index into this.
    pub deployed: Vec<usize>,
}

impl CompiledTree {
    /// Compile a trained decision-tree classifier. Thresholds are folded
    /// back into *raw feature* space (destandardized) so evaluation skips
    /// the z-score transform entirely.
    pub fn compile(clf: &KernelClassifier) -> Option<CompiledTree> {
        let tree = clf.tree()?;
        Some(flatten(tree, &clf.standardizer, clf.deployed.clone()))
    }

    /// Deployed-set class for raw (unstandardized) shape features.
    #[inline]
    pub fn predict_class(&self, raw: &[f64]) -> usize {
        let mut i = 0usize;
        loop {
            let f = self.feat[i];
            if f == LEAF {
                return self.kids[i][0] as usize;
            }
            let right = (raw[f as usize] > self.thr[i]) as usize;
            i = self.kids[i][right] as usize;
        }
    }

    /// Full-space configuration index for raw shape features.
    #[inline]
    pub fn predict_config(&self, raw: &[f64]) -> usize {
        self.deployed[self.predict_class(raw).min(self.deployed.len() - 1)]
    }

    /// Number of nodes (splits + leaves) in the flattened table.
    pub fn n_nodes(&self) -> usize {
        self.feat.len()
    }

    /// One node as `(feature, threshold, left, right)`; leaves report
    /// `feature == usize::MAX` with `left` holding the class. Serialization
    /// and codegen iterate this view.
    fn node(&self, i: usize) -> (usize, f64, u32, u32) {
        if self.feat[i] == LEAF {
            (usize::MAX, 0.0, self.kids[i][0], 0)
        } else {
            (self.feat[i] as usize, self.thr[i], self.kids[i][0], self.kids[i][1])
        }
    }

    // -- serialization (one line per node; human-auditable) ----------------

    /// Text form, one line per node (`deployed` header, then
    /// `split f thr left right` / `leaf class` lines) — human-auditable
    /// and stable across platforms (`{:.17e}` round-trips every f64).
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "deployed {}\n",
            self.deployed
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join(",")
        ));
        for i in 0..self.n_nodes() {
            let (feat, thr, left, right) = self.node(i);
            if feat == usize::MAX {
                out.push_str(&format!("leaf {left}\n"));
            } else {
                out.push_str(&format!("split {feat} {thr:.17e} {left} {right}\n"));
            }
        }
        out
    }

    /// Parse the [`CompiledTree::serialize`] text form; rejects malformed
    /// lines, out-of-range feature indices and empty trees.
    pub fn deserialize(text: &str) -> Result<CompiledTree, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty tree")?;
        let deployed: Vec<usize> = header
            .strip_prefix("deployed ")
            .ok_or("missing deployed header")?
            .split(',')
            .map(|s| s.parse().map_err(|_| format!("bad config index {s}")))
            .collect::<Result<_, String>>()?;
        let mut tree =
            CompiledTree { feat: Vec::new(), thr: Vec::new(), kids: Vec::new(), deployed };
        for line in lines {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts.as_slice() {
                ["leaf", cls] => {
                    let cls: u32 = cls.parse().map_err(|_| "bad leaf class".to_string())?;
                    tree.feat.push(LEAF);
                    tree.thr.push(0.0);
                    tree.kids.push([cls, cls]);
                }
                ["split", f, t, l, r] => {
                    let f: usize = f.parse().map_err(|_| "bad feature")?;
                    if f >= LEAF as usize {
                        return Err(format!("feature index {f} out of range"));
                    }
                    tree.feat.push(f as u32);
                    tree.thr.push(t.parse().map_err(|_| "bad threshold")?);
                    tree.kids.push([
                        l.parse().map_err(|_| "bad left")?,
                        r.parse().map_err(|_| "bad right")?,
                    ]);
                }
                [] => {}
                _ => return Err(format!("bad tree line: {line}")),
            }
        }
        if tree.feat.is_empty() {
            return Err("tree has no nodes".into());
        }
        Ok(tree)
    }
}

fn flatten(tree: &TreeClassifier, st: &Standardizer, deployed: Vec<usize>) -> CompiledTree {
    // Reuse the SoA flattening (and its leaf-majority, last-max collapse)
    // from `ml::decision_tree` — one implementation to keep
    // prediction-identical — then rebase the split thresholds into raw
    // feature space: z <= t  <=>  raw <= t * std + mean.
    let (feat, mut thr, kids) = FlatTree::from_classifier(tree).into_parts();
    for (f, t) in feat.iter().zip(thr.iter_mut()) {
        if *f != LEAF {
            let fi = *f as usize;
            *t = *t * st.std[fi] + st.mean[fi];
        }
    }
    CompiledTree { feat, thr, kids, deployed }
}

/// Generated Rust source: nested ifs over the raw feature names, as a
/// library would embed (paper §5.1).
pub fn to_rust_source(ct: &CompiledTree, fn_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "/// Auto-generated kernel selector: returns an index into the\n\
         /// deployed configuration table {:?}.\n",
        ct.deployed
    ));
    out.push_str(&format!(
        "pub fn {fn_name}(features: &[f64; {}]) -> usize {{\n",
        FEATURE_NAMES.len()
    ));
    emit(ct, 0, 1, &mut out);
    out.push_str("}\n");
    out
}

fn emit(ct: &CompiledTree, node: usize, depth: usize, out: &mut String) {
    let pad = "    ".repeat(depth);
    let (feat, thr, left, right) = ct.node(node);
    if feat == usize::MAX {
        out.push_str(&format!("{pad}{left} // {:?}\n", ct.deployed.get(left as usize)));
        return;
    }
    out.push_str(&format!(
        "{pad}if features[{feat}] <= {thr:.6} {{ // {}\n",
        FEATURE_NAMES[feat]
    ));
    emit(ct, left as usize, depth + 1, out);
    out.push_str(&format!("{pad}}} else {{\n"));
    emit(ct, right as usize, depth + 1, out);
    out.push_str(&format!("{pad}}}\n"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classify::{ClassifierKind, KernelClassifier};
    use crate::dataset::{benchmark_shapes, GemmShape};
    use crate::devsim::{generate_dataset, profile_by_name};

    fn trained() -> KernelClassifier {
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(6).collect();
        let ds = generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes);
        KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &[3, 77, 205, 611], 1)
    }

    #[test]
    fn compiled_matches_original() {
        let clf = trained();
        let ct = CompiledTree::compile(&clf).unwrap();
        for s in benchmark_shapes().iter().step_by(3) {
            let f = s.features();
            assert_eq!(
                ct.predict_config(&f),
                clf.predict_config(&f),
                "mismatch on {s:?}"
            );
        }
    }

    #[test]
    fn compiled_tree_a_matches_classifier_on_full_grid() {
        // Acceptance: the SoA compiled selector must return the identical
        // config to the DecisionTreeA classifier at *every* benchmark
        // shape (the destandardized thresholds and the branchless child
        // select must not move a single boundary).
        let shapes = benchmark_shapes();
        let ds = generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes);
        let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeA, &ds, &[3, 77, 205, 611], 1);
        let ct = CompiledTree::compile(&clf).unwrap();
        for s in &shapes {
            let f = s.features();
            assert_eq!(ct.predict_config(&f), clf.predict_config(&f), "mismatch on {s:?}");
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let clf = trained();
        let ct = CompiledTree::compile(&clf).unwrap();
        let text = ct.serialize();
        let back = CompiledTree::deserialize(&text).unwrap();
        assert_eq!(ct, back);
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(CompiledTree::deserialize("").is_err());
        assert!(CompiledTree::deserialize("deployed 1,2\nnonsense 1 2\n").is_err());
        assert!(CompiledTree::deserialize("deployed 1,2\n").is_err());
    }

    #[test]
    fn rust_source_compilesque() {
        let clf = trained();
        let ct = CompiledTree::compile(&clf).unwrap();
        let src = to_rust_source(&ct, "select_kernel");
        assert!(src.contains("pub fn select_kernel"));
        assert!(src.contains("features["));
        // Balanced braces.
        let open = src.matches('{').count();
        let close = src.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn non_tree_classifier_cannot_compile() {
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(10).collect();
        let ds = generate_dataset(profile_by_name("i7-6700k").unwrap(), &shapes);
        let knn = KernelClassifier::fit(ClassifierKind::NearestNeighbor1, &ds, &[1, 2], 1);
        assert!(CompiledTree::compile(&knn).is_none());
    }
}
