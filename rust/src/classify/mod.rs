//! Runtime kernel classification (paper §5): map a GEMM's matrix sizes to
//! one of the deployed kernel configurations.
//!
//! Training labels come from the benchmark data: for each training size set
//! the label is the deployed configuration with the best measured
//! performance. Features are the log-scaled shape descriptors of
//! `GemmShape::features`, z-score standardized on the training split.
//!
//! The ten classifiers of Tables 1 and 2 are provided behind one enum:
//! decision trees A/B/C, 1/3/7-NN, linear/RBF SVM, random forest, MLP.

pub mod codegen;

use crate::dataset::PerfDataset;
use crate::linalg::stats::argmax;
use crate::linalg::Matrix;
use crate::ml::decision_tree::{FlatTree, TreeClassifier, TreeParams};
use crate::ml::knn::Knn;
use crate::ml::mlp::{Mlp, MlpParams};
use crate::ml::random_forest::{ForestParams, RandomForest};
use crate::ml::svm::{Kernel, Svm, SvmParams};

/// The classifier lineup of paper §5.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ClassifierKind {
    /// Unbounded depth, single-sample leaves.
    DecisionTreeA,
    /// Depth <= 6, >= 3 samples per leaf.
    DecisionTreeB,
    /// Depth <= 3, >= 4 samples per leaf.
    DecisionTreeC,
    /// 1-nearest-neighbor vote in standardized feature space.
    NearestNeighbor1,
    /// 3-nearest-neighbor vote.
    NearestNeighbor3,
    /// 7-nearest-neighbor vote.
    NearestNeighbor7,
    /// One-vs-rest SVM with a linear kernel.
    LinearSvm,
    /// One-vs-rest SVM with an RBF kernel (gamma 0.25).
    RadialSvm,
    /// 50-tree random forest (majority vote).
    RandomForest,
    /// One-hidden-layer (100 unit) perceptron.
    Mlp,
}

/// Every classifier of Tables 1 and 2, in table order.
pub const ALL_CLASSIFIERS: [ClassifierKind; 10] = [
    ClassifierKind::DecisionTreeA,
    ClassifierKind::DecisionTreeB,
    ClassifierKind::DecisionTreeC,
    ClassifierKind::NearestNeighbor1,
    ClassifierKind::NearestNeighbor3,
    ClassifierKind::NearestNeighbor7,
    ClassifierKind::LinearSvm,
    ClassifierKind::RadialSvm,
    ClassifierKind::RandomForest,
    ClassifierKind::Mlp,
];

impl ClassifierKind {
    /// The table row label used in reports and experiment JSON.
    pub fn name(&self) -> &'static str {
        match self {
            ClassifierKind::DecisionTreeA => "DecisionTreeA",
            ClassifierKind::DecisionTreeB => "DecisionTreeB",
            ClassifierKind::DecisionTreeC => "DecisionTreeC",
            ClassifierKind::NearestNeighbor1 => "1NearestNeighbor",
            ClassifierKind::NearestNeighbor3 => "3NearestNeighbor",
            ClassifierKind::NearestNeighbor7 => "7NearestNeighbor",
            ClassifierKind::LinearSvm => "LinearSVM",
            ClassifierKind::RadialSvm => "RadialSVM",
            ClassifierKind::RandomForest => "RandomForest",
            ClassifierKind::Mlp => "MLP",
        }
    }
}

/// Feature standardization fitted on the training split.
#[derive(Clone, Debug)]
pub struct Standardizer {
    /// Per-feature mean over the training rows.
    pub mean: Vec<f64>,
    /// Per-feature standard deviation (floored at 1e-9 to keep constant
    /// features from dividing by zero).
    pub std: Vec<f64>,
}

impl Standardizer {
    /// Fit per-column mean/std on the training feature matrix.
    pub fn fit(x: &Matrix) -> Standardizer {
        let mean = x.col_means();
        let mut var = vec![0.0f64; x.cols];
        for r in 0..x.rows {
            for (v, (&xv, &mu)) in var.iter_mut().zip(x.row(r).iter().zip(&mean)) {
                *v += (xv - mu) * (xv - mu);
            }
        }
        let std = var
            .into_iter()
            .map(|v| (v / x.rows as f64).sqrt().max(1e-9))
            .collect();
        Standardizer { mean, std }
    }

    /// Z-score one raw feature row with the fitted statistics.
    pub fn transform_row(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .zip(self.mean.iter().zip(&self.std))
            .map(|(&v, (&mu, &sd))| (v - mu) / sd)
            .collect()
    }

    /// Z-score a whole feature matrix row by row.
    pub fn transform(&self, x: &Matrix) -> Matrix {
        Matrix::from_rows(&(0..x.rows).map(|r| self.transform_row(x.row(r))).collect::<Vec<_>>())
    }
}

/// A trained kernel selector: classifier + standardizer + the deployed set.
pub struct KernelClassifier {
    /// Which of the ten classifier families this is.
    pub kind: ClassifierKind,
    /// The feature standardization fitted on the training split; raw
    /// shape features pass through it before every prediction.
    pub standardizer: Standardizer,
    /// Deployed configuration indices; classifier classes index into this.
    pub deployed: Vec<usize>,
    model: Model,
}

enum Model {
    /// The reference arena walk plus its flattened (SoA) evaluator; all
    /// predictions run through the flat form, the arena stays for
    /// codegen and exact-match verification.
    Tree(TreeClassifier, FlatTree),
    Knn(Knn),
    Svm(Svm),
    Forest(RandomForest),
    Mlp(Mlp),
}

/// Fit a tree and pre-flatten it for branch-predictable inference.
fn tree_model(x: &Matrix, y: &[usize], params: &TreeParams) -> Model {
    let tree = TreeClassifier::fit(x, y, params);
    let flat = FlatTree::from_classifier(&tree);
    Model::Tree(tree, flat)
}

/// Labels for training: per size set, the best config among `deployed`.
pub fn deployment_labels(ds: &PerfDataset, deployed: &[usize]) -> Vec<usize> {
    (0..ds.n_shapes())
        .map(|r| {
            let per_deploy: Vec<f64> =
                deployed.iter().map(|&c| ds.gflops[(r, c)]).collect();
            argmax(&per_deploy)
        })
        .collect()
}

impl KernelClassifier {
    /// Train on the benchmark data of `train` restricted to `deployed`.
    pub fn fit(
        kind: ClassifierKind,
        train: &PerfDataset,
        deployed: &[usize],
        seed: u64,
    ) -> KernelClassifier {
        assert!(!deployed.is_empty());
        let features_raw = train.features();
        let standardizer = Standardizer::fit(&features_raw);
        let x = standardizer.transform(&features_raw);
        let y = deployment_labels(train, deployed);
        let model = match kind {
            ClassifierKind::DecisionTreeA => {
                tree_model(&x, &y, &TreeParams { seed, ..Default::default() })
            }
            ClassifierKind::DecisionTreeB => tree_model(
                &x,
                &y,
                &TreeParams {
                    max_depth: Some(6),
                    min_samples_leaf: 3,
                    seed,
                    ..Default::default()
                },
            ),
            ClassifierKind::DecisionTreeC => tree_model(
                &x,
                &y,
                &TreeParams {
                    max_depth: Some(3),
                    min_samples_leaf: 4,
                    seed,
                    ..Default::default()
                },
            ),
            ClassifierKind::NearestNeighbor1 => Model::Knn(Knn::fit(&x, &y, 1)),
            ClassifierKind::NearestNeighbor3 => {
                Model::Knn(Knn::fit(&x, &y, 3.min(x.rows)))
            }
            ClassifierKind::NearestNeighbor7 => {
                Model::Knn(Knn::fit(&x, &y, 7.min(x.rows)))
            }
            ClassifierKind::LinearSvm => Model::Svm(Svm::fit(
                &x,
                &y,
                &SvmParams { kernel: Kernel::Linear, c: 10.0, seed, ..Default::default() },
            )),
            ClassifierKind::RadialSvm => Model::Svm(Svm::fit(
                &x,
                &y,
                &SvmParams { kernel: Kernel::Rbf(0.25), c: 10.0, seed, ..Default::default() },
            )),
            ClassifierKind::RandomForest => Model::Forest(RandomForest::fit(
                &x,
                &y,
                &ForestParams { n_trees: 50, seed, ..Default::default() },
            )),
            ClassifierKind::Mlp => Model::Mlp(Mlp::fit(
                &x,
                &y,
                &MlpParams { hidden: 100, epochs: 120, seed, ..Default::default() },
            )),
        };
        KernelClassifier { kind, standardizer, deployed: deployed.to_vec(), model }
    }

    /// Predict the *deployed-set-relative* class for raw shape features.
    pub fn predict_class(&self, raw_features: &[f64]) -> usize {
        let row = self.standardizer.transform_row(raw_features);
        let cls = match &self.model {
            // The flat evaluator is prediction-identical to the arena
            // walk (asserted by tests); it is what serving-path inference
            // and the retuner's candidate scoring run.
            Model::Tree(_, flat) => flat.predict(&row),
            Model::Knn(k) => k.predict(&row),
            Model::Svm(s) => s.predict(&row),
            Model::Forest(f) => f.predict(&row),
            Model::Mlp(m) => m.predict(&row),
        };
        cls.min(self.deployed.len() - 1)
    }

    /// Predict the configuration index (into the full 640-config space).
    pub fn predict_config(&self, raw_features: &[f64]) -> usize {
        self.deployed[self.predict_class(raw_features)]
    }

    /// Per-shape config choices over a dataset.
    pub fn choices(&self, ds: &PerfDataset) -> Vec<usize> {
        ds.shapes
            .iter()
            .map(|s| self.predict_config(&s.features()))
            .collect()
    }

    /// The underlying decision tree, when the classifier is one (codegen).
    pub fn tree(&self) -> Option<&TreeClassifier> {
        match &self.model {
            Model::Tree(tree, _) => Some(tree),
            _ => None,
        }
    }

    /// The flattened evaluator, when the classifier is a tree.
    pub fn flat_tree(&self) -> Option<&FlatTree> {
        match &self.model {
            Model::Tree(_, flat) => Some(flat),
            _ => None,
        }
    }
}

/// Table 1/2 cell: % of the absolute optimal performance the classifier's
/// choices achieve on the test split.
pub fn classifier_percent(
    kind: ClassifierKind,
    train: &PerfDataset,
    test: &PerfDataset,
    deployed: &[usize],
    seed: u64,
) -> f64 {
    let clf = KernelClassifier::fit(kind, train, deployed, seed);
    let choices = clf.choices(test);
    crate::selection::achieved_percent(test, &choices)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{benchmark_shapes, GemmShape, Normalization};
    use crate::devsim::{generate_dataset, profile_by_name};
    use crate::selection::{achievable_percent, select, Method};

    fn dataset() -> PerfDataset {
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(4).collect();
        generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes)
    }

    #[test]
    fn standardizer_zero_mean_unit_std() {
        let ds = dataset();
        let x = ds.features();
        let st = Standardizer::fit(&x);
        let z = st.transform(&x);
        for c in 0..z.cols {
            let col = z.col(c);
            assert!(crate::linalg::stats::mean(&col).abs() < 1e-9);
            let sd = crate::linalg::stats::std_dev(&col);
            assert!((sd - 1.0).abs() < 1e-6, "col {c} std {sd}");
        }
    }

    #[test]
    fn labels_point_at_best_deployed() {
        let ds = dataset();
        let deployed = vec![0usize, 100, 400];
        let labels = deployment_labels(&ds, &deployed);
        for (r, &l) in labels.iter().enumerate() {
            let chosen = ds.gflops[(r, deployed[l])];
            for &d in &deployed {
                assert!(chosen >= ds.gflops[(r, d)]);
            }
        }
    }

    #[test]
    fn all_classifiers_train_and_predict_in_range() {
        let ds = dataset();
        let split = ds.split(0.75, 3);
        let train = ds.subset(&split.train);
        let test = ds.subset(&split.test);
        let deployed = select(Method::PcaKMeans, &train, Normalization::Standard, 5, 1);
        for kind in ALL_CLASSIFIERS {
            let clf = KernelClassifier::fit(kind, &train, &deployed, 7);
            for s in &test.shapes {
                let cfg = clf.predict_config(&s.features());
                assert!(deployed.contains(&cfg), "{kind:?} chose undeployed {cfg}");
            }
        }
    }

    #[test]
    fn decision_tree_close_to_oracle() {
        // The paper's central §5 finding: a decision tree preserves most of
        // the achievable performance of the deployment.
        let ds = dataset();
        let split = ds.split(0.75, 5);
        let train = ds.subset(&split.train);
        let test = ds.subset(&split.test);
        let deployed = select(Method::PcaKMeans, &train, Normalization::Standard, 6, 1);
        let oracle = achievable_percent(&test, &deployed);
        let dt = classifier_percent(ClassifierKind::DecisionTreeA, &train, &test, &deployed, 7);
        assert!(
            dt > 0.75 * oracle,
            "DT {dt:.1}% far below oracle {oracle:.1}%"
        );
    }

    #[test]
    fn flat_evaluator_matches_reference_tree_on_full_grid() {
        // Acceptance: the flattened (SoA) evaluator must agree with the
        // reference DecisionTreeA arena walk on *every* benchmark shape —
        // class for class, config for config — not just a subsample.
        let shapes = benchmark_shapes();
        let ds = generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes);
        let deployed = select(Method::PcaKMeans, &ds, Normalization::Standard, 8, 1);
        let clf = KernelClassifier::fit(ClassifierKind::DecisionTreeA, &ds, &deployed, 7);
        let tree = clf.tree().expect("tree classifier");
        let flat = clf.flat_tree().expect("flattened evaluator");
        for s in &shapes {
            let row = clf.standardizer.transform_row(&s.features());
            let reference = tree.predict(&row).min(deployed.len() - 1);
            assert_eq!(
                flat.predict(&row).min(deployed.len() - 1),
                reference,
                "flat walk diverges from the reference tree at {s:?}"
            );
            assert_eq!(
                clf.predict_config(&s.features()),
                deployed[reference],
                "classifier inference diverges at {s:?}"
            );
        }
    }

    #[test]
    fn tree_accessor_only_for_trees() {
        let ds = dataset();
        let deployed = vec![0usize, 1, 2];
        let t = KernelClassifier::fit(ClassifierKind::DecisionTreeB, &ds, &deployed, 1);
        assert!(t.tree().is_some());
        let k = KernelClassifier::fit(ClassifierKind::NearestNeighbor1, &ds, &deployed, 1);
        assert!(k.tree().is_none());
    }
}
