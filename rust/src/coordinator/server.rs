//! The serving loop: a dedicated executor thread owns the PJRT runtime
//! (whose handles are not `Send`) and drains a dynamic batcher; any number
//! of client threads submit GEMM requests over a channel and receive
//! responses on per-request channels.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::{KernelRegistry, Resolution};
use crate::coordinator::selector::SelectorPolicy;
use crate::dataset::GemmShape;
use crate::runtime::{Manifest, Runtime};

/// A GEMM request: `lhs` is (b, m, k), `rhs` is (b, k, n), row-major.
pub struct GemmRequest {
    pub shape: GemmShape,
    pub lhs: Vec<f32>,
    pub rhs: Vec<f32>,
    pub respond: Sender<GemmResponse>,
}

#[derive(Debug)]
pub struct GemmResponse {
    pub result: Result<Vec<f32>, String>,
    /// The configuration that served the request (None = XLA backend).
    pub config_used: Option<usize>,
    pub artifact: String,
    pub latency: Duration,
}

enum Message {
    Request(GemmRequest, Instant),
    Stop(Sender<Metrics>),
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Sender<Message>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the executor thread.
    pub fn start(
        artifacts_dir: PathBuf,
        policy: SelectorPolicy,
        batcher_cfg: BatcherConfig,
    ) -> Result<Coordinator, String> {
        let (tx, rx) = channel::<Message>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let worker = std::thread::Builder::new()
            .name("kernelsel-executor".into())
            .spawn(move || executor_loop(artifacts_dir, policy, batcher_cfg, rx, ready_tx))
            .map_err(|e| e.to_string())?;
        ready_rx
            .recv()
            .map_err(|_| "executor died during startup".to_string())??;
        Ok(Coordinator { tx, worker: Some(worker) })
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(
        &self,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Receiver<GemmResponse> {
        let (resp_tx, resp_rx) = channel();
        let req = GemmRequest { shape, lhs, rhs, respond: resp_tx };
        // A send failure means the executor is gone; the dropped resp_tx
        // surfaces as RecvError on the caller side.
        let _ = self.tx.send(Message::Request(req, Instant::now()));
        resp_rx
    }

    /// Blocking convenience call.
    pub fn call(
        &self,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Result<GemmResponse, String> {
        self.submit(shape, lhs, rhs)
            .recv()
            .map_err(|_| "coordinator shut down".to_string())
    }

    /// Stop the executor and collect final metrics.
    pub fn stop(mut self) -> Metrics {
        let (mtx, mrx) = channel();
        let _ = self.tx.send(Message::Stop(mtx));
        let metrics = mrx.recv().unwrap_or_default();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            let (mtx, _mrx) = channel();
            let _ = self.tx.send(Message::Stop(mtx));
            let _ = w.join();
        }
    }
}

struct Job {
    req: GemmRequest,
    t_submit: Instant,
    config: Option<usize>,
}

fn executor_loop(
    artifacts_dir: PathBuf,
    policy: SelectorPolicy,
    batcher_cfg: BatcherConfig,
    rx: Receiver<Message>,
    ready: Sender<Result<(), String>>,
) {
    let runtime = match Runtime::new(&artifacts_dir) {
        Ok(rt) => rt,
        Err(e) => {
            let _ = ready.send(Err(format!("runtime init: {e}")));
            return;
        }
    };
    let manifest = match Manifest::load(&artifacts_dir) {
        Ok(m) => m,
        Err(e) => {
            let _ = ready.send(Err(format!("manifest: {e}")));
            return;
        }
    };
    let registry = KernelRegistry::new(manifest, policy);
    let mut batcher: Batcher<Job> = Batcher::new(batcher_cfg);
    let mut metrics = Metrics::default();
    let _ = ready.send(Ok(()));

    let mut stop_reply: Option<Sender<Metrics>> = None;
    'outer: loop {
        // Wait for work, bounded by the batcher's next deadline.
        let timeout = batcher
            .next_deadline()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Message::Request(req, t_submit)) => {
                match registry.resolve(&req.shape) {
                    Ok((meta, resolution)) => {
                        match resolution {
                            Resolution::FallbackConfig => metrics.fallback_config += 1,
                            Resolution::FallbackXla => metrics.fallback_xla += 1,
                            Resolution::Direct => {}
                        }
                        let artifact = meta.path.clone();
                        let config = meta.config_index;
                        batcher.push(artifact, Job { req, t_submit, config });
                    }
                    Err(e) => {
                        metrics.failures += 1;
                        let _ = req.respond.send(GemmResponse {
                            result: Err(e),
                            config_used: None,
                            artifact: String::new(),
                            latency: t_submit.elapsed(),
                        });
                    }
                }
            }
            Ok(Message::Stop(reply)) => {
                stop_reply = Some(reply);
                break 'outer;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break 'outer,
        }
        // Serve every batch that is due.
        while let Some((artifact, group)) = batcher.drain_due() {
            run_batch(&runtime, &artifact, group, &mut metrics);
        }
    }

    // Flush outstanding work before stopping.
    for (artifact, group) in batcher.drain_all() {
        run_batch(&runtime, &artifact, group, &mut metrics);
    }
    if let Some(reply) = stop_reply {
        let _ = reply.send(metrics);
    }
}

fn run_batch(
    runtime: &Runtime,
    artifact: &str,
    group: Vec<crate::coordinator::batcher::Pending<Job>>,
    metrics: &mut Metrics,
) {
    metrics.record_batch(group.len());
    let exe = runtime.load(artifact);
    for pending in group {
        let job = pending.payload;
        let (b, m, k, n) =
            (job.req.shape.batch, job.req.shape.m, job.req.shape.k, job.req.shape.n);
        let result = match &exe {
            Ok(exe) => runtime
                .execute_f32(
                    exe,
                    &[(&job.req.lhs, &[b, m, k]), (&job.req.rhs, &[b, k, n])],
                )
                .map_err(|e| e.to_string()),
            Err(e) => Err(e.to_string()),
        };
        let latency = job.t_submit.elapsed();
        if result.is_err() {
            metrics.failures += 1;
        }
        metrics.record_request(latency.as_secs_f64(), job.config);
        let _ = job.req.respond.send(GemmResponse {
            result,
            config_used: job.config,
            artifact: artifact.to_string(),
            latency,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fill_buffer;
    use std::path::PathBuf;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn start_xla() -> Coordinator {
        Coordinator::start(artifacts(), SelectorPolicy::Xla, BatcherConfig::default())
            .expect("coordinator start")
    }

    #[test]
    fn serves_single_request() {
        let coord = start_xla();
        let shape = GemmShape::new(128, 128, 128, 1);
        let lhs = fill_buffer(1, 128 * 128);
        let rhs = fill_buffer(2, 128 * 128);
        let resp = coord.call(shape, lhs, rhs).unwrap();
        let out = resp.result.expect("gemm result");
        assert_eq!(out.len(), 128 * 128);
        assert!(out.iter().all(|v| v.is_finite()));
        let metrics = coord.stop();
        assert_eq!(metrics.requests, 1);
        assert_eq!(metrics.failures, 0);
    }

    #[test]
    fn every_request_answered_exactly_once_under_concurrency() {
        let coord = std::sync::Arc::new(start_xla());
        let n_threads = 4;
        let per_thread = 6;
        let mut joins = Vec::new();
        for t in 0..n_threads {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let shape = GemmShape::new(128, 128, 128, 1);
                let mut got = 0;
                for i in 0..per_thread {
                    let lhs = fill_buffer((t * 100 + i) as u32, 128 * 128);
                    let rhs = fill_buffer((t * 100 + i + 50) as u32, 128 * 128);
                    let rx = coord.submit(shape, lhs, rhs);
                    let resp = rx.recv().expect("response");
                    assert!(resp.result.is_ok());
                    got += 1;
                }
                got
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, n_threads * per_thread);
        let metrics =
            std::sync::Arc::try_unwrap(coord).ok().expect("sole owner").stop();
        assert_eq!(metrics.requests, n_threads * per_thread);
        assert_eq!(metrics.failures, 0);
        assert!(metrics.mean_batch_size() >= 1.0);
    }

    #[test]
    fn unknown_shape_fails_cleanly() {
        let coord = start_xla();
        let resp = coord
            .call(GemmShape::new(17, 19, 23, 1), vec![0.0; 17 * 19], vec![0.0; 19 * 23])
            .unwrap();
        assert!(resp.result.is_err());
        let metrics = coord.stop();
        assert_eq!(metrics.failures, 1);
    }

    #[test]
    fn tuned_policy_uses_deployed_config() {
        let dir = artifacts();
        let manifest = Manifest::load(&dir).unwrap();
        let best = crate::dataset::config_by_name(&manifest.single_best)
            .unwrap()
            .index();
        let coord = Coordinator::start(
            dir,
            SelectorPolicy::Single(best),
            BatcherConfig::default(),
        )
        .unwrap();
        let resp = coord
            .call(
                GemmShape::new(128, 128, 128, 1),
                fill_buffer(1, 128 * 128),
                fill_buffer(2, 128 * 128),
            )
            .unwrap();
        assert_eq!(resp.config_used, Some(best));
        assert!(resp.result.is_ok());
        coord.stop();
    }
}
