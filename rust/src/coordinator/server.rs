//! The serving loop: a sharded executor pool.
//!
//! Any number of client threads submit GEMM requests; the submit path
//! resolves each to a shipped artifact through the memoized selector cache,
//! routes it by **shape affinity** (hash of the resolved artifact path) to
//! one of N executor shards, and receives the response on a per-request
//! channel. Each shard owns a private [`Backend`] instance (PJRT handles
//! are not `Send`, so backends are constructed on the shard's own thread
//! from a Send-able [`EngineKind`] spec), a dynamic [`Batcher`], and its
//! own [`Metrics`]; affinity routing keeps every executable cache hot on
//! exactly one shard. At shutdown the per-shard metrics are collected and
//! merged into a pool-wide total.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, BatcherConfig, Pending};
use crate::coordinator::cache::{ResolutionCache, ResolvedKernel};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::KernelRegistry;
use crate::coordinator::selector::SelectorPolicy;
use crate::dataset::GemmShape;
use crate::engine::{Backend, EngineKind};
use crate::runtime::Manifest;

/// A GEMM request: `lhs` is (b, m, k), `rhs` is (b, k, n), row-major.
pub struct GemmRequest {
    pub shape: GemmShape,
    pub lhs: Vec<f32>,
    pub rhs: Vec<f32>,
    pub respond: Sender<GemmResponse>,
}

#[derive(Debug)]
pub struct GemmResponse {
    pub result: Result<Vec<f32>, String>,
    /// The configuration that served the request (None = XLA backend).
    pub config_used: Option<usize>,
    pub artifact: String,
    pub latency: Duration,
}

/// Executor-pool configuration.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Number of executor shards (worker threads), each owning a backend.
    pub shards: usize,
    /// Which execution backend every shard instantiates.
    pub engine: EngineKind,
    pub batcher: BatcherConfig,
    /// Capacity of the memoized shape -> artifact selector cache.
    pub selector_cache: usize,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            shards: 1,
            engine: EngineKind::default(),
            batcher: BatcherConfig::default(),
            selector_cache: 1024,
        }
    }
}

/// Shutdown report: per-shard metrics plus the merged pool totals.
#[derive(Clone, Debug, Default)]
pub struct PoolReport {
    pub per_shard: Vec<Metrics>,
    pub total: Metrics,
    /// Selector-cache (hits, misses) over the pool's lifetime.
    pub cache_hits: usize,
    pub cache_misses: usize,
}

impl PoolReport {
    pub fn summary(&self) -> String {
        let mut out = format!(
            "pool: {} shard(s), selector cache {}/{} hits\n  total: {}",
            self.per_shard.len(),
            self.cache_hits,
            self.cache_hits + self.cache_misses,
            self.total.summary()
        );
        for (i, m) in self.per_shard.iter().enumerate() {
            out.push_str(&format!("\n  shard {i}: {}", m.summary()));
        }
        out
    }
}

enum Message {
    Request(Job),
    Stop(Sender<Metrics>),
}

struct Job {
    req: GemmRequest,
    t_submit: Instant,
    resolved: Arc<ResolvedKernel>,
}

struct Shard {
    tx: Sender<Message>,
    worker: Option<JoinHandle<()>>,
}

/// Handle to a running executor pool.
pub struct Coordinator {
    registry: Arc<KernelRegistry>,
    cache: ResolutionCache,
    shards: Vec<Shard>,
    /// Metrics for requests that never reach a shard (resolution failures).
    front: Mutex<Metrics>,
    engine_name: &'static str,
}

impl Coordinator {
    /// Start a single-shard pool with the default engine — the SimBackend,
    /// or (with the `pjrt` feature) still the SimBackend; pass an explicit
    /// [`PoolConfig`] to `start_pool` for native execution.
    pub fn start(
        artifacts_dir: PathBuf,
        policy: SelectorPolicy,
        batcher_cfg: BatcherConfig,
    ) -> Result<Coordinator, String> {
        Coordinator::start_pool(
            artifacts_dir,
            policy,
            PoolConfig { batcher: batcher_cfg, ..PoolConfig::default() },
        )
    }

    /// Start the executor pool: N shard threads, each constructing its own
    /// backend instance and reporting readiness before requests flow.
    pub fn start_pool(
        artifacts_dir: PathBuf,
        policy: SelectorPolicy,
        cfg: PoolConfig,
    ) -> Result<Coordinator, String> {
        // The SimBackend reads no artifacts, so a missing manifest falls
        // back to the synthetic deployment; native backends need the real
        // one.
        #[cfg(feature = "pjrt")]
        let manifest = match &cfg.engine {
            EngineKind::Sim { .. } => Manifest::load_or_synthetic(&artifacts_dir),
            EngineKind::Pjrt => Manifest::load(&artifacts_dir)?,
        };
        #[cfg(not(feature = "pjrt"))]
        let manifest = Manifest::load_or_synthetic(&artifacts_dir);

        let registry = Arc::new(KernelRegistry::new(manifest, policy));
        let n_shards = cfg.shards.max(1);
        let mut shards = Vec::with_capacity(n_shards);
        for shard_id in 0..n_shards {
            let (tx, rx) = channel::<Message>();
            let (ready_tx, ready_rx) = channel::<Result<(), String>>();
            let engine = cfg.engine.clone();
            let batcher_cfg = cfg.batcher.clone();
            let dir = artifacts_dir.clone();
            let worker = std::thread::Builder::new()
                .name(format!("kernelsel-shard-{shard_id}"))
                .spawn(move || shard_loop(dir, engine, batcher_cfg, rx, ready_tx))
                .map_err(|e| e.to_string())?;
            ready_rx
                .recv()
                .map_err(|_| format!("shard {shard_id} died during startup"))?
                .map_err(|e| format!("shard {shard_id}: {e}"))?;
            shards.push(Shard { tx, worker: Some(worker) });
        }
        Ok(Coordinator {
            registry,
            cache: ResolutionCache::new(cfg.selector_cache),
            shards,
            front: Mutex::new(Metrics::default()),
            engine_name: cfg.engine.name(),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine_name
    }

    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    /// Selector-cache (hits, misses) so far.
    pub fn selector_cache_stats(&self) -> (usize, usize) {
        self.cache.stats()
    }

    /// Shape-affinity router: requests resolving to the same artifact land
    /// on the same shard, keeping its executable cache hot.
    fn shard_for(&self, artifact: &str) -> usize {
        let mut h = DefaultHasher::new();
        artifact.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(
        &self,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Receiver<GemmResponse> {
        let (resp_tx, resp_rx) = channel();
        let t_submit = Instant::now();
        let resolved = match self.cache.resolve(&self.registry, &shape) {
            Ok(r) => r,
            Err(e) => {
                self.front.lock().unwrap().failures += 1;
                let _ = resp_tx.send(GemmResponse {
                    result: Err(e),
                    config_used: None,
                    artifact: String::new(),
                    latency: t_submit.elapsed(),
                });
                return resp_rx;
            }
        };
        let shard = self.shard_for(&resolved.meta.path);
        let req = GemmRequest { shape, lhs, rhs, respond: resp_tx };
        // A send failure means the shard is gone; the dropped resp_tx
        // surfaces as RecvError on the caller side.
        let _ = self.shards[shard]
            .tx
            .send(Message::Request(Job { req, t_submit, resolved }));
        resp_rx
    }

    /// Blocking convenience call.
    pub fn call(
        &self,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Result<GemmResponse, String> {
        self.submit(shape, lhs, rhs)
            .recv()
            .map_err(|_| "coordinator shut down".to_string())
    }

    /// Stop every shard and return the merged pool metrics.
    pub fn stop(self) -> Metrics {
        self.stop_detailed().total
    }

    /// Stop every shard; return per-shard metrics plus merged totals.
    pub fn stop_detailed(mut self) -> PoolReport {
        // Signal all shards first so they drain concurrently, then join.
        let mut replies = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let (mtx, mrx) = channel();
            let _ = shard.tx.send(Message::Stop(mtx));
            replies.push(mrx);
        }
        let mut per_shard = Vec::with_capacity(self.shards.len());
        for (shard, mrx) in self.shards.iter_mut().zip(replies) {
            per_shard.push(mrx.recv().unwrap_or_default());
            if let Some(w) = shard.worker.take() {
                let _ = w.join();
            }
        }
        let mut total = self.front.lock().map(|m| m.clone()).unwrap_or_default();
        for m in &per_shard {
            total.merge(m.clone());
        }
        let (cache_hits, cache_misses) = self.cache.stats();
        PoolReport { per_shard, total, cache_hits, cache_misses }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for shard in &mut self.shards {
            if let Some(w) = shard.worker.take() {
                let (mtx, _mrx) = channel();
                let _ = shard.tx.send(Message::Stop(mtx));
                let _ = w.join();
            }
        }
    }
}

fn shard_loop(
    artifacts_dir: PathBuf,
    engine: EngineKind,
    batcher_cfg: BatcherConfig,
    rx: Receiver<Message>,
    ready: Sender<Result<(), String>>,
) {
    let mut backend = match engine.create(&artifacts_dir) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(format!("backend init: {e}")));
            return;
        }
    };
    let mut batcher: Batcher<Job> = Batcher::new(batcher_cfg);
    let mut metrics = Metrics::default();
    let _ = ready.send(Ok(()));

    let mut stop_reply: Option<Sender<Metrics>> = None;
    'outer: loop {
        // Wait for work, bounded by the batcher's next deadline.
        let timeout = batcher
            .next_deadline()
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(Message::Request(job)) => {
                let artifact = job.resolved.meta.path.clone();
                batcher.push(artifact, job);
            }
            Ok(Message::Stop(reply)) => {
                stop_reply = Some(reply);
                break 'outer;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break 'outer,
        }
        // Serve every batch that is due.
        while let Some((artifact, group)) = batcher.drain_due() {
            run_batch(backend.as_mut(), &artifact, group, &mut metrics);
        }
    }

    // Flush outstanding work before stopping.
    for (artifact, group) in batcher.drain_all() {
        run_batch(backend.as_mut(), &artifact, group, &mut metrics);
    }
    if let Some(reply) = stop_reply {
        let _ = reply.send(metrics);
    }
}

fn run_batch(
    backend: &mut dyn Backend,
    artifact: &str,
    group: Vec<Pending<Job>>,
    metrics: &mut Metrics,
) {
    metrics.record_batch(group.len());
    // One prepare per batch: first touch compiles, later batches hit the
    // backend's executable cache (kept hot by shape-affinity routing).
    let prepared = match group.first() {
        Some(p) => backend.prepare(&p.payload.resolved.meta),
        None => return,
    };
    for pending in group {
        let job = pending.payload;
        let meta = &job.resolved.meta;
        let result = match &prepared {
            Ok(()) => backend.execute(meta, &job.req.shape, &job.req.lhs, &job.req.rhs),
            Err(e) => Err(e.clone()),
        };
        let latency = job.t_submit.elapsed();
        if result.is_err() {
            metrics.failures += 1;
        }
        metrics.record_resolution(&job.resolved.resolution);
        metrics.record_request(latency.as_secs_f64(), meta.config_index);
        let _ = job.req.respond.send(GemmResponse {
            result,
            config_used: meta.config_index,
            artifact: artifact.to_string(),
            latency,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::config_by_name;
    use crate::engine::sim::host_gemm;
    use crate::util::fill_buffer;
    use std::path::PathBuf;

    fn sim_pool(shards: usize, policy: SelectorPolicy) -> Coordinator {
        Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            policy,
            PoolConfig { shards, ..PoolConfig::default() },
        )
        .expect("coordinator start")
    }

    #[test]
    fn serves_single_request_with_correct_result() {
        let coord = sim_pool(1, SelectorPolicy::Xla);
        let shape = GemmShape::new(64, 64, 64, 1);
        let lhs = fill_buffer(1, 64 * 64);
        let rhs = fill_buffer(2, 64 * 64);
        let resp = coord.call(shape, lhs.clone(), rhs.clone()).unwrap();
        let out = resp.result.expect("gemm result");
        assert_eq!(out, host_gemm(&shape, &lhs, &rhs).unwrap());
        let metrics = coord.stop();
        assert_eq!(metrics.requests, 1);
        assert_eq!(metrics.failures, 0);
    }

    #[test]
    fn every_request_answered_exactly_once_across_shards() {
        let coord = std::sync::Arc::new(sim_pool(4, SelectorPolicy::Xla));
        let n_threads = 4;
        let per_thread = 6;
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(64, 64, 64, 4),
        ];
        let mut joins = Vec::new();
        for t in 0..n_threads {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let mut got = 0;
                for i in 0..per_thread {
                    let shape = shapes[(t + i) % shapes.len()];
                    let lhs =
                        fill_buffer((t * 100 + i) as u32, shape.batch * shape.m * shape.k);
                    let rhs = fill_buffer(
                        (t * 100 + i + 50) as u32,
                        shape.batch * shape.k * shape.n,
                    );
                    let rx = coord.submit(shape, lhs, rhs);
                    let resp = rx.recv().expect("response");
                    assert!(resp.result.is_ok());
                    got += 1;
                }
                got
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, n_threads * per_thread);
        let report = std::sync::Arc::try_unwrap(coord)
            .ok()
            .expect("sole owner")
            .stop_detailed();
        assert_eq!(report.per_shard.len(), 4);
        assert_eq!(report.total.requests, n_threads * per_thread);
        assert_eq!(report.total.failures, 0);
        assert!(report.total.mean_batch_size() >= 1.0);
        // 3 distinct shapes, many lookups: the memoized selector must hit.
        // Concurrent first touches can each count a miss (get-then-insert
        // is not atomic), so the bound is per-thread, not global.
        let worst_case_misses = 3 * n_threads;
        assert!(report.cache_hits >= n_threads * per_thread - worst_case_misses);
        assert_eq!(report.cache_hits + report.cache_misses, n_threads * per_thread);
    }

    #[test]
    fn shape_affinity_concentrates_an_artifact_on_one_shard() {
        let coord = sim_pool(4, SelectorPolicy::Xla);
        let shape = GemmShape::new(32, 32, 32, 1);
        for i in 0..8 {
            let lhs = fill_buffer(i, 32 * 32);
            let rhs = fill_buffer(i + 9, 32 * 32);
            coord.call(shape, lhs, rhs).unwrap().result.unwrap();
        }
        let report = coord.stop_detailed();
        let busy: Vec<usize> = report
            .per_shard
            .iter()
            .filter(|m| m.requests > 0)
            .map(|m| m.requests)
            .collect();
        assert_eq!(busy, vec![8], "one shape must be served by exactly one shard");
    }

    #[test]
    fn unknown_shape_fails_cleanly() {
        let coord = sim_pool(2, SelectorPolicy::Xla);
        let resp = coord
            .call(GemmShape::new(17, 19, 23, 1), vec![0.0; 17 * 19], vec![0.0; 19 * 23])
            .unwrap();
        assert!(resp.result.is_err());
        let metrics = coord.stop();
        assert_eq!(metrics.failures, 1);
        assert_eq!(metrics.requests, 0, "rejected requests never reach a shard");
    }

    #[test]
    fn tuned_policy_uses_deployed_config() {
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let coord = sim_pool(2, SelectorPolicy::Single(best));
        let shape = GemmShape::new(128, 128, 128, 1);
        let resp = coord
            .call(shape, fill_buffer(1, 128 * 128), fill_buffer(2, 128 * 128))
            .unwrap();
        assert_eq!(resp.config_used, Some(best));
        assert!(resp.result.is_ok());
        let metrics = coord.stop();
        assert_eq!(metrics.fallback_config + metrics.fallback_xla, 0);
    }

    #[test]
    fn fallback_resolutions_recorded_per_request() {
        // r1a1c1_wg8x8 is legal but not in the synthetic deployment, so a
        // Single policy for it must fall back to the XLA artifact at every
        // shipped bucket — and the shard must count each fallback.
        let undeployed = config_by_name("r1a1c1_wg8x8").unwrap().index();
        let coord = sim_pool(2, SelectorPolicy::Single(undeployed));
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..3 {
            let resp = coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 7, 64 * 64))
                .unwrap();
            assert!(resp.result.is_ok());
            assert_eq!(resp.config_used, None, "served by the XLA comparator");
        }
        let metrics = coord.stop();
        assert_eq!(metrics.fallback_xla, 3);
        assert_eq!(metrics.fallback_config, 0);
    }

    #[test]
    fn resolution_cache_serves_repeat_shapes() {
        let coord = sim_pool(1, SelectorPolicy::Xla);
        let shape = GemmShape::new(32, 32, 32, 1);
        for i in 0..4 {
            coord
                .call(shape, fill_buffer(i, 32 * 32), fill_buffer(i + 3, 32 * 32))
                .unwrap()
                .result
                .unwrap();
        }
        let (hits, misses) = coord.selector_cache_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 3);
        coord.stop();
    }

    #[test]
    fn multi_shard_handles_mixed_shapes_with_direct_resolutions() {
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let coord = sim_pool(3, SelectorPolicy::Single(best));
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(32, 32, 32, 4),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(64, 64, 64, 4),
        ];
        for (i, shape) in shapes.iter().cycle().take(12).enumerate() {
            let lhs = fill_buffer(i as u32, shape.batch * shape.m * shape.k);
            let rhs = fill_buffer((i + 5) as u32, shape.batch * shape.k * shape.n);
            let resp = coord.call(*shape, lhs, rhs).unwrap();
            assert!(resp.result.is_ok());
        }
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, 12);
        assert_eq!(report.total.failures, 0);
        assert!(report.summary().contains("shard 0:"));
        // Registry resolutions were direct for a deployed config.
        assert_eq!(report.total.fallback_config + report.total.fallback_xla, 0);
    }
}
