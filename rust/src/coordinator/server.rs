//! The serving loop: a load-aware, work-stealing executor pool with a
//! lock-light submit fast path.
//!
//! Any number of client threads submit GEMM requests; the submit path
//! resolves each to a shipped artifact through the striped memoized
//! selector cache (which also attaches a devsim-informed per-dispatch cost
//! hint), then routes it to one of N executor shards. A warm cache-hit
//! submit touches no pool-global lock and performs **zero heap
//! allocations** on the client thread: the resolution is an `Arc` clone
//! out of a striped snapshot map, the response rendezvous is a reusable
//! [`CompletionPool`] slot (atomic state + park/unpark) instead of a fresh
//! `mpsc::channel` pair, frontend counters are striped atomic cells
//! instead of a `Mutex<Metrics>`, and the shard injector pre-reserves its
//! deque. [`Coordinator::submit_many`] batches the resolution, cost
//! pricing, routing and gauge update across consecutive requests sharing a
//! shape.
//!
//! Routing keeps **shape affinity** (memoized hash of the resolved
//! artifact path) as a *preference* — it is what keeps every executable
//! cache hot on exactly one shard — but each shard exposes an atomic
//! [`ShardLoad`] gauge (queue depth + estimated in-flight cost), and when
//! the preferred shard's load exceeds a configurable imbalance threshold
//! the request **spills** to the least-loaded shard instead.
//! Independently, an idle shard **steals** a whole ready batch (one
//! artifact group) from the most loaded peer's injector deque, so tail
//! latency stops tracking the hottest shape even when the spill heuristic
//! lags a bursty mix.
//!
//! Each shard owns a private [`Backend`] instance (PJRT handles are not
//! `Send`, so backends are constructed on the shard's own thread from a
//! Send-able [`EngineKind`] spec), a dynamic [`Batcher`], and its own
//! [`Metrics`]. Stolen work keeps its original submit stamp, so batch
//! deadlines survive migration. At shutdown the per-shard metrics are
//! collected and merged into a pool-wide total; the merge is exact, so the
//! pool totals equal the per-shard sums whatever spilled or was stolen.
//!
//! When offered load exceeds capacity, the optional [`AdmissionPolicy`]
//! keeps the pool predictable instead of letting latency collapse: the
//! submit path may refuse a request before it takes a completion slot
//! (the ticket then carries a typed rejection with a retry hint), and the
//! shards shed already-admitted work that blew its queue budget at drain
//! time. The default policy, [`AdmissionPolicy::Unbounded`], bypasses all
//! of it with a zero-cost early exit — see [`crate::coordinator::admission`].
//!
//! The pool is **multi-tenant**: [`Coordinator::submit_as`] tags a
//! request with a [`TenantId`], and registered tenants
//! ([`PoolConfig::tenants`]) get weighted-fair admission quotas (a
//! tenant's reserved share is admission-guaranteed; past it, the tenant
//! is refused before it can compete for the shared budgets — see
//! [`crate::coordinator::tenant`]), SLO-class-scaled admission budgets,
//! per-tenant metrics lanes, and optionally a per-device telemetry
//! *domain*: a tenant pinned to a device profile records its measured
//! costs into a dedicated sink with its own registry/cache/retuner, so
//! the retuner trains and hot-swaps a selector per domain instead of
//! blending heterogeneous mixes. Anonymous traffic (`submit`,
//! `submit_many`, `call` — all delegating with
//! [`TenantId::ANONYMOUS`]) bypasses every tenant mechanism and stays
//! bit-identical to the pre-tenant pool.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::admission::{
    drain_hint_ns, AdmissionPolicy, RejectReason, RetryBudget, SubmitError, MIN_RETRY_HINT_NS,
    REJECT_REASONS,
};
use crate::coordinator::batcher::{Batcher, BatcherConfig, Pending};
use crate::coordinator::cache::{CostModel, ResolutionCache, ResolvedKernel};
use crate::coordinator::completion::{Completion, CompletionPool, Ticket};
use crate::coordinator::metrics::{LatencyHistogram, Metrics, StripedCounter};
use crate::coordinator::quarantine::{QuarantineConfig, QuarantineSet, Transition};
use crate::coordinator::registry::KernelRegistry;
use crate::coordinator::selector::SelectorPolicy;
use crate::coordinator::tenant::{quota_would_admit, reserved_shares, TenantId, TenantSpec};
use crate::coordinator::trace::{pack_shape, EventKind, FlightRecorder, TraceConfig};
use crate::dataset::GemmShape;
use crate::engine::{Backend, EngineKind, FaultPlan, FaultyBackend};
use crate::runtime::Manifest;
use crate::tuning::explore::{
    measured_coverage, probe_would_admit, rank_by_prior, unmeasured_candidates, ExploreConfig,
    ExplorePlanner, ExploreStats,
};
use crate::tuning::regret::{evaluate_regret, RegretEstimator};
use crate::tuning::retuner::{retune_once, RetuneConfig, RetuneOutcome, Retuner, RetunerStats};
use crate::tuning::swap::deploy_policy;
use crate::tuning::telemetry::TelemetrySink;

/// A GEMM request: `lhs` is (b, m, k), `rhs` is (b, k, n), row-major.
pub struct GemmRequest {
    /// The GEMM dimensions (must match a shipped artifact bucket).
    pub shape: GemmShape,
    /// Left operand, (b, m, k) row-major.
    pub lhs: Vec<f32>,
    /// Right operand, (b, k, n) row-major.
    pub rhs: Vec<f32>,
}

/// What a submitted request resolves to: the result (or the error that
/// stopped it), plus how and how fast it was served.
#[derive(Debug)]
pub struct GemmResponse {
    /// The (b, m, n) output, or the failure that stopped the request
    /// (resolution error, execution error, admission rejection, shed).
    pub result: Result<Vec<f32>, String>,
    /// The configuration that served the request (None = XLA backend).
    pub config_used: Option<usize>,
    /// The artifact path that served it (shared, not copied per response).
    pub artifact: Arc<str>,
    /// End-to-end latency from submit to completion.
    pub latency: Duration,
}

/// Router policy of the executor pool.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Routing {
    /// Pure shape affinity: the artifact hash alone picks the shard.
    Affinity,
    /// Shape affinity as a preference, spilling to the least-loaded shard
    /// when the preferred shard's load gauge exceeds the imbalance
    /// threshold (the default).
    #[default]
    LoadAware,
}

impl Routing {
    /// Stable policy label (flags, metrics, bench JSON).
    pub fn name(&self) -> &'static str {
        match self {
            Routing::Affinity => "affinity",
            Routing::LoadAware => "load-aware",
        }
    }

    /// Parse a `--routing` style flag value.
    pub fn by_name(name: &str) -> Option<Routing> {
        match name {
            "affinity" => Some(Routing::Affinity),
            "load-aware" | "load_aware" => Some(Routing::LoadAware),
            _ => None,
        }
    }
}

/// Fixed per-request dispatch overhead (ns) folded into the load score for
/// every queued request, so many cheap requests register as load just like
/// one expensive one.
const QUEUED_OVERHEAD_NS: u64 = 20_000;

/// Minimum absolute load (ns) on the preferred shard before the router
/// even considers spilling — keeps a near-idle pool on the pure-affinity
/// fast path and stops spill ping-pong at trivial depths.
const SPILL_MIN_EXCESS_NS: u64 = 50_000;

/// How long an idle shard sleeps between steal attempts when the whole
/// pool is quiet. Short enough that a suddenly-overloaded peer is relieved
/// promptly, long enough to keep idle wakeups negligible.
const IDLE_POLL: Duration = Duration::from_millis(5);

/// Injector capacity pre-reserved per shard so steady-state pushes never
/// reallocate on the client thread (the zero-allocation hit path).
const INJECTOR_RESERVE: usize = 32;

/// Attempts (first try included) `call_with_retry` makes per request.
pub const MAX_RETRY_ATTEMPTS: u32 = 3;

/// Upper bound on how long a retry sleeps on an admission retry hint —
/// hints are drain-priced and can stretch under deep backlogs, but a
/// blocking retry caller should re-probe admission well before that.
const RETRY_SLEEP_CAP: Duration = Duration::from_millis(20);

/// EWMA smoothing factor for the measured per-shard drain rate. Biased
/// toward history (new sample weighted 1/4) because batch-to-batch
/// throughput is noisy — one unusually small or large batch should nudge
/// the retry hints, not whipsaw them.
const DRAIN_EWMA_ALPHA: f64 = 0.25;

/// Atomic load gauge of one executor shard: how many requests it owns
/// (injector + batcher + currently executing) and their summed estimated
/// cost. Written by the router on submit, by the shard on completion, and
/// transferred wholesale on steals. Also carries the shard's measured
/// drain rate (completions per second, EWMA over served batches) — the
/// signal admission retry hints are priced on once it is warm.
#[derive(Debug, Default)]
pub struct ShardLoad {
    queued: AtomicUsize,
    cost_ns: AtomicU64,
    /// Measured drain rate as `f64` bits (0 bits == 0.0 == unmeasured).
    /// Written only by the owning shard thread after each served batch;
    /// read lock-free by the submit path.
    drain_rate_bits: AtomicU64,
}

impl ShardLoad {
    fn add(&self, n: usize, cost_ns: u64) {
        self.queued.fetch_add(n, Ordering::Relaxed);
        self.cost_ns.fetch_add(cost_ns, Ordering::Relaxed);
    }

    fn sub(&self, n: usize, cost_ns: u64) {
        // Saturating, not wrapping: a dead-queue gauge reset (see
        // [`ShardLoad::reset_to`]) can race a concurrent push or steal
        // transfer whose matching `sub` lands after the reset already
        // dropped that share — underflow would poison the router's score
        // forever, while a transiently low gauge self-corrects.
        let _ = self
            .queued
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |q| Some(q.saturating_sub(n)));
        let _ = self.cost_ns.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| {
            Some(c.saturating_sub(cost_ns))
        });
    }

    /// Fold `n` completions served over `secs` of wall clock into the
    /// drain-rate EWMA. Called only by the owning shard thread at the end
    /// of each batch, so the load-modify-store needs no CAS loop. The
    /// first sample seeds the EWMA directly.
    fn note_completions(&self, n: usize, secs: f64) {
        if n == 0 || !(secs > 0.0) {
            return;
        }
        let sample = n as f64 / secs;
        let prev = f64::from_bits(self.drain_rate_bits.load(Ordering::Relaxed));
        let next =
            if prev > 0.0 { prev + DRAIN_EWMA_ALPHA * (sample - prev) } else { sample };
        self.drain_rate_bits.store(next.to_bits(), Ordering::Relaxed);
    }

    /// Reset the gauge to an exact inventory: `queued` requests of
    /// `cost_ns` total estimated cost, and a cold drain rate. Called when
    /// a queue is declared dead (its worker exited or panicked): jobs the
    /// dead worker had already pulled into its private batcher can never
    /// complete, so their `sub` side will never run — without this reset
    /// the gauge keeps their share forever and the router keeps scoring a
    /// corpse as busy. The inventory is what the injector still holds
    /// (rescuable by steal or a respawned worker); the drain EWMA resets
    /// to unmeasured because a replacement worker's rate starts cold.
    pub fn reset_to(&self, queued: usize, cost_ns: u64) {
        self.queued.store(queued, Ordering::Relaxed);
        self.cost_ns.store(cost_ns, Ordering::Relaxed);
        self.drain_rate_bits.store(0, Ordering::Relaxed);
    }

    /// Requests currently owned by the shard.
    pub fn depth(&self) -> usize {
        self.queued.load(Ordering::Relaxed)
    }

    /// Measured drain rate in completions per second (EWMA over served
    /// batches); `0.0` until the shard completes its first batch.
    pub fn drain_rate_per_sec(&self) -> f64 {
        f64::from_bits(self.drain_rate_bits.load(Ordering::Relaxed))
    }

    /// The scalar the router compares: estimated in-flight cost plus a
    /// fixed dispatch overhead per queued request, in nanoseconds.
    pub fn score_ns(&self) -> u64 {
        self.cost_ns
            .load(Ordering::Relaxed)
            .saturating_add(self.depth() as u64 * QUEUED_OVERHEAD_NS)
    }
}

/// Executor-pool configuration.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Number of executor shards (worker threads), each owning a backend.
    pub shards: usize,
    /// Which execution backend every shard instantiates.
    pub engine: EngineKind,
    /// Per-shard dynamic-batching knobs.
    pub batcher: BatcherConfig,
    /// Capacity of the memoized shape -> artifact selector cache.
    pub selector_cache: usize,
    /// Completion slots pre-allocated for in-flight requests; submits
    /// beyond this depth fall back to per-request heap slots.
    pub completion_slots: usize,
    /// Router policy: pure shape affinity, or affinity with load spill.
    pub routing: Routing,
    /// Spill threshold: the preferred shard's load score must exceed
    /// `imbalance x` the least-loaded shard's score (plus a small absolute
    /// slack) before a request leaves its affinity shard.
    pub imbalance: f64,
    /// Minimum jobs a victim's injector must hold before an idle shard
    /// steals a batch from it.
    pub steal_min: usize,
    /// Admission control: when and whether the submit path refuses work
    /// instead of queueing it (see [`AdmissionPolicy`]). The default,
    /// [`AdmissionPolicy::Unbounded`], is the pre-admission behavior:
    /// everything is accepted and the submit path takes a zero-cost early
    /// exit around all admission bookkeeping.
    pub admission: AdmissionPolicy,
    /// Online retuning: when set, a background thread watches the
    /// measured-cost telemetry for drift and hot-swaps re-tuned selectors
    /// (see [`crate::tuning`]). `None` = frozen-at-startup selector, but
    /// telemetry still accumulates and measured cost hints still apply.
    pub retune: Option<RetuneConfig>,
    /// Devsim profile cost hints (and drift predictions) are priced on.
    /// `None` (the default) derives the [`CostModel`] from the engine —
    /// a sim pool prices on the profile it serves (preserving the
    /// pre-retuning routing behavior) and a CPU pool prices on the
    /// native backend's analytic prior. Set it explicitly to the device
    /// the deployed selector was *tuned* against when that differs from
    /// the serving device: the measured-vs-predicted gap between the two
    /// is exactly the drift signal the retuner watches.
    pub pricing_profile: Option<&'static str>,
    /// Registered tenants (see [`TenantSpec`]): each gets a weighted-fair
    /// admission quota, an SLO-scaled admission policy, a metrics lane in
    /// the report, and — when pinned to a device profile — its own
    /// telemetry/retune domain. Tenant ids must be unique and non-zero
    /// (`TenantId(0)` is the anonymous default). Empty (the default)
    /// means a single-tenant pool, bit-identical to the pre-tenant
    /// behavior.
    pub tenants: Vec<TenantSpec>,
    /// Capacity (in-flight requests) the weighted-fair quotas divide.
    /// `0` (the default) disables quota accounting — except that a
    /// registered tenant with weight 0 is still always refused — and
    /// falls back to `BoundedQueue::max_inflight` when that policy is
    /// active, so quotas and the pool-wide cap share one capacity
    /// number unless overridden.
    pub quota_slots: usize,
    /// Flight-recorder tracing: when set, every request's lifecycle
    /// (submit → admission verdict → route → batch → execute →
    /// complete/shed/reject) is written into preallocated per-stripe
    /// ring buffers, exportable as `kernelsel-trace-v1` or Chrome Trace
    /// Event JSON (see [`FlightRecorder`]). `None` (the default) costs
    /// one branch per submit; enabled, the warm submit path stays
    /// zero-allocation — events are fixed-size values written in place,
    /// and a full ring drops-and-counts instead of blocking.
    pub trace: Option<TraceConfig>,
    /// Deterministic fault injection (see [`FaultPlan`]): when set, every
    /// shard wraps its backend in a [`FaultyBackend`] seeded from the
    /// plan, and the drain path verifies an output canary on every
    /// result so silent corruption surfaces as `Err`, never `Ok`.
    /// `None` (the default) skips the wrap entirely — the no-fault pool
    /// is bit-identical to one without this field.
    pub fault: Option<FaultPlan>,
    /// Variant-quarantine knobs (see [`QuarantineConfig`]): windowed
    /// failure tracking per kernel configuration, cooloff, and the
    /// half-open probation cadence. Tracking is always on — the healthy
    /// fast path is one relaxed atomic load per served request.
    pub quarantine: QuarantineConfig,
    /// Exploration (see [`ExploreConfig`]): when set and not inert, a
    /// seeded epsilon fraction of live submits is redirected to
    /// *unmeasured but shipped* configs (budget-capped, quarantine-
    /// screened, and strictly idle-capacity-only — probes are shed
    /// before any in-SLO work is refused), and the first submit of a
    /// never-seen shape bucket queues an off-hot-path micro-benchmark
    /// of the top-k prior-ranked variants. Probe measurements land in
    /// the ordinary telemetry (flagged as probes), so they persist
    /// through `--telemetry-out` and warm-start the next deployment.
    /// `None` (the default) keeps the submit path bit-identical to a
    /// pool without exploration.
    pub explore: Option<ExploreConfig>,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig {
            shards: 1,
            engine: EngineKind::default(),
            batcher: BatcherConfig::default(),
            selector_cache: 1024,
            completion_slots: 1024,
            routing: Routing::default(),
            imbalance: 4.0,
            steal_min: 2,
            admission: AdmissionPolicy::default(),
            retune: None,
            pricing_profile: None,
            tenants: Vec::new(),
            quota_slots: 0,
            trace: None,
            fault: None,
            quarantine: QuarantineConfig::default(),
            explore: None,
        }
    }
}

/// Shutdown report: per-shard metrics plus the merged pool totals.
/// Frontend counters (submit-path failures, admission rejections, the
/// in-flight peak) and retuner counters are folded into `total`.
#[derive(Clone, Debug, Default)]
pub struct PoolReport {
    /// Each shard's own metrics, in shard order.
    pub per_shard: Vec<Metrics>,
    /// Exact merge of every shard plus the frontend/tuning counters.
    pub total: Metrics,
    /// Selector-cache (hits, misses) over the pool's lifetime.
    pub cache_hits: usize,
    /// Selector-cache misses over the pool's lifetime.
    pub cache_misses: usize,
    /// Retuner counters (background thread + explicit `retune_now` calls)
    /// for the default domain; extra domains fold their counters into
    /// `total` only.
    pub tuning: RetunerStats,
    /// Per-tenant serving report, in registration order (empty for a
    /// pool without registered tenants).
    pub tenants: Vec<TenantReport>,
    /// Exploration counters (all zero when exploration was off).
    pub explore: ExploreStats,
}

/// One registered tenant's slice of the shutdown report: its goodput
/// (in-SLO completions), refusals, sheds, and latency tail — the numbers
/// that make fairness observable instead of asserted.
#[derive(Clone, Debug, Default)]
pub struct TenantReport {
    /// Raw tenant id ([`TenantId`] payload).
    pub id: u32,
    /// Registered display name.
    pub name: String,
    /// Requests served to completion.
    pub requests: usize,
    /// Served requests that finished successfully within the tenant's
    /// SLO wall (all successful completions when no wall is set).
    pub in_slo: usize,
    /// Requests refused at submit time (quota or pool admission).
    pub rejected: usize,
    /// `rejected`, split by [`RejectReason`] (indexed by
    /// [`RejectReason::code`]): quota refusals, queue-full refusals and
    /// deadline refusals each get their own cell, so "who was turned
    /// away and why" survives into the report.
    pub rejected_by_reason: [usize; REJECT_REASONS],
    /// Admitted requests shed at drain time past the queue budget.
    pub shed: usize,
    /// `shed`, split by the [`RejectReason`] the drain-side shed maps to
    /// (`queue-full` under `BoundedQueue`, `deadline-unmeetable` under
    /// `DeadlineShed`), indexed by [`RejectReason::code`].
    pub shed_by_reason: [usize; REJECT_REASONS],
    /// Peak of this tenant's own in-flight (quota) counter observed at
    /// admit time; stays 0 while quota accounting is off.
    pub inflight_peak: usize,
    /// Median end-to-end latency, milliseconds (0 when nothing served).
    pub p50_ms: f64,
    /// 99th-percentile end-to-end latency, milliseconds.
    pub p99_ms: f64,
}

impl PoolReport {
    /// Multi-line human-readable rendering (totals, then each shard).
    pub fn summary(&self) -> String {
        let mut out = format!(
            "pool: {} shard(s), selector cache {}/{} hits\n  total: {}",
            self.per_shard.len(),
            self.cache_hits,
            self.cache_hits + self.cache_misses,
            self.total.summary()
        );
        for (i, m) in self.per_shard.iter().enumerate() {
            out.push_str(&format!("\n  shard {i}: {}", m.summary()));
        }
        for t in &self.tenants {
            out.push_str(&format!(
                "\n  tenant {} ({}): requests={} in_slo={} rejected={} shed={} \
                 inflight_peak={} p50={:.2}ms p99={:.2}ms",
                t.id,
                t.name,
                t.requests,
                t.in_slo,
                t.rejected,
                t.shed,
                t.inflight_peak,
                t.p50_ms,
                t.p99_ms
            ));
            for reason in RejectReason::all() {
                let i = reason.code() as usize;
                if t.rejected_by_reason[i] > 0 || t.shed_by_reason[i] > 0 {
                    out.push_str(&format!(
                        " {}={}/{}",
                        reason.name(),
                        t.rejected_by_reason[i],
                        t.shed_by_reason[i]
                    ));
                }
            }
        }
        let ex = &self.explore;
        if ex.probes_issued > 0 || ex.probes_shed > 0 || ex.first_sight_shapes > 0 {
            out.push_str(&format!(
                "\n  explore: probes={} shed={} completed={} first_sight={} runs={}",
                ex.probes_issued,
                ex.probes_shed,
                ex.probes_completed,
                ex.first_sight_shapes,
                ex.first_sight_runs,
            ));
        }
        if self.tuning.ticks > 0 {
            out.push_str(&format!(
                "\n  tuning: swaps={} retunes={} drift_trips={} ticks={} \
                 last_drift={:.2}x generation={}",
                self.tuning.swaps,
                self.tuning.retunes,
                self.tuning.drift_trips,
                self.tuning.ticks,
                self.tuning.last_drift_deviation,
                self.tuning.generation,
            ));
        }
        out
    }
}

/// RAII admission reservation: one slot on the pool-wide in-flight
/// counter and/or one on the submitting tenant's quota counter, each
/// released exactly once when dropped. Riding on the [`Job`] itself
/// means every exit path releases them — normal completion, a shed, or
/// a panicking worker unwinding its local batcher — so a crashed shard
/// can never leak `max_inflight` or quota capacity. Both `None` for
/// anonymous traffic under a non-capping policy (no counter traffic at
/// all).
struct InflightSlot {
    /// The pool-wide in-flight reservation (inflight-capping policies).
    pool: Option<Arc<AtomicUsize>>,
    /// The submitting tenant's quota reservation (quota-enabled pools).
    tenant: Option<Arc<AtomicUsize>>,
}

impl InflightSlot {
    /// A slot holding no reservation at all (the uncounted fast path).
    fn none() -> InflightSlot {
        InflightSlot { pool: None, tenant: None }
    }

    /// A slot holding one reserved unit on `counter`'s pool-wide cap.
    fn pool(counter: Arc<AtomicUsize>) -> InflightSlot {
        InflightSlot { pool: Some(counter), tenant: None }
    }

    /// A slot holding one reserved unit on a tenant's quota counter.
    fn tenant(counter: Arc<AtomicUsize>) -> InflightSlot {
        InflightSlot { pool: None, tenant: Some(counter) }
    }

    /// Take the tenant reservation out, leaving this slot empty of it —
    /// used to fold the quota slot into the pool admission slot so one
    /// RAII value rides the job and releases both.
    fn into_tenant(mut self) -> Option<Arc<AtomicUsize>> {
        self.tenant.take()
    }
}

impl Drop for InflightSlot {
    fn drop(&mut self) {
        if let Some(counter) = self.pool.take() {
            counter.fetch_sub(1, Ordering::Release);
        }
        if let Some(counter) = self.tenant.take() {
            counter.fetch_sub(1, Ordering::Release);
        }
    }
}

struct Job {
    req: GemmRequest,
    t_submit: Instant,
    resolved: Arc<ResolvedKernel>,
    /// Cost hint frozen at submit time; the exact amount later subtracted
    /// from whichever gauge ends up owning the job.
    cost_ns: u64,
    /// True when the router sent this job off its affinity shard.
    spilled: bool,
    /// The response rendezvous: a pooled slot (or a one-shot fallback).
    /// Dropping it undelivered — a worker panic — delivers a synthetic
    /// failure, so callers never hang.
    completion: Completion,
    /// The admission reservation this job holds (see [`InflightSlot`]).
    reservation: InflightSlot,
    /// The submitting tenant ([`TenantId::ANONYMOUS`] for untagged
    /// traffic — never tracked in the per-tenant metrics lanes).
    tenant: TenantId,
    /// The tenant's SLO wall, frozen at submit: completions within it
    /// count as in-SLO goodput in the tenant's lane.
    slo_wall: Option<Duration>,
    /// The retune domain this job's measured cost feeds (0 = pool-wide).
    domain: u32,
    /// Index of the tenant's live exposition lane (`u32::MAX` for
    /// anonymous/unregistered traffic — no lane traffic at all).
    lane: u32,
    /// Flight-recorder chain id linking this job's lifecycle events
    /// (0 = recorder off or this submit sampled out).
    trace_seq: u64,
    /// True when the exploration policy redirected this request to an
    /// unmeasured shipped config: its measurement records with probe
    /// provenance and counts toward the planner's completion tally.
    probe: bool,
}

/// Index sentinel for jobs outside every tenant lane.
const NO_LANE: u32 = u32::MAX;

/// Live counters for one registered tenant, written by the serving
/// shards (drain side, never the submit path) and read lock-free by
/// [`Coordinator::metrics_text`]. The shutdown report's exact lanes live
/// in the per-shard [`Metrics`]; these exist so a metrics scrape works
/// against a *running* pool.
#[derive(Default)]
struct TenantLive {
    /// Requests served to completion.
    requests: AtomicU64,
    /// Served requests inside the tenant's SLO wall.
    in_slo: AtomicU64,
    /// Drain-time sheds by [`RejectReason::code`] index.
    shed_by: [AtomicU64; REJECT_REASONS],
    /// Log2-bucketed end-to-end latency for approximate live p50/p99.
    latency: LatencyHistogram,
}

/// Live per-shard counters mirroring the shard's thread-local [`Metrics`]
/// for the running-pool exposition: bumped with relaxed atomics on the
/// drain side (batch/complete/shed/steal), never on the submit path.
#[derive(Default)]
struct ShardLive {
    requests: AtomicU64,
    batches: AtomicU64,
    shed: AtomicU64,
    steals: AtomicU64,
    stolen_requests: AtomicU64,
    spilled: AtomicU64,
}

/// Live admission/accounting state for one registered tenant.
struct TenantState {
    spec: TenantSpec,
    /// This tenant's reserved share of the quota capacity (see
    /// [`reserved_shares`]); admission-guaranteed under overload.
    reserved: usize,
    /// The tenant's own in-flight count — reserved-then-checked on
    /// submit exactly like the pool-wide counter, released by the job's
    /// [`InflightSlot`].
    inflight: Arc<AtomicUsize>,
    /// Striped count of this tenant's submit-path refusals (quota and
    /// pool admission), folded into the tenant's lane at shutdown.
    rejected: StripedCounter,
    /// `rejected`, split by [`RejectReason::code`] index.
    rejected_by: [StripedCounter; REJECT_REASONS],
    /// Peak of `inflight` observed at admit time (quota pools only).
    inflight_peak: AtomicUsize,
    /// Position in the live-lane vector shards write into (== this
    /// tenant's registration index).
    lane: u32,
    /// The shard-written live counters for this tenant's exposition.
    live: Arc<TenantLive>,
    /// The retune domain the tenant's telemetry feeds (0 = pool-wide).
    domain: u32,
    /// The pool admission policy with its latency budgets scaled by the
    /// tenant's SLO class, precomputed at registration.
    policy: AdmissionPolicy,
}

/// One extra per-device retune domain: its own registry (independently
/// hot-swappable selector), resolution cache, telemetry sink and
/// optional background retuner. Domain 0 is the pool's own
/// registry/cache/telemetry; these are domains `1..`.
struct DomainState {
    registry: Arc<KernelRegistry>,
    cache: Arc<ResolutionCache>,
    telemetry: Arc<TelemetrySink>,
    retuner: Option<Retuner>,
    retune_stats: Arc<Mutex<RetunerStats>>,
}

/// The per-domain view a shard needs at serve time: the sink measured
/// costs record into and the device profile the timing is priced on
/// (`None` = the backend's own device). Index 0 is the default domain.
struct ShardDomain {
    telemetry: Arc<TelemetrySink>,
    device: Option<&'static str>,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    stop: Option<Sender<Metrics>>,
}

/// One shard's injector: the deque the router pushes into, the shard
/// drains from, and idle peers steal ready batches out of.
struct ShardQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    load: ShardLoad,
    /// Live exposition counters (see [`ShardLive`]).
    live: ShardLive,
    /// Cleared (via [`AliveGuard`], so panics count too) when the owning
    /// worker exits. Peers relax the steal threshold to 1 for dead queues
    /// so orphaned jobs are rescued instead of hanging their callers.
    alive: AtomicBool,
}

/// Marks the shard's queue dead when the worker leaves `shard_loop` for
/// any reason — a normal stop, a backend-init failure, or an unwind.
struct AliveGuard(Arc<ShardQueue>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.alive.store(false, Ordering::Relaxed);
        // Reset the load gauge to exactly the injector's surviving
        // backlog. Jobs the worker had pulled into its private batcher
        // die with it (their completions deliver synthetic failures as
        // the batcher unwinds — which happens before this guard drops),
        // and their gauge share would otherwise leak forever, making the
        // router score a corpse as busy. `try_lock` degrades gracefully:
        // a contended or poisoned lock skips the reset rather than
        // risking a double panic during unwind.
        if let Ok(inner) = self.0.inner.try_lock() {
            let cost = inner.jobs.iter().map(|j| j.cost_ns).sum();
            self.0.load.reset_to(inner.jobs.len(), cost);
        }
    }
}

impl ShardQueue {
    fn new() -> ShardQueue {
        ShardQueue {
            inner: Mutex::new(QueueInner {
                jobs: VecDeque::with_capacity(INJECTOR_RESERVE),
                stop: None,
            }),
            cv: Condvar::new(),
            load: ShardLoad::default(),
            live: ShardLive::default(),
            alive: AtomicBool::new(true),
        }
    }

    fn push(&self, job: Job) {
        self.load.add(1, job.cost_ns);
        let mut inner = self.inner.lock().unwrap();
        inner.jobs.push_back(job);
        drop(inner);
        self.cv.notify_one();
    }

    /// Enqueue a whole run of jobs under one lock acquisition and one
    /// load-gauge update — the `submit_many` amortization.
    fn push_batch(&self, jobs: Vec<Job>) {
        if jobs.is_empty() {
            return;
        }
        let cost: u64 = jobs.iter().map(|j| j.cost_ns).sum();
        self.load.add(jobs.len(), cost);
        let mut inner = self.inner.lock().unwrap();
        inner.jobs.extend(jobs);
        drop(inner);
        self.cv.notify_one();
    }

    fn signal_stop(&self, reply: Sender<Metrics>) {
        let mut inner = self.inner.lock().unwrap();
        inner.stop = Some(reply);
        drop(inner);
        self.cv.notify_one();
    }
}

/// Frontend counters bumped on client threads at submit time: striped /
/// atomic cells instead of a `Mutex<Metrics>`, because the submit path
/// must not take a pool-global lock. Folded into the pool totals at
/// shutdown.
#[derive(Default)]
struct FrontCounters {
    /// Requests that failed before reaching a shard (resolution failures,
    /// dead pool).
    failures: StripedCounter,
    /// Requests refused by the admission policy (no slot, no shard).
    rejected: StripedCounter,
    /// `rejected`, split by [`RejectReason::code`] index — the live
    /// per-reason view the metrics exposition renders.
    rejected_by: [StripedCounter; REJECT_REASONS],
    /// Peak pool-wide in-flight count observed at admit time. Only
    /// maintained while a bounding admission policy is active — the
    /// `Unbounded` fast path must not scan gauges per submit.
    inflight_peak: AtomicUsize,
    /// Selector hot-swaps published via `swap_selector` (the background
    /// retuner counts its own swaps in [`RetunerStats`]).
    selector_swaps: AtomicUsize,
    /// Retries spent from the retry budget by `call_with_retry`.
    retries: StripedCounter,
    /// Retries refused because the budget was below its shed threshold.
    retries_denied: StripedCounter,
    /// Dead shard workers respawned by the supervisor.
    respawns: AtomicUsize,
}

/// Handle to a running executor pool.
///
/// The 60-second tour (the `quickstart` example as a doc-test): start a
/// pool — a missing artifacts directory falls back to the synthetic
/// deployment served by the pure-Rust [`SimBackend`], so this runs
/// anywhere — submit a GEMM, check the result, read the report.
///
/// ```
/// use std::path::PathBuf;
/// use kernelsel::coordinator::{Coordinator, PoolConfig, SelectorPolicy};
/// use kernelsel::dataset::GemmShape;
///
/// let coord = Coordinator::start_pool(
///     PathBuf::from("artifacts"), // missing dir -> synthetic deployment
///     SelectorPolicy::Xla,        // serve via the XLA-dot comparator
///     PoolConfig { shards: 2, ..PoolConfig::default() },
/// )
/// .expect("pool start");
///
/// let shape = GemmShape::new(64, 64, 64, 1); // (m, k, n, batch)
/// let ticket = coord.submit(shape, vec![1.0; 64 * 64], vec![1.0; 64 * 64]);
/// let resp = ticket.wait();
/// let out = resp.result.expect("gemm result");
/// assert_eq!(out.len(), 64 * 64);
/// assert_eq!(out[0], 64.0); // all-ones GEMM: every cell is k
///
/// let report = coord.stop_detailed();
/// assert_eq!(report.total.requests, 1);
/// assert_eq!(report.total.failures, 0);
/// ```
///
/// [`SimBackend`]: crate::engine::SimBackend
pub struct Coordinator {
    registry: Arc<KernelRegistry>,
    cache: Arc<ResolutionCache>,
    telemetry: Arc<TelemetrySink>,
    /// Reusable completion slots for in-flight requests.
    completions: Arc<CompletionPool>,
    /// Background retuner (when `PoolConfig::retune` was set).
    retuner: Option<Retuner>,
    /// Single store for all retuner counters — the background thread and
    /// explicit `retune_now` calls accumulate into the same place.
    retune_stats: Arc<Mutex<RetunerStats>>,
    queues: Arc<Vec<Arc<ShardQueue>>>,
    /// Worker handles, mutex-wrapped so the supervisor can swap a dead
    /// worker's handle for its replacement's from any submitting thread.
    /// Never locked on the submit fast path — liveness reads go through
    /// the queues' lock-free `alive` flags.
    workers: Mutex<Vec<Option<JoinHandle<()>>>>,
    /// Striped frontend counters (requests that never reach a shard, plus
    /// explicit swap counts); folded into the totals at shutdown.
    front: FrontCounters,
    /// Pool-wide in-flight reservation counter, maintained only under an
    /// inflight-capping admission policy: admission *reserves* a slot
    /// with one `fetch_add` before deciding (undone on reject), and the
    /// job carries an RAII [`InflightSlot`] that releases it exactly once
    /// — on completion, on shed, or when a panicking worker unwinds — so
    /// `max_inflight` is a real cap even under concurrent submitters,
    /// and a crashed shard can never leak capacity.
    inflight: Arc<AtomicUsize>,
    engine_name: &'static str,
    routing: Routing,
    imbalance: f64,
    admission: AdmissionPolicy,
    /// Registered tenants in registration order; indexed through
    /// `tenant_index`. The quota gate scans this vector (tiny T) for the
    /// peers' unused reservations.
    tenants: Vec<TenantState>,
    /// Raw tenant id -> index into `tenants`.
    tenant_index: HashMap<u32, usize>,
    /// Per-device retune domains beyond the default (domain `d` lives at
    /// `extra_domains[d - 1]`; domain 0 is the coordinator's own
    /// registry/cache/telemetry).
    extra_domains: Vec<DomainState>,
    /// Capacity the weighted-fair tenant quotas divide (0 = quotas off).
    quota_slots: usize,
    /// Flight recorder (None = tracing off, one branch per submit).
    recorder: Option<Arc<FlightRecorder>>,
    /// Per-domain online selection-regret estimators, advanced by each
    /// [`Coordinator::metrics_text`] scrape.
    regret: Mutex<Vec<RegretEstimator>>,
    /// The typed reason drain-side sheds are attributed to (derived from
    /// the admission policy at startup).
    shed_reason: RejectReason,
    /// The pool-wide variant circuit breaker every domain's registry and
    /// cache consult (see [`QuarantineSet`]).
    quarantine: Arc<QuarantineSet>,
    /// Token bucket bounding `call_with_retry`: retries shed first under
    /// load, so they can never amplify overload.
    retry_budget: RetryBudget,
    /// Exploration planner (`None` = exploration off or inert; the
    /// submit path then takes a zero-cost early exit around it).
    explore: Option<Arc<ExplorePlanner>>,
    /// The first-sight micro-benchmark worker (armed with `explore`;
    /// dropping the coordinator closes its channel and joins it).
    seeder: Option<FirstSightSeeder>,
    /// Everything `maybe_respawn` needs to spawn a replacement worker on
    /// a dead shard's existing queue.
    respawn: RespawnSpec,
}

/// The construction inputs `start_pool` gave the original shard workers,
/// retained so the supervisor can respawn a replacement on the same
/// queue after a worker dies.
struct RespawnSpec {
    artifacts_dir: PathBuf,
    engine: EngineKind,
    batcher: BatcherConfig,
    steal_min: usize,
    queue_budget: Option<Duration>,
    domains: Arc<Vec<ShardDomain>>,
    lanes: Arc<Vec<Arc<TenantLive>>>,
    fault: Option<FaultPlan>,
    explore: Option<Arc<ExplorePlanner>>,
}

/// The first-sight micro-benchmark worker: a dedicated thread owning its
/// own backend instance. The first submit of a never-seen shape bucket
/// sends the shape here; the worker times the top-k prior-ranked healthy
/// variants once, off the hot path, and records the measurements into
/// the default domain's telemetry with probe provenance — so the
/// selector's answer for a new bucket is backed by data before the
/// retuner next trains. Dropping the handle closes the channel and joins
/// the thread.
struct FirstSightSeeder {
    tx: Option<Sender<GemmShape>>,
    worker: Option<JoinHandle<()>>,
}

impl FirstSightSeeder {
    /// Queue `shape` for a first-sight sweep (never blocks; a dead
    /// worker just drops the send).
    fn send(&self, shape: GemmShape) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(shape);
        }
    }
}

impl Drop for FirstSightSeeder {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(worker) = self.worker.take() {
            let _ = worker.join();
        }
    }
}

/// Spawn the first-sight worker (see [`FirstSightSeeder`]). A backend
/// that fails to construct (or a thread that fails to spawn) disables
/// first-sight seeding rather than failing the pool — coverage is an
/// optimization, never a liveness dependency.
fn start_seeder(
    artifacts_dir: &Path,
    engine: &EngineKind,
    registry: Arc<KernelRegistry>,
    telemetry: Arc<TelemetrySink>,
    planner: Arc<ExplorePlanner>,
    model: CostModel,
) -> Option<FirstSightSeeder> {
    let mut backend = engine.create(artifacts_dir).ok()?;
    let (tx, rx) = channel::<GemmShape>();
    let worker = std::thread::Builder::new()
        .name("kernelsel-first-sight".to_string())
        .spawn(move || {
            let top_k = planner.config().top_k;
            while let Ok(shape) = rx.recv() {
                // All-ones operands: cheap to build, and the measured
                // time of a GEMM does not depend on operand values.
                let lhs = vec![1.0f32; shape.batch * shape.m * shape.k];
                let rhs = vec![1.0f32; shape.batch * shape.k * shape.n];
                for config in rank_by_prior(&registry, &model, &shape, top_k) {
                    // "Once": a variant the sink already prices from real
                    // measurements — earlier in this run or restored from
                    // a warm-start snapshot — is never re-benchmarked.
                    if telemetry.measured_cost_secs(&shape, Some(config)).is_some() {
                        continue;
                    }
                    let Some(meta) = registry.manifest.find_matmul(
                        Some(config),
                        shape.m,
                        shape.k,
                        shape.n,
                        shape.batch,
                    ) else {
                        continue;
                    };
                    let meta = meta.clone();
                    if backend.prepare(&meta).is_err() {
                        continue;
                    }
                    if let Ok((_, secs)) =
                        backend.execute_timed_for(&meta, &shape, &lhs, &rhs, None)
                    {
                        telemetry.record_probe(shape, meta.config_index, secs);
                        planner.note_first_sight_run();
                    }
                }
            }
        })
        .ok()?;
    Some(FirstSightSeeder { tx: Some(tx), worker: Some(worker) })
}

/// The synthetic response for a request rejected on the submit path.
fn failure_response(error: String, t_submit: Instant) -> GemmResponse {
    GemmResponse {
        result: Err(error),
        config_used: None,
        artifact: Arc::from(""),
        latency: t_submit.elapsed(),
    }
}

impl Coordinator {
    /// Start a single-shard pool with the default engine — the SimBackend,
    /// or (with the `pjrt` feature) still the SimBackend; pass an explicit
    /// [`PoolConfig`] to `start_pool` for native execution.
    pub fn start(
        artifacts_dir: PathBuf,
        policy: SelectorPolicy,
        batcher_cfg: BatcherConfig,
    ) -> Result<Coordinator, String> {
        Coordinator::start_pool(
            artifacts_dir,
            policy,
            PoolConfig { batcher: batcher_cfg, ..PoolConfig::default() },
        )
    }

    /// Start the executor pool: N shard threads, each constructing its own
    /// backend instance and reporting readiness before requests flow.
    pub fn start_pool(
        artifacts_dir: PathBuf,
        policy: SelectorPolicy,
        cfg: PoolConfig,
    ) -> Result<Coordinator, String> {
        // The SimBackend reads no artifacts, so a missing manifest falls
        // back to the synthetic deployment; the CPU backend falls back to
        // the synthetic deployment of its own variant family; PJRT needs
        // real artifacts.
        #[cfg(feature = "pjrt")]
        let manifest = match &cfg.engine {
            EngineKind::Sim { .. } | EngineKind::SimPaced { .. } => {
                Manifest::load_or_synthetic(&artifacts_dir)
            }
            EngineKind::Cpu { .. } => {
                Manifest::load(&artifacts_dir).unwrap_or_else(|_| Manifest::synthetic_cpu())
            }
            EngineKind::Pjrt => Manifest::load(&artifacts_dir)?,
        };
        #[cfg(not(feature = "pjrt"))]
        let manifest = match &cfg.engine {
            EngineKind::Cpu { .. } => {
                Manifest::load(&artifacts_dir).unwrap_or_else(|_| Manifest::synthetic_cpu())
            }
            _ => Manifest::load_or_synthetic(&artifacts_dir),
        };

        // Cost model for dispatch hints and drift predictions: an
        // explicit profile override wins, else it derives from the engine
        // — sim pools price on the profile they serve, the native CPU
        // backend prices on its analytic prior, PJRT defaults to the
        // repo's reference tuning device.
        let model = match cfg.pricing_profile {
            Some(name) => CostModel::devsim(name),
            None => match &cfg.engine {
                EngineKind::Sim { profile } | EngineKind::SimPaced { profile, .. } => {
                    CostModel::devsim(profile)
                }
                EngineKind::Cpu { .. } => CostModel::CpuAnalytic,
                #[cfg(feature = "pjrt")]
                EngineKind::Pjrt => CostModel::devsim("i7-6700k"),
            },
        };

        let n_shards = cfg.shards.max(1);
        // Resolve the CPU engine's thread budget up front: 0 means "one
        // worker per available core", divided across the shards so a
        // multi-shard pool does not oversubscribe the host.
        let mut engine_spec = cfg.engine.clone();
        if let EngineKind::Cpu { threads } = &mut engine_spec {
            if *threads == 0 {
                let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
                *threads = (cores / n_shards).max(1);
            }
        }

        // Per-device retune domains: tenants pinned to a device profile
        // share one domain per distinct profile; everyone else (and all
        // anonymous traffic) stays in domain 0, the pool's own
        // registry/cache/telemetry — so a pool without pinned tenants
        // builds nothing extra. Each extra domain gets its own registry
        // (cloned manifest + boot policy, independently hot-swappable)
        // and telemetry sink now; caches and retuners follow below.
        let mut domain_devices: Vec<&'static str> = Vec::new();
        let mut domain_of_device: HashMap<&'static str, u32> = HashMap::new();
        for spec in &cfg.tenants {
            if let Some(device) = spec.device {
                domain_of_device.entry(device).or_insert_with(|| {
                    domain_devices.push(device);
                    domain_devices.len() as u32
                });
            }
        }
        // One pool-wide quarantine set: every domain's registry and cache
        // consult the same circuit breaker, so a variant tripped by one
        // tenant's failures stops being served to everyone.
        let quarantine = Arc::new(QuarantineSet::new(cfg.quarantine));
        let domain_registries: Vec<Arc<KernelRegistry>> = domain_devices
            .iter()
            .map(|_| {
                Arc::new(
                    KernelRegistry::new(manifest.clone(), policy.clone())
                        .with_quarantine(quarantine.clone()),
                )
            })
            .collect();
        let domain_sinks: Vec<Arc<TelemetrySink>> =
            domain_devices.iter().map(|_| Arc::new(TelemetrySink::default())).collect();

        // Weighted-fair quota capacity: an explicit `quota_slots` wins,
        // else the BoundedQueue in-flight cap doubles as the quota
        // capacity, else quotas are off (weight-0 tenants still always
        // reject — that gate is capacity-independent).
        let quota_slots = if cfg.quota_slots > 0 {
            cfg.quota_slots
        } else if let AdmissionPolicy::BoundedQueue { max_inflight, .. } = cfg.admission {
            max_inflight
        } else {
            0
        };
        let weights: Vec<u32> = cfg.tenants.iter().map(|t| t.weight).collect();
        let shares = reserved_shares(&weights, quota_slots);
        let mut tenants: Vec<TenantState> = Vec::with_capacity(cfg.tenants.len());
        let mut tenant_index: HashMap<u32, usize> = HashMap::with_capacity(cfg.tenants.len());
        for (spec, &reserved) in cfg.tenants.iter().zip(&shares) {
            if spec.id.is_anonymous() {
                return Err("tenant id 0 is reserved for anonymous traffic".to_string());
            }
            if tenant_index.insert(spec.id.0, tenants.len()).is_some() {
                return Err(format!("duplicate tenant id {}", spec.id.0));
            }
            tenants.push(TenantState {
                reserved,
                inflight: Arc::new(AtomicUsize::new(0)),
                rejected: StripedCounter::new(),
                rejected_by: Default::default(),
                inflight_peak: AtomicUsize::new(0),
                lane: tenants.len() as u32,
                live: Arc::new(TenantLive::default()),
                domain: spec.device.map_or(0, |d| domain_of_device[d]),
                policy: cfg.admission.for_slo_factor(spec.slo.deadline_factor()),
                spec: spec.clone(),
            });
        }
        // The live tenant lanes the shards write, in registration order.
        let lanes: Arc<Vec<Arc<TenantLive>>> =
            Arc::new(tenants.iter().map(|t| t.live.clone()).collect());
        // Every drain-side shed is attributed to the reason the active
        // policy's budget maps to (only `BoundedQueue` sheds today, but
        // the mapping keeps the trace/report stable if that changes).
        let shed_reason = match cfg.admission {
            AdmissionPolicy::DeadlineShed { .. } => RejectReason::DeadlineUnmeetable,
            _ => RejectReason::QueueFull,
        };
        let n_domains = 1 + domain_devices.len();
        let recorder = cfg
            .trace
            .map(|trace_cfg| Arc::new(FlightRecorder::new(trace_cfg, n_domains)));

        let registry =
            Arc::new(KernelRegistry::new(manifest, policy).with_quarantine(quarantine.clone()));
        let telemetry = Arc::new(TelemetrySink::default());
        let shard_domains: Arc<Vec<ShardDomain>> = Arc::new(
            std::iter::once(ShardDomain { telemetry: telemetry.clone(), device: None })
                .chain(domain_devices.iter().zip(&domain_sinks).map(|(&device, sink)| {
                    ShardDomain { telemetry: sink.clone(), device: Some(device) }
                }))
                .collect(),
        );
        let inflight = Arc::new(AtomicUsize::new(0));
        // Exploration is armed only by a non-inert config: the planner is
        // shared by the submit path (epsilon redirect + first-sight
        // detection), the shards (probe completion accounting) and the
        // first-sight worker. An absent or inert config keeps the submit
        // path bit-identical to a pool without exploration.
        let explore = cfg
            .explore
            .filter(|e| !e.is_inert())
            .map(|e| Arc::new(ExplorePlanner::new(e)));
        let seeder = explore.as_ref().and_then(|planner| {
            start_seeder(
                &artifacts_dir,
                &engine_spec,
                registry.clone(),
                telemetry.clone(),
                planner.clone(),
                model,
            )
        });
        let queues: Arc<Vec<Arc<ShardQueue>>> =
            Arc::new((0..n_shards).map(|_| Arc::new(ShardQueue::new())).collect());
        // The shed budget is wall-clock wait since submit, which includes
        // the batcher's *deliberate* max_wait batching delay — a budget
        // below it would shed underfull traffic on an idle pool. Clamp so
        // only time beyond the intended batching window (with slack for
        // the batch then being served) ever counts as overload.
        let queue_budget =
            cfg.admission.queue_budget().map(|b| b.max(cfg.batcher.max_wait * 2));
        let mut workers: Vec<Option<JoinHandle<()>>> = Vec::with_capacity(n_shards);
        for shard_id in 0..n_shards {
            let (ready_tx, ready_rx) = channel::<Result<(), String>>();
            let engine = engine_spec.clone();
            let batcher_cfg = cfg.batcher.clone();
            let dir = artifacts_dir.clone();
            let queues_for_shard = queues.clone();
            let steal_min = cfg.steal_min.max(1);
            let domains_for_shard = shard_domains.clone();
            let recorder_for_shard = recorder.clone();
            let lanes_for_shard = lanes.clone();
            let quarantine_for_shard = quarantine.clone();
            let explore_for_shard = explore.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("kernelsel-shard-{shard_id}"))
                .spawn(move || {
                    shard_loop(
                        shard_id,
                        dir,
                        engine,
                        batcher_cfg,
                        queues_for_shard,
                        steal_min,
                        queue_budget,
                        domains_for_shard,
                        ShardSide {
                            recorder: recorder_for_shard,
                            lanes: lanes_for_shard,
                            shed_reason,
                            quarantine: quarantine_for_shard,
                            fault: cfg.fault,
                            explore: explore_for_shard,
                        },
                        ready_tx,
                    )
                })
                .map_err(|e| e.to_string());
            let readiness = match spawned {
                Ok(worker) => {
                    workers.push(Some(worker));
                    ready_rx
                        .recv()
                        .map_err(|_| format!("shard {shard_id} died during startup"))
                        .and_then(|r| r.map_err(|e| format!("shard {shard_id}: {e}")))
                }
                Err(e) => Err(e),
            };
            if let Err(e) = readiness {
                // Stop and join the shards that did start; otherwise they
                // idle-poll forever on queues nobody will ever use.
                shutdown_workers(&queues, &mut workers);
                return Err(e);
            }
        }
        let cache = Arc::new(
            ResolutionCache::with_model(cfg.selector_cache, model)
                .with_telemetry(telemetry.clone())
                .with_quarantine(quarantine.clone()),
        );
        let retune_stats = Arc::new(Mutex::new(RetunerStats::default()));
        let retuner = cfg.retune.clone().map(|retune_cfg| {
            Retuner::start(
                retune_cfg,
                registry.clone(),
                cache.clone(),
                telemetry.clone(),
                retune_stats.clone(),
            )
        });
        // Extra domains keep the POOL's cost model, not their pinned
        // device's: the gap between that prediction and the domain's
        // measured telemetry is exactly the drift signal that trips a
        // per-domain retune.
        let extra_domains: Vec<DomainState> = domain_registries
            .into_iter()
            .zip(domain_sinks)
            .map(|(domain_registry, sink)| {
                let domain_cache = Arc::new(
                    ResolutionCache::with_model(cfg.selector_cache, model)
                        .with_telemetry(sink.clone())
                        .with_quarantine(quarantine.clone()),
                );
                let stats = Arc::new(Mutex::new(RetunerStats::default()));
                let domain_retuner = cfg.retune.clone().map(|retune_cfg| {
                    Retuner::start(
                        retune_cfg,
                        domain_registry.clone(),
                        domain_cache.clone(),
                        sink.clone(),
                        stats.clone(),
                    )
                });
                DomainState {
                    registry: domain_registry,
                    cache: domain_cache,
                    telemetry: sink,
                    retuner: domain_retuner,
                    retune_stats: stats,
                }
            })
            .collect();
        Ok(Coordinator {
            registry,
            cache,
            telemetry,
            completions: CompletionPool::new(cfg.completion_slots),
            retuner,
            retune_stats,
            queues,
            workers: Mutex::new(workers),
            front: FrontCounters::default(),
            inflight,
            engine_name: cfg.engine.name(),
            routing: cfg.routing,
            imbalance: cfg.imbalance.max(1.0),
            admission: cfg.admission,
            tenants,
            tenant_index,
            extra_domains,
            quota_slots,
            recorder,
            regret: Mutex::new((0..n_domains).map(|_| RegretEstimator::default()).collect()),
            shed_reason,
            quarantine,
            retry_budget: RetryBudget::default(),
            explore: explore.clone(),
            seeder,
            respawn: RespawnSpec {
                artifacts_dir,
                engine: engine_spec,
                batcher: cfg.batcher,
                steal_min: cfg.steal_min.max(1),
                queue_budget,
                domains: shard_domains,
                lanes,
                fault: cfg.fault,
                explore,
            },
        })
    }

    /// Number of executor shards (worker threads).
    pub fn n_shards(&self) -> usize {
        self.queues.len()
    }

    /// Name of the backend every shard runs.
    pub fn engine_name(&self) -> &'static str {
        self.engine_name
    }

    /// The router policy this pool was started with.
    pub fn routing(&self) -> Routing {
        self.routing
    }

    /// The admission policy this pool was started with.
    pub fn admission(&self) -> AdmissionPolicy {
        self.admission
    }

    /// The kernel registry resolving requests to shipped artifacts.
    pub fn registry(&self) -> &KernelRegistry {
        &self.registry
    }

    /// Selector-cache (hits, misses) so far.
    pub fn selector_cache_stats(&self) -> (usize, usize) {
        self.cache.stats()
    }

    /// The measured-cost telemetry sink the shards report into.
    pub fn telemetry(&self) -> &Arc<TelemetrySink> {
        &self.telemetry
    }

    /// Generation of the currently deployed selector (0 = boot policy).
    pub fn selector_generation(&self) -> u64 {
        self.registry.generation()
    }

    /// Hot-swap the selector policy under traffic: in-flight requests keep
    /// the snapshot they resolved under, new requests see only the new
    /// deployment, and stale selector-cache entries are invalidated.
    /// Returns the new generation.
    pub fn swap_selector(&self, policy: SelectorPolicy) -> u64 {
        let generation = deploy_policy(&self.registry, &self.cache, policy);
        self.front.selector_swaps.fetch_add(1, Ordering::Relaxed);
        if let Some(rec) = self.recorder.as_deref() {
            rec.note_generation(0, generation);
        }
        generation
    }

    /// Run one synchronous retune attempt against the live telemetry (the
    /// deterministic alternative to the background thread — benches drive
    /// explicit measure/retune/measure cycles with it).
    pub fn retune_now(&self, cfg: &RetuneConfig) -> RetuneOutcome {
        let mut stats = self.retune_stats.lock().unwrap();
        retune_once(cfg, true, &self.registry, &self.cache, &self.telemetry, &mut stats)
    }

    /// Retuner counters so far (background thread + `retune_now`; swaps
    /// made via [`Coordinator::swap_selector`] are counted in the pool
    /// metrics, not here).
    pub fn retune_stats(&self) -> RetunerStats {
        self.retune_stats.lock().unwrap().clone()
    }

    /// How many retune domains this pool serves: 1 (the pool-wide
    /// default) plus one per distinct device profile its registered
    /// tenants are pinned to.
    pub fn domain_count(&self) -> usize {
        1 + self.extra_domains.len()
    }

    /// The retune domain `tenant`'s telemetry feeds (0 for unregistered
    /// and unpinned tenants — the pool-wide domain).
    pub fn tenant_domain(&self, tenant: TenantId) -> u32 {
        self.tenant_state(tenant).map_or(0, |s| s.domain)
    }

    /// The telemetry sink of retune domain `domain`.
    ///
    /// # Panics
    /// Panics when `domain >= domain_count()`.
    pub fn domain_telemetry(&self, domain: u32) -> &Arc<TelemetrySink> {
        match domain {
            0 => &self.telemetry,
            d => &self.extra_domains[d as usize - 1].telemetry,
        }
    }

    /// The registry of retune domain `domain` (its independently
    /// hot-swappable deployed selector).
    ///
    /// # Panics
    /// Panics when `domain >= domain_count()`.
    pub fn domain_registry(&self, domain: u32) -> &Arc<KernelRegistry> {
        self.domain_handles(domain).0
    }

    /// Selector generation of retune domain `domain`.
    ///
    /// # Panics
    /// Panics when `domain >= domain_count()`.
    pub fn domain_generation(&self, domain: u32) -> u64 {
        self.domain_handles(domain).0.generation()
    }

    /// [`Coordinator::retune_now`] against one retune domain's own
    /// registry/cache/telemetry (domain 0 = the pool-wide default).
    ///
    /// # Panics
    /// Panics when `domain >= domain_count()`.
    pub fn retune_domain_now(&self, domain: u32, cfg: &RetuneConfig) -> RetuneOutcome {
        match domain {
            0 => self.retune_now(cfg),
            d => {
                let state = &self.extra_domains[d as usize - 1];
                let mut stats = state.retune_stats.lock().unwrap();
                retune_once(
                    cfg,
                    true,
                    &state.registry,
                    &state.cache,
                    &state.telemetry,
                    &mut stats,
                )
            }
        }
    }

    /// A registered tenant's reserved quota share (admission-guaranteed
    /// slots); `None` for unregistered ids.
    pub fn tenant_reserved(&self, tenant: TenantId) -> Option<usize> {
        self.tenant_state(tenant).map(|s| s.reserved)
    }

    /// Live per-shard (queue depth, load score ns) snapshot.
    pub fn shard_loads(&self) -> Vec<(usize, u64)> {
        self.queues
            .iter()
            .map(|q| (q.load.depth(), q.load.score_ns()))
            .collect()
    }

    /// The flight recorder, when tracing was enabled at startup via
    /// [`PoolConfig::trace`] — export its ring contents with
    /// [`FlightRecorder::to_json`] (`kernelsel-trace-v1`) or
    /// [`FlightRecorder::to_chrome_json`] (Chrome Trace Event Format).
    pub fn recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.recorder.as_ref()
    }

    /// Render the live Prometheus-style text exposition: per-shard
    /// gauges and counters, per-tenant lanes (with approximate live
    /// latency quantiles), admission refusals by typed reason, retune /
    /// drift / generation counters per domain, the online selection
    /// regret, and — when tracing is on — the recorder's own counters.
    ///
    /// Reads only lock-free live state (plus the retuner's stats mutex
    /// and a telemetry snapshot per domain for the regret estimate), so
    /// it is safe to scrape a loaded pool; it never blocks the submit
    /// path. Counters here settle to the shutdown report's exact values
    /// once in-flight work drains — asserted by the
    /// `exposition_agrees_with_shutdown_report` test.
    ///
    /// Each scrape also advances the per-domain [`RegretEstimator`]:
    /// the `kernelsel_selection_regret` gauge is an EWMA over scrape
    /// evaluations, `kernelsel_selection_regret_raw` the current
    /// geomean chosen-vs-best ratio (1.0 = measured-optimal).
    pub fn metrics_text(&self) -> String {
        let mut out = String::with_capacity(4096);
        // Pool-level.
        prom_family(&mut out, "kernelsel_pool_shards", "gauge", "Executor shards serving.");
        prom_sample(&mut out, "kernelsel_pool_shards", "", self.queues.len() as f64);
        prom_family(
            &mut out,
            "kernelsel_pool_inflight",
            "gauge",
            "Pool-wide in-flight reservations (0 unless a capping policy runs).",
        );
        prom_sample(
            &mut out,
            "kernelsel_pool_inflight",
            "",
            self.inflight.load(Ordering::Relaxed) as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_pool_inflight_peak",
            "gauge",
            "Peak pool-wide in-flight count observed at admit time.",
        );
        prom_sample(
            &mut out,
            "kernelsel_pool_inflight_peak",
            "",
            self.front.inflight_peak.load(Ordering::Relaxed) as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_pool_submit_failures_total",
            "counter",
            "Requests failed before reaching a shard (resolution errors, dead pool).",
        );
        prom_sample(
            &mut out,
            "kernelsel_pool_submit_failures_total",
            "",
            self.front.failures.sum() as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_pool_rejected_total",
            "counter",
            "Admission refusals by typed reason.",
        );
        for reason in RejectReason::all() {
            prom_sample(
                &mut out,
                "kernelsel_pool_rejected_total",
                &format!("reason=\"{}\"", reason.name()),
                self.front.rejected_by[reason.code() as usize].sum() as f64,
            );
        }
        // Per-domain selector / cache / tuning counters.
        prom_family(
            &mut out,
            "kernelsel_cache_hits_total",
            "counter",
            "Selector-cache hits per retune domain.",
        );
        prom_family(
            &mut out,
            "kernelsel_cache_misses_total",
            "counter",
            "Selector-cache misses per retune domain.",
        );
        prom_family(
            &mut out,
            "kernelsel_selector_generation",
            "gauge",
            "Deployed selector generation per retune domain (0 = boot policy).",
        );
        for d in 0..self.domain_count() as u32 {
            let (registry, cache) = self.domain_handles(d);
            let (hits, misses) = cache.stats();
            let label = format!("domain=\"{d}\"");
            prom_sample(&mut out, "kernelsel_cache_hits_total", &label, hits as f64);
            prom_sample(&mut out, "kernelsel_cache_misses_total", &label, misses as f64);
            prom_sample(
                &mut out,
                "kernelsel_selector_generation",
                &label,
                registry.generation() as f64,
            );
        }
        prom_family(
            &mut out,
            "kernelsel_retunes_total",
            "counter",
            "Full selection reruns on measured data, per domain.",
        );
        prom_family(
            &mut out,
            "kernelsel_selector_swaps_total",
            "counter",
            "Selector hot-swaps (retuner + explicit), per domain.",
        );
        prom_family(
            &mut out,
            "kernelsel_drift_trips_total",
            "counter",
            "Retune ticks where the drift detector tripped, per domain.",
        );
        prom_family(
            &mut out,
            "kernelsel_retune_ticks_total",
            "counter",
            "Retune attempts (timer ticks + explicit), per domain.",
        );
        prom_family(
            &mut out,
            "kernelsel_drift_deviation",
            "gauge",
            "Worst measured/predicted drift deviation on the last retune tick.",
        );
        for d in 0..self.domain_count() {
            let stats = match d {
                0 => self.retune_stats.lock().unwrap().clone(),
                n => self.extra_domains[n - 1].retune_stats.lock().unwrap().clone(),
            };
            // Manual `swap_selector` calls act on the default domain.
            let manual_swaps =
                if d == 0 { self.front.selector_swaps.load(Ordering::Relaxed) } else { 0 };
            let label = format!("domain=\"{d}\"");
            prom_sample(&mut out, "kernelsel_retunes_total", &label, stats.retunes as f64);
            prom_sample(
                &mut out,
                "kernelsel_selector_swaps_total",
                &label,
                (stats.swaps + manual_swaps) as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_drift_trips_total",
                &label,
                stats.drift_trips as f64,
            );
            prom_sample(&mut out, "kernelsel_retune_ticks_total", &label, stats.ticks as f64);
            prom_sample(
                &mut out,
                "kernelsel_drift_deviation",
                &label,
                stats.last_drift_deviation,
            );
        }
        // Online selection regret, per domain.
        prom_family(
            &mut out,
            "kernelsel_selection_regret",
            "gauge",
            "EWMA of the geomean chosen-vs-best-measured cost ratio (1.0 = optimal).",
        );
        prom_family(
            &mut out,
            "kernelsel_selection_regret_raw",
            "gauge",
            "Current geomean chosen-vs-best-measured cost ratio.",
        );
        prom_family(
            &mut out,
            "kernelsel_selection_regret_shapes",
            "gauge",
            "Shapes with >= 2 measured variants backing the regret estimate.",
        );
        {
            let mut estimators = self.regret.lock().unwrap();
            for d in 0..self.domain_count() {
                let snapshot = self.domain_telemetry(d as u32).snapshot();
                let report = evaluate_regret(
                    &snapshot,
                    self.domain_registry(d as u32),
                    REGRET_MIN_CELL_SAMPLES,
                );
                let smoothed = estimators[d].observe(&report);
                let label = format!("domain=\"{d}\"");
                prom_sample(&mut out, "kernelsel_selection_regret", &label, smoothed);
                prom_sample(
                    &mut out,
                    "kernelsel_selection_regret_raw",
                    &label,
                    report.geomean,
                );
                prom_sample(
                    &mut out,
                    "kernelsel_selection_regret_shapes",
                    &label,
                    report.comparable_shapes as f64,
                );
            }
        }
        // Per-shard lanes.
        prom_family(
            &mut out,
            "kernelsel_shard_queue_depth",
            "gauge",
            "Requests owned by the shard.",
        );
        prom_family(&mut out, "kernelsel_shard_load_ns", "gauge", "Shard load-gauge score (ns).");
        prom_family(
            &mut out,
            "kernelsel_shard_drain_rate",
            "gauge",
            "Measured drain rate (completions/s EWMA; 0 until warm).",
        );
        prom_family(&mut out, "kernelsel_shard_requests_total", "counter", "Requests served.");
        prom_family(&mut out, "kernelsel_shard_batches_total", "counter", "Batches drained.");
        prom_family(&mut out, "kernelsel_shard_shed_total", "counter", "Jobs shed at drain time.");
        prom_family(
            &mut out,
            "kernelsel_shard_steals_total",
            "counter",
            "Batches stolen from peers.",
        );
        prom_family(
            &mut out,
            "kernelsel_shard_stolen_requests_total",
            "counter",
            "Requests arriving via stolen batches.",
        );
        prom_family(
            &mut out,
            "kernelsel_shard_spilled_total",
            "counter",
            "Served requests routed off their affinity shard.",
        );
        for (i, q) in self.queues.iter().enumerate() {
            let label = format!("shard=\"{i}\"");
            prom_sample(&mut out, "kernelsel_shard_queue_depth", &label, q.load.depth() as f64);
            prom_sample(&mut out, "kernelsel_shard_load_ns", &label, q.load.score_ns() as f64);
            prom_sample(
                &mut out,
                "kernelsel_shard_drain_rate",
                &label,
                q.load.drain_rate_per_sec(),
            );
            let live = &q.live;
            prom_sample(
                &mut out,
                "kernelsel_shard_requests_total",
                &label,
                live.requests.load(Ordering::Relaxed) as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_shard_batches_total",
                &label,
                live.batches.load(Ordering::Relaxed) as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_shard_shed_total",
                &label,
                live.shed.load(Ordering::Relaxed) as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_shard_steals_total",
                &label,
                live.steals.load(Ordering::Relaxed) as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_shard_stolen_requests_total",
                &label,
                live.stolen_requests.load(Ordering::Relaxed) as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_shard_spilled_total",
                &label,
                live.spilled.load(Ordering::Relaxed) as f64,
            );
        }
        // Per-tenant lanes.
        if !self.tenants.is_empty() {
            prom_family(
                &mut out,
                "kernelsel_tenant_requests_total",
                "counter",
                "Requests served to completion per tenant.",
            );
            prom_family(
                &mut out,
                "kernelsel_tenant_in_slo_total",
                "counter",
                "Served requests inside the tenant's SLO wall.",
            );
            prom_family(
                &mut out,
                "kernelsel_tenant_rejected_total",
                "counter",
                "Submit-path refusals per tenant by typed reason.",
            );
            prom_family(
                &mut out,
                "kernelsel_tenant_shed_total",
                "counter",
                "Drain-time sheds per tenant by typed reason.",
            );
            prom_family(
                &mut out,
                "kernelsel_tenant_inflight",
                "gauge",
                "The tenant's live quota (in-flight) count.",
            );
            prom_family(
                &mut out,
                "kernelsel_tenant_inflight_peak",
                "gauge",
                "Peak of the tenant's quota count observed at admit time.",
            );
            prom_family(
                &mut out,
                "kernelsel_tenant_latency_p50_ms",
                "gauge",
                "Approximate live median latency (log2-bucketed).",
            );
            prom_family(
                &mut out,
                "kernelsel_tenant_latency_p99_ms",
                "gauge",
                "Approximate live p99 latency (log2-bucketed).",
            );
            for t in &self.tenants {
                let base = format!(
                    "tenant=\"{}\",id=\"{}\"",
                    prom_escape(&t.spec.name),
                    t.spec.id.0
                );
                let live = &t.live;
                prom_sample(
                    &mut out,
                    "kernelsel_tenant_requests_total",
                    &base,
                    live.requests.load(Ordering::Relaxed) as f64,
                );
                prom_sample(
                    &mut out,
                    "kernelsel_tenant_in_slo_total",
                    &base,
                    live.in_slo.load(Ordering::Relaxed) as f64,
                );
                for reason in RejectReason::all() {
                    let i = reason.code() as usize;
                    prom_sample(
                        &mut out,
                        "kernelsel_tenant_rejected_total",
                        &format!("{base},reason=\"{}\"", reason.name()),
                        t.rejected_by[i].sum() as f64,
                    );
                    prom_sample(
                        &mut out,
                        "kernelsel_tenant_shed_total",
                        &format!("{base},reason=\"{}\"", reason.name()),
                        live.shed_by[i].load(Ordering::Relaxed) as f64,
                    );
                }
                prom_sample(
                    &mut out,
                    "kernelsel_tenant_inflight",
                    &base,
                    t.inflight.load(Ordering::Relaxed) as f64,
                );
                prom_sample(
                    &mut out,
                    "kernelsel_tenant_inflight_peak",
                    &base,
                    t.inflight_peak.load(Ordering::Relaxed) as f64,
                );
                prom_sample(
                    &mut out,
                    "kernelsel_tenant_latency_p50_ms",
                    &base,
                    live.latency.quantile_ns(0.50) / 1e6,
                );
                prom_sample(
                    &mut out,
                    "kernelsel_tenant_latency_p99_ms",
                    &base,
                    live.latency.quantile_ns(0.99) / 1e6,
                );
            }
        }
        // Flight-recorder health.
        if let Some(rec) = self.recorder.as_deref() {
            prom_family(
                &mut out,
                "kernelsel_trace_events_total",
                "counter",
                "Events currently held in the recorder's rings.",
            );
            prom_sample(&mut out, "kernelsel_trace_events_total", "", rec.recorded() as f64);
            prom_family(
                &mut out,
                "kernelsel_trace_dropped_total",
                "counter",
                "Events dropped because every ring stripe was full or contended.",
            );
            prom_sample(&mut out, "kernelsel_trace_dropped_total", "", rec.dropped() as f64);
            prom_family(
                &mut out,
                "kernelsel_trace_chains_total",
                "counter",
                "Traced submit chains opened.",
            );
            prom_sample(&mut out, "kernelsel_trace_chains_total", "", rec.chains() as f64);
        }
        // Quarantine / self-healing: the variant circuit breaker, the
        // shard supervisor, and the retry budget. Always exposed —
        // tracking is always on.
        prom_family(
            &mut out,
            "kernelsel_quarantine_trips_total",
            "counter",
            "Variants tripped into quarantine by windowed failure tracking.",
        );
        prom_sample(
            &mut out,
            "kernelsel_quarantine_trips_total",
            "",
            self.quarantine.trips() as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_quarantine_probes_total",
            "counter",
            "Half-open probation probes of quarantined variants.",
        );
        prom_sample(
            &mut out,
            "kernelsel_quarantine_probes_total",
            "",
            self.quarantine.probes() as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_quarantine_restores_total",
            "counter",
            "Variants promoted back to healthy after sustained probe success.",
        );
        prom_sample(
            &mut out,
            "kernelsel_quarantine_restores_total",
            "",
            self.quarantine.restores() as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_quarantine_active",
            "gauge",
            "Variants currently quarantined or in probation.",
        );
        prom_sample(
            &mut out,
            "kernelsel_quarantine_active",
            "",
            self.quarantine.active_count() as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_worker_respawns",
            "counter",
            "Dead shard workers respawned by the supervisor.",
        );
        prom_sample(
            &mut out,
            "kernelsel_worker_respawns",
            "",
            self.front.respawns.load(Ordering::Relaxed) as f64,
        );
        prom_family(
            &mut out,
            "kernelsel_retries_total",
            "counter",
            "Retries spent from the retry budget by call_with_retry.",
        );
        prom_sample(&mut out, "kernelsel_retries_total", "", self.front.retries.sum() as f64);
        prom_family(
            &mut out,
            "kernelsel_retries_denied_total",
            "counter",
            "Retries refused because the budget was below its shed threshold.",
        );
        prom_sample(
            &mut out,
            "kernelsel_retries_denied_total",
            "",
            self.front.retries_denied.sum() as f64,
        );
        // Exploration: probe accounting plus the measured-coverage gauge
        // over the default domain's healthy shipped (bucket, config)
        // matrix — the number the exploration acceptance gate watches.
        if let Some(planner) = self.explore.as_deref() {
            let stats = planner.stats();
            prom_family(
                &mut out,
                "kernelsel_explore_probes_total",
                "counter",
                "Epsilon probes dispatched, by outcome bucket.",
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_probes_total",
                "outcome=\"issued\"",
                stats.probes_issued as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_probes_total",
                "outcome=\"shed\"",
                stats.probes_shed as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_probes_total",
                "outcome=\"completed\"",
                stats.probes_completed as f64,
            );
            prom_family(
                &mut out,
                "kernelsel_explore_probe_budget",
                "gauge",
                "Lifetime probe budget this pool was started with.",
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_probe_budget",
                "",
                planner.config().budget as f64,
            );
            prom_family(
                &mut out,
                "kernelsel_explore_first_sight_total",
                "counter",
                "Never-seen shape buckets seen, and micro-benchmark runs made for them.",
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_first_sight_total",
                "kind=\"shapes\"",
                stats.first_sight_shapes as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_first_sight_total",
                "kind=\"runs\"",
                stats.first_sight_runs as f64,
            );
            let (measured, total) = self.explore_coverage(1);
            prom_family(
                &mut out,
                "kernelsel_explore_coverage",
                "gauge",
                "Measured fraction of the healthy shipped (bucket, config) matrix.",
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_coverage",
                "",
                if total == 0 { 0.0 } else { measured as f64 / total as f64 },
            );
            prom_family(
                &mut out,
                "kernelsel_explore_coverage_pairs",
                "gauge",
                "Measured and total (bucket, config) pairs behind the coverage gauge.",
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_coverage_pairs",
                "state=\"measured\"",
                measured as f64,
            );
            prom_sample(
                &mut out,
                "kernelsel_explore_coverage_pairs",
                "state=\"total\"",
                total as f64,
            );
        }
        out
    }

    /// Live exploration counters (all zero when exploration is off).
    pub fn explore_stats(&self) -> ExploreStats {
        self.explore.as_deref().map(ExplorePlanner::stats).unwrap_or_default()
    }

    /// Measured coverage `(measured, total)` of the default domain's
    /// healthy shipped (bucket, config) matrix, counting cells holding at
    /// least `min_samples` samples — the exploration acceptance gate
    /// (`measured / total >= 0.9` within the probe budget). Available
    /// whether or not exploration is armed: restored telemetry
    /// (`serve --telemetry-in`) counts, which is exactly how a
    /// warm-started pool proves it needs zero live probes.
    pub fn explore_coverage(&self, min_samples: u64) -> (usize, usize) {
        measured_coverage(&self.telemetry.snapshot(), &self.registry, min_samples)
    }

    /// Whether a shard's worker thread is still running, read lock-free
    /// from the queue's `alive` flag (cleared by the worker's
    /// [`AliveGuard`] on every exit path — normal stop, failed backend
    /// init, or a panic unwinding; re-armed by a respawned replacement).
    fn worker_alive(&self, shard: usize) -> bool {
        self.queues[shard].alive.load(Ordering::Relaxed)
    }

    /// Supervisor: try to respawn a dead shard's worker on its existing
    /// queue, so queued work is re-homed to the replacement and routing
    /// stops favoring a corpse. Returns whether the shard is (again)
    /// alive. Contention-tolerant: if another submitter already holds the
    /// supervisor lock, this one routes around the dead shard and lets
    /// the winner finish the respawn.
    fn maybe_respawn(&self, shard: usize) -> bool {
        let Ok(mut workers) = self.workers.try_lock() else { return false };
        if self.worker_alive(shard) {
            return true; // another submitter's respawn already landed
        }
        // Join the dead handle first: the thread has already left
        // `shard_loop` (its AliveGuard cleared the flag), so this only
        // reaps it and surfaces nothing to unwind into us.
        if let Some(old) = workers[shard].take() {
            let _ = old.join();
        }
        let spec = &self.respawn;
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let dir = spec.artifacts_dir.clone();
        let engine = spec.engine.clone();
        let batcher_cfg = spec.batcher.clone();
        let queues = self.queues.clone();
        let steal_min = spec.steal_min;
        let queue_budget = spec.queue_budget;
        let domains = spec.domains.clone();
        let side = ShardSide {
            recorder: self.recorder.clone(),
            lanes: spec.lanes.clone(),
            shed_reason: self.shed_reason,
            quarantine: self.quarantine.clone(),
            fault: spec.fault,
            explore: spec.explore.clone(),
        };
        let spawned = std::thread::Builder::new()
            .name(format!("kernelsel-shard-{shard}"))
            .spawn(move || {
                shard_loop(
                    shard,
                    dir,
                    engine,
                    batcher_cfg,
                    queues,
                    steal_min,
                    queue_budget,
                    domains,
                    side,
                    ready_tx,
                )
            });
        let Ok(worker) = spawned else { return false };
        match ready_rx.recv() {
            Ok(Ok(())) => {
                // The replacement owns the dead worker's whole injector
                // backlog — that is the re-homed request count.
                let rehomed = self.queues[shard].load.depth() as u64;
                workers[shard] = Some(worker);
                self.front.respawns.fetch_add(1, Ordering::Relaxed);
                if let Some(rec) = self.recorder.as_deref() {
                    rec.event(0, EventKind::Respawn, shard as u16, 0, [rehomed, 0, 0]);
                }
                true
            }
            _ => {
                // Backend init failed (or the replacement died during
                // startup): reap it and leave the shard dead — the
                // router keeps spilling around it.
                let _ = worker.join();
                false
            }
        }
    }

    /// The least-loaded shard whose worker is still alive, if any.
    fn least_loaded_alive(&self) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&i| self.worker_alive(i))
            .min_by_key(|&i| self.queues[i].load.score_ns())
    }

    /// Shape-affinity preference: requests resolving to the same artifact
    /// prefer the same shard, keeping its executable cache hot. The hash
    /// is memoized on the resolution, so this is one modulo.
    fn shard_for(&self, resolved: &ResolvedKernel) -> usize {
        (resolved.affinity() as usize) % self.queues.len()
    }

    /// Pick the shard for a resolved request. Returns `(shard, spilled)`:
    /// affinity preference first; under [`Routing::LoadAware`], spill to
    /// the least-loaded shard once the preferred shard's gauge exceeds
    /// `imbalance x` the minimum plus an absolute slack.
    fn route(&self, resolved: &ResolvedKernel) -> (usize, bool) {
        let preferred = self.shard_for(resolved);
        if self.queues.len() == 1 || self.routing == Routing::Affinity {
            return (preferred, false);
        }
        let pref_score = self.queues[preferred].load.score_ns();
        if pref_score < SPILL_MIN_EXCESS_NS {
            // Near-idle preferred shard: stay on the affinity fast path.
            return (preferred, false);
        }
        let mut min_shard = preferred;
        let mut min_score = pref_score;
        for (i, q) in self.queues.iter().enumerate() {
            let s = q.load.score_ns();
            if s < min_score {
                min_shard = i;
                min_score = s;
            }
        }
        let threshold = min_score as f64 * self.imbalance + SPILL_MIN_EXCESS_NS as f64;
        if min_shard != preferred && pref_score as f64 > threshold {
            (min_shard, true)
        } else {
            (preferred, false)
        }
    }

    /// Route a resolved request to a live shard. A dead worker's shard is
    /// first offered to the supervisor for an in-place respawn (re-homing
    /// its queued work to the replacement); if that fails or is
    /// contended, reroute to the least-loaded live shard (work already
    /// queued on the dead shard can still be rescued by the steal path).
    /// `None` when no live shard is left and none could be revived.
    fn pick_shard(&self, resolved: &ResolvedKernel) -> Option<(usize, bool)> {
        let (shard, spilled) = self.route(resolved);
        if self.worker_alive(shard) || self.maybe_respawn(shard) {
            Some((shard, spilled))
        } else {
            self.least_loaded_alive().map(|alt| (alt, true))
        }
    }

    /// A pooled completion pair, falling back to a one-shot heap slot when
    /// every pooled slot is in flight.
    fn checkout_completion(&self) -> (Completion, Ticket) {
        CompletionPool::checkout(&self.completions).unwrap_or_else(Completion::oneshot)
    }

    /// Open one request's trace chain: a `submit` event (packed shape +
    /// priced cost) followed by its `route` decision. Returns the chain
    /// id the job carries (0 = tracing off or sampled out). Writes
    /// fixed-size events by value — no allocation on the warm path.
    #[inline]
    fn trace_submit(
        &self,
        shape: &GemmShape,
        cost_ns: u64,
        tenant: TenantId,
        shard: usize,
        spilled: bool,
    ) -> u64 {
        let Some(rec) = self.recorder.as_deref() else { return 0 };
        let seq = rec.begin_submit();
        rec.event(
            seq,
            EventKind::Submit,
            shard as u16,
            tenant.0,
            [pack_shape(shape), cost_ns, 0],
        );
        rec.event(seq, EventKind::Route, shard as u16, tenant.0, [u64::from(spilled), 0, 0]);
        seq
    }

    /// Terminate a chain with its admission refusal: the typed reason
    /// code and the retry hint (0 = none).
    #[inline]
    fn trace_reject(&self, seq: u64, shard: usize, tenant: TenantId, err: &SubmitError) {
        if let Some(rec) = self.recorder.as_deref() {
            let hint_ns = err.retry_after_hint().map_or(0, |d| d.as_nanos() as u64);
            rec.event(
                seq,
                EventKind::Reject,
                shard as u16,
                tenant.0,
                [u64::from(err.reason().code()), hint_ns, 0],
            );
        }
    }

    /// Count one submit-path refusal: the frontend totals, the frontend
    /// per-reason cell, and (for registered tenants) the tenant's own
    /// total and per-reason cells — all striped, no pool-global lock.
    #[inline]
    fn count_reject(&self, state: Option<&TenantState>, err: &SubmitError) {
        let code = err.reason().code() as usize;
        if let Some(s) = state {
            s.rejected.incr();
            s.rejected_by[code].incr();
        }
        self.front.rejected.incr();
        self.front.rejected_by[code].incr();
    }

    /// Consult `policy` (the pool policy, or a tenant's SLO-scaled copy)
    /// for one request routed to `shard`. `Unbounded` (the default) exits
    /// before touching any counter, so the uncontended fast path is
    /// bit-identical to the pre-admission pool. Under a bounding policy
    /// the pool-wide in-flight slot is *reserved* (one `fetch_add`)
    /// before the decision — concurrent submitters cannot race past
    /// `max_inflight` — and released either here on reject or by the
    /// serving shard on completion/shed.
    fn admit(
        &self,
        policy: AdmissionPolicy,
        shard: usize,
        cost_ns: u64,
    ) -> Result<InflightSlot, SubmitError> {
        if policy.is_unbounded() {
            return Ok(InflightSlot::none());
        }
        let load = &self.queues[shard].load;
        self.admit_at(policy, cost_ns, load.score_ns(), load.depth(), load.drain_rate_per_sec())
    }

    /// The shared reservation protocol for a known-bounding policy and an
    /// already-computed backlog estimate (`submit_many` advances its own
    /// local estimate per admitted request; `admit` reads the gauge). On
    /// success the reservation IS the returned [`InflightSlot`] — the
    /// caller moves it into the job, so acquire and release are paired
    /// structurally and no code path can take one without the other.
    /// `queued_depth` and `drain_per_sec` come from the routed shard's
    /// gauge: they only shape rejection retry hints, never the decision.
    fn admit_at(
        &self,
        policy: AdmissionPolicy,
        cost_ns: u64,
        backlog_ns: u64,
        queued_depth: usize,
        drain_per_sec: f64,
    ) -> Result<InflightSlot, SubmitError> {
        if !policy.caps_inflight() {
            // DeadlineShed never reads the in-flight count: no
            // pool-global RMW traffic on its submit path.
            policy.admit_with_drain(cost_ns, backlog_ns, 0, queued_depth, drain_per_sec)?;
            return Ok(InflightSlot::none());
        }
        let reserved = self.inflight.fetch_add(1, Ordering::AcqRel);
        match policy.admit_with_drain(cost_ns, backlog_ns, reserved, queued_depth, drain_per_sec)
        {
            Ok(()) => {
                self.front.inflight_peak.fetch_max(reserved + 1, Ordering::Relaxed);
                Ok(InflightSlot::pool(self.inflight.clone()))
            }
            Err(err) => {
                self.inflight.fetch_sub(1, Ordering::Release);
                Err(err)
            }
        }
    }

    /// The weighted-fair quota gate for one registered tenant's request:
    /// admit within the tenant's reserved share unconditionally; past it,
    /// admit only while unreserved capacity remains (see
    /// [`quota_would_admit`] for the exact predicate — this method adds
    /// the reserve-then-check counter protocol around it). On success the
    /// returned [`InflightSlot`] holds the tenant's reservation, to be
    /// folded into the pool admission slot; dropping it on a later
    /// reject path releases the quota slot automatically.
    fn quota_gate(
        &self,
        state: &TenantState,
        shard: usize,
    ) -> Result<InflightSlot, SubmitError> {
        if state.spec.weight == 0 {
            // A zero-weight tenant is switched off: no retry hint,
            // because no amount of waiting admits it.
            return Err(SubmitError::Rejected {
                reason: RejectReason::QuotaExceeded,
                retry_after_hint: None,
            });
        }
        if self.quota_slots == 0 {
            return Ok(InflightSlot::none());
        }
        // Reserve-then-check, mirroring the pool-wide protocol: the slot
        // is taken before the decision so concurrent submitters of the
        // same tenant cannot race past its share.
        let mine = state.inflight.fetch_add(1, Ordering::AcqRel);
        let mut peers_used = 0usize;
        let mut others_free = 0usize;
        for peer in &self.tenants {
            if Arc::ptr_eq(&peer.inflight, &state.inflight) {
                continue;
            }
            let used = peer.inflight.load(Ordering::Acquire);
            peers_used += used;
            others_free += peer.reserved.saturating_sub(used);
        }
        if quota_would_admit(
            state.spec.weight,
            mine,
            state.reserved,
            mine + peers_used,
            others_free,
            self.quota_slots,
        ) {
            state.inflight_peak.fetch_max(mine + 1, Ordering::Relaxed);
            return Ok(InflightSlot::tenant(state.inflight.clone()));
        }
        state.inflight.fetch_sub(1, Ordering::Release);
        // Retry hint: how long the routed shard needs to drain this
        // tenant's excess over its reserved share — measured drain rate
        // when warm, the queue's own average cost estimate while cold.
        let excess = ((mine + 1).saturating_sub(state.reserved)).max(1) as u64;
        let load = &self.queues[shard].load;
        let drain = load.drain_rate_per_sec();
        let hint = if drain > 0.0 {
            drain_hint_ns(excess, drain)
        } else {
            (load.score_ns() / load.depth().max(1) as u64)
                .saturating_mul(excess)
                .max(MIN_RETRY_HINT_NS)
        };
        Err(SubmitError::Rejected {
            reason: RejectReason::QuotaExceeded,
            retry_after_hint: Some(Duration::from_nanos(hint)),
        })
    }

    /// The registered state for `tenant`, or `None` for anonymous or
    /// unregistered ids (both bypass every tenant mechanism).
    fn tenant_state(&self, tenant: TenantId) -> Option<&TenantState> {
        if tenant.is_anonymous() {
            return None;
        }
        self.tenant_index.get(&tenant.0).map(|&i| &self.tenants[i])
    }

    /// The registry/cache pair requests in `domain` resolve through
    /// (domain 0 = the pool's own).
    fn domain_handles(&self, domain: u32) -> (&Arc<KernelRegistry>, &Arc<ResolutionCache>) {
        match domain {
            0 => (&self.registry, &self.cache),
            d => {
                let state = &self.extra_domains[d as usize - 1];
                (&state.registry, &state.cache)
            }
        }
    }

    /// Submit an anonymous request; the response arrives on the returned
    /// ticket. Delegates to [`Coordinator::submit_as`] with
    /// [`TenantId::ANONYMOUS`], which bypasses every tenant mechanism —
    /// bit-identical to the pre-tenant pool.
    ///
    /// Under a bounding [`AdmissionPolicy`] the request may be refused
    /// *before* taking a completion slot: the returned ticket then carries
    /// the typed rejection ([`Ticket::rejection`]) and resolves
    /// immediately — no allocation, no slab capacity, no shard traffic.
    pub fn submit(&self, shape: GemmShape, lhs: Vec<f32>, rhs: Vec<f32>) -> Ticket {
        self.submit_as(TenantId::ANONYMOUS, shape, lhs, rhs)
    }

    /// Submit a request on behalf of `tenant`. A registered tenant passes
    /// the weighted-fair quota gate first (within its reserved share:
    /// guaranteed; past it: only while unreserved capacity remains — the
    /// ticket otherwise carries a `quota-exceeded` rejection with a
    /// drain-priced retry hint), then pool admission under its SLO-scaled
    /// policy; its requests resolve through its domain's registry/cache
    /// and its completions land in its metrics lane. An unregistered or
    /// anonymous id takes the untenanted fast path.
    pub fn submit_as(
        &self,
        tenant: TenantId,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Ticket {
        let t_submit = Instant::now();
        let state = self.tenant_state(tenant);
        let domain = state.map_or(0, |s| s.domain);
        let (registry, cache) = self.domain_handles(domain);
        let organic = match cache.resolve(registry, &shape) {
            Ok(r) => r,
            Err(e) => {
                self.front.failures.incr();
                let (completion, ticket) = self.checkout_completion();
                completion.complete(failure_response(e, t_submit));
                return ticket;
            }
        };
        let (shard, spilled) = match self.pick_shard(&organic) {
            Some(pick) => pick,
            None => {
                self.front.failures.incr();
                let (completion, ticket) = self.checkout_completion();
                completion.complete(failure_response(
                    "executor pool: every shard worker is dead".to_string(),
                    t_submit,
                ));
                return ticket;
            }
        };
        let policy = state.map_or(self.admission, |s| s.policy);
        // Exploration: the seeded epsilon draw may redirect this request
        // to an unmeasured shipped config at the same shape. The organic
        // resolution is kept alongside — if admission later refuses the
        // probe-priced request, it retries once un-redirected, so a probe
        // can never displace work that would have been admitted without
        // it. The probe rides the organic routing decision: exploration
        // changes which variant serves the request, never where.
        let mut probe = false;
        let mut resolved = organic.clone();
        if let Some(planner) = self.explore.as_deref() {
            if planner.first_sight(shape) {
                if let Some(seeder) = &self.seeder {
                    seeder.send(shape);
                }
            }
            let ordinal = planner.next_ordinal();
            if planner.should_probe(ordinal) {
                match self
                    .plan_probe(planner, ordinal, registry, cache, domain, &shape, shard, policy)
                {
                    Some(redirect) => {
                        resolved = redirect;
                        probe = true;
                    }
                    None => planner.note_shed(),
                }
            }
        }
        // Measured EWMA once telemetry is warm, devsim estimate while cold.
        let mut cost_ns = cache.dispatch_cost_ns(&resolved);
        let trace_seq = self.trace_submit(&shape, cost_ns, tenant, shard, spilled);
        let tenant_slot = match state.map_or(Ok(InflightSlot::none()), |s| {
            self.quota_gate(s, shard)
        }) {
            Ok(slot) => slot,
            Err(err) => {
                debug_assert!(state.is_some(), "quota gate only rejects registered tenants");
                if probe {
                    // The fired draw dies with the request: quota is
                    // resolution-independent, so the organic request
                    // would have been refused identically — nothing was
                    // displaced, but the probe never issued.
                    if let Some(planner) = self.explore.as_deref() {
                        planner.note_shed();
                    }
                }
                self.count_reject(state, &err);
                self.trace_reject(trace_seq, shard, tenant, &err);
                return Ticket::rejected(err);
            }
        };
        let mut reservation = match self.admit(policy, shard, cost_ns) {
            Ok(slot) => slot,
            Err(err) if probe => {
                // Shed the probe, not the request: retry admission once
                // with the organic resolution — the airtight half of the
                // never-displace guarantee (the strict
                // [`probe_would_admit`] pre-check is the cheap half).
                if let Some(planner) = self.explore.as_deref() {
                    planner.note_shed();
                }
                probe = false;
                resolved = organic;
                cost_ns = cache.dispatch_cost_ns(&resolved);
                let _ = err; // the probe-priced refusal is superseded
                match self.admit(policy, shard, cost_ns) {
                    Ok(slot) => slot,
                    Err(err) => {
                        // `tenant_slot` drops, releasing the quota slot.
                        self.count_reject(state, &err);
                        self.trace_reject(trace_seq, shard, tenant, &err);
                        return Ticket::rejected(err);
                    }
                }
            }
            Err(err) => {
                // `tenant_slot` drops here, releasing the quota slot.
                self.count_reject(state, &err);
                self.trace_reject(trace_seq, shard, tenant, &err);
                return Ticket::rejected(err);
            }
        };
        reservation.tenant = tenant_slot.into_tenant();
        if probe {
            if let Some(planner) = self.explore.as_deref() {
                planner.note_issued();
            }
        }
        let (completion, ticket) = self.checkout_completion();
        let req = GemmRequest { shape, lhs, rhs };
        self.queues[shard].push(Job {
            req,
            t_submit,
            resolved,
            cost_ns,
            spilled,
            completion,
            reservation,
            tenant,
            slo_wall: state.and_then(|s| s.spec.slo_wall),
            domain,
            lane: state.map_or(NO_LANE, |s| s.lane),
            trace_seq,
            probe,
        });
        ticket
    }

    /// Try to place the probe the epsilon draw at `ordinal` fired:
    /// the routed shard must be near-idle with at least half of any
    /// admission budget untouched ([`probe_would_admit`]), unmeasured
    /// healthy candidates must exist at `shape`, and the deterministic
    /// pick must survive the quarantine `blocks` read inside
    /// [`ResolutionCache::resolve_probe`]. `None` means this probe is
    /// shed and the request proceeds organically.
    #[allow(clippy::too_many_arguments)]
    fn plan_probe(
        &self,
        planner: &ExplorePlanner,
        ordinal: u64,
        registry: &Arc<KernelRegistry>,
        cache: &Arc<ResolutionCache>,
        domain: u32,
        shape: &GemmShape,
        shard: usize,
        policy: AdmissionPolicy,
    ) -> Option<Arc<ResolvedKernel>> {
        // Only `BoundedQueue` exposes budget knobs for the half-budget
        // rules; under other policies the idle-shard rules still apply,
        // and the retry-as-organic fallback covers whatever a policy
        // might refuse that this predicate cannot see.
        let (max_inflight, max_queue_ns) = match policy {
            AdmissionPolicy::BoundedQueue { max_inflight, max_queue_ns } => {
                (max_inflight, max_queue_ns)
            }
            _ => (0, 0),
        };
        let load = &self.queues[shard].load;
        if !probe_would_admit(
            load.score_ns(),
            load.depth(),
            self.inflight.load(Ordering::Acquire),
            max_inflight,
            max_queue_ns,
        ) {
            return None;
        }
        let candidates = unmeasured_candidates(registry, self.domain_telemetry(domain), shape);
        if candidates.is_empty() {
            return None;
        }
        let pick = planner.pick(ordinal, candidates.len());
        cache.resolve_probe(registry, shape, candidates[pick])
    }

    /// Submit a batch of requests in one call; returns one [`Ticket`] per
    /// request, in submission order. Consecutive requests sharing a shape
    /// are resolved, cost-priced and routed **once**, and land on their
    /// shard under a single lock acquisition with a single load-gauge
    /// update — the batched fast path for callers that naturally produce
    /// runs of equal shapes (a model replaying its GEMM sequence).
    ///
    /// Admission is **partial**: under a bounding policy each request in a
    /// run is judged against the backlog estimate *including the requests
    /// admitted ahead of it in the same call*, so a burst can be half
    /// admitted and half refused. Every ticket reports its own outcome —
    /// check [`Ticket::rejection`] per ticket.
    pub fn submit_many(&self, requests: Vec<(GemmShape, Vec<f32>, Vec<f32>)>) -> Vec<Ticket> {
        self.submit_many_as(TenantId::ANONYMOUS, requests)
    }

    /// [`Coordinator::submit_many`] on behalf of `tenant`: the batched
    /// fast path plus the per-request tenant mechanics of
    /// [`Coordinator::submit_as`]. The quota gate runs per request inside
    /// each run, so a burst can be quota-admitted up to the tenant's fair
    /// share and refused past it within one call.
    pub fn submit_many_as(
        &self,
        tenant: TenantId,
        requests: Vec<(GemmShape, Vec<f32>, Vec<f32>)>,
    ) -> Vec<Ticket> {
        let state = self.tenant_state(tenant);
        let (registry, cache) = self.domain_handles(state.map_or(0, |s| s.domain));
        let policy = state.map_or(self.admission, |s| s.policy);
        let slo_wall = state.and_then(|s| s.spec.slo_wall);
        let domain = state.map_or(0, |s| s.domain);
        let lane = state.map_or(NO_LANE, |s| s.lane);
        let mut tickets = Vec::with_capacity(requests.len());
        let mut iter = requests.into_iter().peekable();
        while let Some((shape, lhs, rhs)) = iter.next() {
            // Per-run stamp, not per-call: a later run must not arrive at
            // the batcher pre-aged by the time earlier runs took to
            // resolve and enqueue (its latency epoch and its max_wait
            // deadline both derive from this instant).
            let t_submit = Instant::now();
            let mut run = vec![(lhs, rhs)];
            while iter.peek().map_or(false, |(next, _, _)| *next == shape) {
                let (_, lhs, rhs) = iter.next().expect("peeked");
                run.push((lhs, rhs));
            }
            let resolved = match cache.resolve(registry, &shape) {
                Ok(r) => r,
                Err(e) => {
                    self.fail_requests(run.len(), &e, t_submit, &mut tickets);
                    continue;
                }
            };
            let (shard, spilled) = match self.pick_shard(&resolved) {
                Some(pick) => pick,
                None => {
                    self.fail_requests(
                        run.len(),
                        "executor pool: every shard worker is dead",
                        t_submit,
                        &mut tickets,
                    );
                    continue;
                }
            };
            let cost_ns = cache.dispatch_cost_ns(&resolved);
            // Admission state for the run: the shard backlog is read once,
            // then advanced locally per admitted request (the jobs only
            // hit the shard's gauge at push_batch below, so without this
            // the whole run would be judged against the pre-run backlog).
            // In-flight slots are individually reserved, exactly as in
            // `admit` — concurrent submitters cannot race past the cap.
            let bounding = !policy.is_unbounded();
            let (mut backlog_ns, mut queued_depth, drain_per_sec) = if bounding {
                let load = &self.queues[shard].load;
                (load.score_ns(), load.depth(), load.drain_rate_per_sec())
            } else {
                (0, 0, 0.0)
            };
            let mut jobs = Vec::with_capacity(run.len());
            for (lhs, rhs) in run {
                let trace_seq = self.trace_submit(&shape, cost_ns, tenant, shard, spilled);
                let tenant_slot = match state.map_or(Ok(InflightSlot::none()), |s| {
                    self.quota_gate(s, shard)
                }) {
                    Ok(slot) => slot,
                    Err(err) => {
                        debug_assert!(
                            state.is_some(),
                            "quota gate only rejects registered tenants"
                        );
                        self.count_reject(state, &err);
                        self.trace_reject(trace_seq, shard, tenant, &err);
                        tickets.push(Ticket::rejected(err));
                        continue;
                    }
                };
                let mut reservation = if bounding {
                    match self.admit_at(
                        policy,
                        cost_ns,
                        backlog_ns,
                        queued_depth,
                        drain_per_sec,
                    ) {
                        Ok(slot) => {
                            backlog_ns = backlog_ns
                                .saturating_add(cost_ns)
                                .saturating_add(QUEUED_OVERHEAD_NS);
                            queued_depth += 1;
                            slot
                        }
                        Err(err) => {
                            // `tenant_slot` drops: the quota slot frees.
                            self.count_reject(state, &err);
                            self.trace_reject(trace_seq, shard, tenant, &err);
                            tickets.push(Ticket::rejected(err));
                            continue;
                        }
                    }
                } else {
                    InflightSlot::none()
                };
                reservation.tenant = tenant_slot.into_tenant();
                let (completion, ticket) = self.checkout_completion();
                tickets.push(ticket);
                jobs.push(Job {
                    req: GemmRequest { shape, lhs, rhs },
                    t_submit,
                    resolved: resolved.clone(),
                    cost_ns,
                    spilled,
                    completion,
                    reservation,
                    tenant,
                    slo_wall,
                    domain,
                    lane,
                    trace_seq,
                    // The batched fast path is deliberately unexplored:
                    // a probe would split the run's single resolution,
                    // and bursty batch traffic is exactly when probes
                    // should not fire anyway.
                    probe: false,
                });
            }
            self.queues[shard].push_batch(jobs);
        }
        tickets
    }

    /// Complete `n` tickets immediately with a submit-time failure.
    fn fail_requests(&self, n: usize, error: &str, t_submit: Instant, tickets: &mut Vec<Ticket>) {
        for _ in 0..n {
            self.front.failures.incr();
            let (completion, ticket) = self.checkout_completion();
            completion.complete(failure_response(error.to_string(), t_submit));
            tickets.push(ticket);
        }
    }

    /// Blocking convenience call. Always returns `Ok`: submit-time and
    /// execution failures surface inside [`GemmResponse::result`]. The
    /// `Result` shell is kept for call-site compatibility.
    pub fn call(
        &self,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Result<GemmResponse, String> {
        Ok(self.submit(shape, lhs, rhs).wait())
    }

    /// Blocking convenience call on behalf of `tenant` (see
    /// [`Coordinator::submit_as`]); quota refusals surface inside
    /// [`GemmResponse::result`] like every other submit-time failure.
    pub fn call_as(
        &self,
        tenant: TenantId,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Result<GemmResponse, String> {
        Ok(self.submit_as(tenant, shape, lhs, rhs).wait())
    }

    /// [`Coordinator::call`] with a bounded, admission-aware retry: an
    /// admission rejection (after sleeping its retry hint) or a failed
    /// execution is re-submitted up to [`MAX_RETRY_ATTEMPTS`] times, each
    /// retry spending one token from the pool's [`RetryBudget`]. Tokens
    /// refill only on success, so under sustained overload the bucket
    /// drains to its shed threshold and retries are refused *first* —
    /// retry traffic can never amplify overload. The last response is
    /// returned as-is when retries are exhausted or denied.
    pub fn call_with_retry(
        &self,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Result<GemmResponse, String> {
        self.call_with_retry_as(TenantId::ANONYMOUS, shape, lhs, rhs)
    }

    /// [`Coordinator::call_with_retry`] on behalf of `tenant` (see
    /// [`Coordinator::submit_as`] for the tenant mechanics each attempt
    /// goes through).
    pub fn call_with_retry_as(
        &self,
        tenant: TenantId,
        shape: GemmShape,
        lhs: Vec<f32>,
        rhs: Vec<f32>,
    ) -> Result<GemmResponse, String> {
        let mut attempt = 1u32;
        loop {
            let ticket = self.submit_as(tenant, shape, lhs.clone(), rhs.clone());
            let rejection = ticket.rejection();
            let resp = ticket.wait();
            if resp.result.is_ok() {
                self.retry_budget.on_success();
                return Ok(resp);
            }
            if attempt >= MAX_RETRY_ATTEMPTS {
                return Ok(resp);
            }
            if !self.retry_budget.try_spend() {
                self.front.retries_denied.incr();
                return Ok(resp);
            }
            self.front.retries.incr();
            // Trace the retry: the rejection's typed reason code, or the
            // transient-failure sentinel for an executed-but-failed call.
            let (code, hint) = match rejection {
                Some(err) => (u64::from(err.reason().code()), err.retry_after_hint()),
                None => (u64::MAX, None),
            };
            if let Some(rec) = self.recorder.as_deref() {
                rec.event(
                    0,
                    EventKind::Retry,
                    0,
                    tenant.0,
                    [code, u64::from(attempt), self.retry_budget.tokens_milli()],
                );
            }
            if let Some(hint) = hint {
                std::thread::sleep(hint.min(RETRY_SLEEP_CAP));
            }
            attempt += 1;
        }
    }

    /// Stop every shard and return the merged pool metrics.
    pub fn stop(self) -> Metrics {
        self.stop_detailed().total
    }

    /// Stop every shard; return per-shard metrics plus merged totals.
    pub fn stop_detailed(mut self) -> PoolReport {
        // Stop the retuner first so the selector is frozen while the
        // shards drain, then fold the counters into the pool totals.
        if let Some(retuner) = self.retuner.take() {
            let _ = retuner.finish();
        }
        for domain in &mut self.extra_domains {
            if let Some(retuner) = domain.retuner.take() {
                let _ = retuner.finish();
            }
        }
        // Drain the first-sight seeder before folding counters: dropping
        // it closes the channel and joins the worker, so every queued
        // micro-benchmark lands in telemetry (and in the explore stats)
        // before the report — and before any `--telemetry-out` export —
        // reads them. Also what makes same-seed runs report-identical.
        self.seeder.take();
        let tuning = self.retune_stats.lock().unwrap().clone();
        // Signal all shards first so they drain concurrently, then join.
        let mut replies = Vec::with_capacity(self.queues.len());
        for q in self.queues.iter() {
            let (mtx, mrx) = channel();
            q.signal_stop(mtx);
            replies.push(mrx);
        }
        let mut per_shard = Vec::with_capacity(self.queues.len());
        {
            let mut workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
            for (worker, mrx) in workers.iter_mut().zip(replies) {
                // Join before reading the reply: a worker that died without
                // taking its stop signal never sends, and its reply Sender sits
                // parked inside the queue — a blocking recv() would deadlock.
                // After the join, the flushed metrics (if any) are buffered.
                if let Some(w) = worker.take() {
                    let _ = w.join();
                }
                per_shard.push(mrx.try_recv().unwrap_or_default());
            }
        }
        let mut total = Metrics::default();
        for m in &per_shard {
            total.merge(m.clone());
        }
        // Fold the striped frontend cells and the retuner's counters into
        // the totals (shards never see these).
        total.failures += self.front.failures.sum();
        total.rejected += self.front.rejected.sum();
        total.inflight_peak =
            total.inflight_peak.max(self.front.inflight_peak.load(Ordering::Relaxed));
        total.selector_swaps += self.front.selector_swaps.load(Ordering::Relaxed) + tuning.swaps;
        total.retunes += tuning.retunes;
        total.drift_trips += tuning.drift_trips;
        // Quarantine / self-healing counters: the shared set's atomics
        // and the frontend's supervisor/retry cells.
        total.quarantine_trips += self.quarantine.trips() as usize;
        total.quarantine_probes += self.quarantine.probes() as usize;
        total.quarantine_restores += self.quarantine.restores() as usize;
        total.worker_respawns += self.front.respawns.load(Ordering::Relaxed);
        total.retries += self.front.retries.sum();
        total.retries_denied += self.front.retries_denied.sum();
        // Extra domains fold their retuner counters into the totals too
        // (the dedicated `tuning` field stays the default domain's).
        for domain in &self.extra_domains {
            let stats = domain.retune_stats.lock().unwrap();
            total.selector_swaps += stats.swaps;
            total.retunes += stats.retunes;
            total.drift_trips += stats.drift_trips;
        }
        // Per-tenant lanes: shards recorded completions and sheds; the
        // frontend counted refusals. Fold the refusals in, then render
        // the lanes into per-tenant reports in registration order.
        for t in &self.tenants {
            let rejected = t.rejected.sum();
            if rejected > 0 {
                total.per_tenant.entry(t.spec.id.0).or_default().rejected += rejected;
            }
        }
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                let lane = total.per_tenant.get(&t.spec.id.0);
                let stats = lane.and_then(|l| l.latency_stats());
                TenantReport {
                    id: t.spec.id.0,
                    name: t.spec.name.clone(),
                    requests: lane.map_or(0, |l| l.requests),
                    in_slo: lane.map_or(0, |l| l.in_slo),
                    rejected: lane.map_or(0, |l| l.rejected),
                    rejected_by_reason: std::array::from_fn(|i| t.rejected_by[i].sum()),
                    shed: lane.map_or(0, |l| l.shed),
                    shed_by_reason: lane.map_or([0; REJECT_REASONS], |l| l.shed_by_reason),
                    inflight_peak: t.inflight_peak.load(Ordering::Relaxed),
                    p50_ms: stats.as_ref().map_or(0.0, |s| s.p50 * 1e3),
                    p99_ms: stats.as_ref().map_or(0.0, |s| s.p99 * 1e3),
                }
            })
            .collect();
        let (cache_hits, cache_misses) = self.cache.stats();
        let explore = self.explore_stats();
        PoolReport { per_shard, total, cache_hits, cache_misses, tuning, tenants, explore }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let mut workers = self.workers.lock().unwrap_or_else(|p| p.into_inner());
        shutdown_workers(&self.queues, &mut workers);
    }
}

/// A shape cell needs this many measurements before the regret
/// estimator trusts its chosen-vs-best comparison (see
/// [`evaluate_regret`]).
const REGRET_MIN_CELL_SAMPLES: u64 = 2;

/// Append one `# HELP` / `# TYPE` exposition header pair.
fn prom_family(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

/// Append one `name{labels} value` sample line (`labels` pre-rendered,
/// may be empty for a label-free sample).
fn prom_sample(out: &mut String, name: &str, labels: &str, value: f64) {
    if labels.is_empty() {
        out.push_str(&format!("{name} {value}\n"));
    } else {
        out.push_str(&format!("{name}{{{labels}}} {value}\n"));
    }
}

/// Escape a string for use inside a Prometheus label value.
fn prom_escape(name: &str) -> String {
    name.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Signal stop to every queue with a live worker handle and join it.
/// Shared by `Drop` and the partial-startup failure path.
fn shutdown_workers(queues: &[Arc<ShardQueue>], workers: &mut [Option<JoinHandle<()>>]) {
    for (q, worker) in queues.iter().zip(workers.iter_mut()) {
        if let Some(w) = worker.take() {
            let (mtx, _mrx) = channel();
            q.signal_stop(mtx);
            let _ = w.join();
        }
    }
}

/// Drain everything the injector currently holds, plus a pending stop
/// signal if one arrived. Never blocks.
fn take_injector(q: &ShardQueue) -> (Vec<Job>, Option<Sender<Metrics>>) {
    let mut inner = q.inner.lock().unwrap();
    let jobs = inner.jobs.drain(..).collect();
    let stop = inner.stop.take();
    (jobs, stop)
}

/// Block until new work or a stop signal lands in the injector, bounded by
/// `timeout` (the batcher's next deadline). Spurious wakeups simply loop.
fn wait_for_work(q: &ShardQueue, timeout: Duration) {
    let inner = q.inner.lock().unwrap();
    if inner.jobs.is_empty() && inner.stop.is_none() {
        let _unused = q.cv.wait_timeout(inner, timeout).unwrap();
    }
}

/// Steal one whole ready batch (the oldest artifact group, up to
/// `max_batch` jobs) from the most loaded peer whose injector holds at
/// least `steal_min` jobs. Transfers the stolen jobs' load-gauge share
/// from the victim to the thief. Returns `None` when there is nothing
/// worth stealing (or the best victim's lock is contended — next idle poll
/// retries).
fn try_steal(
    queues: &[Arc<ShardQueue>],
    my_id: usize,
    steal_min: usize,
    max_batch: usize,
) -> Option<(usize, Vec<Job>)> {
    // Rank peers by load score, but probe them in descending order rather
    // than committing to the top one: the gauge overstates *stealable*
    // work (it includes jobs a victim already drained into its private
    // batcher), so the busiest-looking shard may have an empty injector
    // while a lower-scored peer's injector backlog goes unrelieved.
    // A dead queue (worker exited/panicked) is stealable down to a single
    // job — orphaned work must be rescued, not left to hang its callers.
    let mut candidates: Vec<(u64, usize)> = Vec::new();
    for (i, q) in queues.iter().enumerate() {
        if i == my_id {
            continue;
        }
        let min_jobs = if q.alive.load(Ordering::Relaxed) { steal_min } else { 1 };
        if q.load.depth() >= min_jobs {
            candidates.push((q.load.score_ns(), i));
        }
    }
    candidates.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    for (_, victim_id) in candidates {
        let victim = &queues[victim_id];
        let min_jobs = if victim.alive.load(Ordering::Relaxed) { steal_min } else { 1 };
        let Ok(mut inner) = victim.inner.try_lock() else {
            continue; // contended: try the next victim, re-poll soon
        };
        if inner.jobs.len() < min_jobs {
            continue;
        }
        // The oldest group is the batch closest to its deadline; taking
        // the whole group keeps the executable-cache story intact on both
        // sides.
        let anchor = inner.jobs.front().expect("len >= min_jobs >= 1").resolved.artifact().clone();
        let mut stolen = Vec::new();
        let mut rest = VecDeque::with_capacity(inner.jobs.len());
        while let Some(job) = inner.jobs.pop_front() {
            if stolen.len() < max_batch && job.resolved.artifact() == &anchor {
                stolen.push(job);
            } else {
                rest.push_back(job);
            }
        }
        inner.jobs = rest;
        drop(inner);
        let cost: u64 = stolen.iter().map(|j| j.cost_ns).sum();
        victim.load.sub(stolen.len(), cost);
        queues[my_id].load.add(stolen.len(), cost);
        return Some((victim_id, stolen));
    }
    None
}

/// The observability half of one shard's serve-time state, bundled so it
/// travels from `start_pool` into `shard_loop` as one value: the shared
/// flight recorder, the tenants' live exposition lanes, and the typed
/// reason drain-side sheds carry.
struct ShardSide {
    recorder: Option<Arc<FlightRecorder>>,
    lanes: Arc<Vec<Arc<TenantLive>>>,
    shed_reason: RejectReason,
    /// The pool-wide variant circuit breaker `run_batch` feeds per-job
    /// outcomes (and whose transitions it traces).
    quarantine: Arc<QuarantineSet>,
    /// Fault-injection plan: `Some` additionally arms the per-result
    /// integrity canary in `run_batch`. `None` in production pools — the
    /// canary then costs one branch per served result, no recompute.
    fault: Option<FaultPlan>,
    /// Exploration planner the drain side reports probe completions to
    /// (`None` = exploration off; probe jobs then cannot exist).
    explore: Option<Arc<ExplorePlanner>>,
}

/// Everything the drain-side paths (`run_batch`, `shed_jobs`) share for
/// one shard: its queue (load gauge + live counters), the observability
/// bundle, and the shard id events are stamped with.
struct ShardCtx {
    shard_id: u16,
    queue: Arc<ShardQueue>,
    side: ShardSide,
}

impl ShardCtx {
    /// Record one chain event if tracing is on (see [`FlightRecorder::event`]).
    #[inline]
    fn event(&self, seq: u64, kind: EventKind, tenant: u32, payload: [u64; 3]) {
        if let Some(rec) = self.side.recorder.as_deref() {
            rec.event(seq, kind, self.shard_id, tenant, payload);
        }
    }

    /// The live exposition lane for `lane`, or `None` for [`NO_LANE`].
    #[inline]
    fn lane(&self, lane: u32) -> Option<&TenantLive> {
        self.side.lanes.get(lane as usize).map(Arc::as_ref)
    }
}

/// Complete every job the shed hook pulled out of the batcher with a
/// rejection, releasing its load-gauge share and its admission
/// reservation. Runs on the shard thread at drain time — the
/// "shed-on-drain" stage of the admission state machine.
fn shed_jobs(shed: Vec<Pending<Job>>, budget: Duration, ctx: &ShardCtx, metrics: &mut Metrics) {
    let reason_idx = ctx.side.shed_reason.code() as usize;
    for pending in shed {
        // The handoff stamps `enqueued` with the submit instant, so the
        // wait measured here — and the latency `failure_response` derives
        // from the same stamp — is time since submit.
        let waited = pending.enqueued.elapsed();
        let job = pending.payload;
        metrics.shed += 1;
        ctx.queue.live.shed.fetch_add(1, Ordering::Relaxed);
        if !job.tenant.is_anonymous() {
            let lane = metrics.per_tenant.entry(job.tenant.0).or_default();
            lane.shed += 1;
            lane.shed_by_reason[reason_idx] += 1;
        }
        if let Some(live) = ctx.lane(job.lane) {
            live.shed_by[reason_idx].fetch_add(1, Ordering::Relaxed);
        }
        ctx.event(
            job.trace_seq,
            EventKind::Shed,
            job.tenant.0,
            [waited.as_nanos() as u64, budget.as_nanos() as u64, 0],
        );
        ctx.queue.load.sub(1, job.cost_ns);
        // Release the reservation before responding, like the gauge: a
        // blocking caller must be admittable as soon as it wakes.
        drop(job.reservation);
        job.completion.complete(failure_response(
            format!(
                "shed: queued {}us, past the {}us admission queue budget",
                waited.as_micros(),
                budget.as_micros()
            ),
            pending.enqueued,
        ));
    }
}

/// One shed pass over a shard's batcher: remove and reject everything
/// past the queue budget (no-op without one). Shared by the serve loop
/// and the shutdown flush so the two can never diverge.
fn shed_pass(
    batcher: &mut Batcher<Job>,
    queue_budget: Option<Duration>,
    ctx: &ShardCtx,
    metrics: &mut Metrics,
) {
    if let Some(budget) = queue_budget {
        let shed = batcher.shed_overdue(budget);
        if !shed.is_empty() {
            shed_jobs(shed, budget, ctx, metrics);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_loop(
    shard_id: usize,
    artifacts_dir: PathBuf,
    engine: EngineKind,
    batcher_cfg: BatcherConfig,
    queues: Arc<Vec<Arc<ShardQueue>>>,
    steal_min: usize,
    queue_budget: Option<Duration>,
    domains: Arc<Vec<ShardDomain>>,
    side: ShardSide,
    ready: Sender<Result<(), String>>,
) {
    let my = queues[shard_id].clone();
    let ctx = ShardCtx { shard_id: shard_id as u16, queue: my.clone(), side };
    // Clears `my.alive` on every exit path — normal stop, failed backend
    // init, or a panic unwinding — so the router and the steal path know
    // this queue is orphaned.
    let _alive = AliveGuard(my.clone());
    let mut backend = match engine.create(&artifacts_dir) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(format!("backend init: {e}")));
            return;
        }
    };
    // Fault injection: wrap the backend in the seeded fault proxy. An
    // absent or inert plan (and one targeting another shard) skips the
    // wrap entirely, so the no-fault pool runs the unwrapped backend —
    // asserted bit-identical by the `fault_plan_off` tests.
    if let Some(plan) = ctx.side.fault {
        if !plan.is_inert() && plan.applies_to_shard(shard_id) {
            backend = Box::new(FaultyBackend::new(backend, plan, shard_id));
        }
    }
    let max_batch = batcher_cfg.max_batch.max(1);
    let mut batcher: Batcher<Job> = Batcher::new(batcher_cfg);
    let mut metrics = Metrics::default();
    // Re-arm the liveness flag: on a respawn the dead predecessor's
    // AliveGuard cleared it, and the router must start counting this
    // shard as alive again exactly when it is ready to serve.
    my.alive.store(true, Ordering::Relaxed);
    let _ = ready.send(Ok(()));

    let mut stop_reply: Option<Sender<Metrics>> = None;
    loop {
        // Pull everything the injector holds; stolen or fresh, a job's
        // wait-clock starts at submit, so deadlines survive the handoff.
        let (jobs, stop) = take_injector(&my);
        for job in jobs {
            let artifact = job.resolved.artifact().clone();
            batcher.push_pending(Pending { artifact, enqueued: job.t_submit, payload: job });
        }
        if let Some(reply) = stop {
            stop_reply = Some(reply);
            break;
        }

        // Serve every batch that is due, shedding first: work that has
        // already waited past the admission queue budget is not worth
        // serving — completing it now with a rejection is cheaper for
        // everyone than serving it late and delaying everything queued
        // behind it. The shed check re-runs before *every* batch, because
        // a batch's own execution time is exactly what pushes the work
        // queued behind it over the budget.
        let mut ran = false;
        loop {
            shed_pass(&mut batcher, queue_budget, &ctx, &mut metrics);
            let Some((artifact, group)) = batcher.drain_due() else { break };
            run_batch(backend.as_mut(), &ctx, &artifact, group, &domains, &mut metrics);
            ran = true;
        }
        if ran {
            continue; // re-check the injector before sleeping
        }

        // Fully idle: relieve the most loaded peer before going to sleep.
        if batcher.is_empty() {
            if let Some((victim, stolen)) = try_steal(&queues, shard_id, steal_min, max_batch) {
                metrics.steals += 1;
                metrics.stolen_requests += stolen.len();
                my.live.steals.fetch_add(1, Ordering::Relaxed);
                my.live.stolen_requests.fetch_add(stolen.len() as u64, Ordering::Relaxed);
                ctx.event(
                    0,
                    EventKind::Steal,
                    0,
                    [victim as u64, stolen.len() as u64, 0],
                );
                for job in stolen {
                    let artifact = job.resolved.artifact().clone();
                    batcher.push_pending(Pending {
                        artifact,
                        enqueued: job.t_submit,
                        payload: job,
                    });
                }
                continue; // aged entries: drain_due fires immediately
            }
        }

        let timeout = batcher.next_deadline().unwrap_or(IDLE_POLL);
        wait_for_work(&my, timeout);
    }

    // Flush outstanding work before stopping (still shedding what the
    // queue budget already wrote off — shutdown must not serve it late,
    // and each flushed batch's execution time can push the work queued
    // behind it over the budget, so the check re-runs per batch here too).
    loop {
        shed_pass(&mut batcher, queue_budget, &ctx, &mut metrics);
        let Some((artifact, group)) = batcher.drain_next() else { break };
        run_batch(backend.as_mut(), &ctx, &artifact, group, &domains, &mut metrics);
    }
    if let Some(reply) = stop_reply {
        let _ = reply.send(metrics);
    }
}

/// Recompute output element (0, 0, 0) as the ascending-k dot product of
/// the first LHS row and the first RHS column — the exact accumulation
/// (including the zero-LHS skip) of the reference `host_gemm`, which the
/// native CPU variant family reproduces bit-for-bit. A mismatch means
/// the backend delivered a silently corrupted result; refusing it here
/// turns corruption into an execution failure (counted in the metrics
/// and fed to the quarantine tracker), so a corrupt result is never
/// delivered as `Ok`. Only run while a fault plan is configured.
///
/// [`host_gemm`]: crate::engine::sim::host_gemm
fn integrity_canary(out: &[f32], req: &GemmRequest) -> Result<(), String> {
    let (k, n) = (req.shape.k, req.shape.n);
    if k == 0 || req.lhs.len() < k || req.rhs.len() < (k - 1) * n + 1 {
        return Ok(()); // degenerate request: nothing to verify
    }
    let mut expect = 0.0f32;
    for (kk, &a) in req.lhs[..k].iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        expect += a * req.rhs[kk * n];
    }
    match out.first() {
        Some(got) if got.to_bits() == expect.to_bits() => Ok(()),
        _ => Err(format!(
            "corrupt result detected: output[0] disagrees with the reference \
             dot product (expected {expect})"
        )),
    }
}

fn run_batch(
    backend: &mut dyn Backend,
    ctx: &ShardCtx,
    artifact: &Arc<str>,
    group: Vec<Pending<Job>>,
    domains: &[ShardDomain],
    metrics: &mut Metrics,
) {
    let t_batch = Instant::now();
    let load = &ctx.queue.load;
    let n_jobs = group.len();
    metrics.record_batch(group.len());
    metrics.record_occupancy(load.depth());
    ctx.queue.live.batches.fetch_add(1, Ordering::Relaxed);
    if ctx.side.recorder.is_some() {
        // The oldest job's wait is the batch's age — how long the drain
        // lagged the first submit it serves.
        let oldest_ns = group
            .iter()
            .map(|p| p.enqueued.elapsed().as_nanos() as u64)
            .max()
            .unwrap_or(0);
        ctx.event(0, EventKind::Batch, 0, [n_jobs as u64, oldest_ns, 0]);
    }
    // One prepare per batch: first touch compiles, later batches hit the
    // backend's executable cache (kept hot by the affinity preference).
    let prepared = match group.first() {
        Some(p) => backend.prepare(&p.payload.resolved.meta),
        None => return,
    };
    for pending in group {
        let job = pending.payload;
        // The job's retune domain: its sink and pinned pricing device.
        // Domain 0 always exists; an out-of-range index (impossible by
        // construction) degrades to it rather than panicking a shard.
        let dom = domains.get(job.domain as usize).unwrap_or(&domains[0]);
        let mut measured_ns = 0u64;
        let result = match &prepared {
            Ok(()) => {
                let run = backend.execute_timed_for(
                    &job.resolved.meta,
                    &job.req.shape,
                    &job.req.lhs,
                    &job.req.rhs,
                    dom.device,
                );
                match run {
                    Ok((out, measured_secs)) => {
                        measured_ns = (measured_secs * 1e9) as u64;
                        // Close the loop: the measured execution time of
                        // this (shape, config) cell feeds cost hints and
                        // the background retuner — of the job's domain.
                        // Probe-redirected requests record with probe
                        // provenance (the `probed` snapshot counter) and
                        // count toward the planner's completion tally.
                        if job.probe {
                            dom.telemetry.record_probe(
                                job.req.shape,
                                job.resolved.meta.config_index,
                                measured_secs,
                            );
                            if let Some(planner) = ctx.side.explore.as_deref() {
                                planner.note_completed();
                            }
                            ctx.event(
                                0,
                                EventKind::ExploreProbe,
                                0,
                                [
                                    job.resolved.meta.config_index.map_or(0, |c| c as u64),
                                    measured_ns,
                                    0,
                                ],
                            );
                        } else {
                            dom.telemetry.record(
                                job.req.shape,
                                job.resolved.meta.config_index,
                                measured_secs,
                            );
                        }
                        // Integrity canary, armed only under a fault
                        // plan: silent corruption must surface as `Err`,
                        // never be delivered as `Ok`.
                        if ctx.side.fault.is_some() {
                            integrity_canary(&out, &job.req).map(|()| out)
                        } else {
                            Ok(out)
                        }
                    }
                    Err(e) => Err(e),
                }
            }
            Err(e) => Err(e.clone()),
        };
        let latency = job.t_submit.elapsed();
        if result.is_err() {
            metrics.failures += 1;
        }
        if job.spilled {
            metrics.spilled += 1;
            ctx.queue.live.spilled.fetch_add(1, Ordering::Relaxed);
        }
        metrics.record_resolution(&job.resolved.resolution);
        let config_used = job.resolved.meta.config_index;
        metrics.record_request(latency.as_secs_f64(), config_used);
        // Feed the circuit breaker. The healthy-success fast path is one
        // relaxed load inside `observe`; a transition is rare enough to
        // trace unconditionally (pool-level events, seq 0).
        if let Some(transition) = ctx.side.quarantine.observe(config_used, result.is_ok()) {
            let q = ctx.side.quarantine.as_ref();
            let config = config_used.map_or(0, |c| c as u64);
            match transition {
                Transition::Tripped => {
                    ctx.event(0, EventKind::QuarantineTrip, 0, [config, q.trips(), 0]);
                }
                Transition::Probed => {
                    ctx.event(0, EventKind::QuarantineProbe, 0, [config, 0, 0]);
                }
                Transition::Restored => {
                    ctx.event(0, EventKind::QuarantineRestore, 0, [config, q.restores(), 0]);
                }
            }
        }
        ctx.queue.live.requests.fetch_add(1, Ordering::Relaxed);
        if !job.tenant.is_anonymous() {
            let in_slo = result.is_ok() && job.slo_wall.map_or(true, |wall| latency <= wall);
            metrics.record_tenant(job.tenant.0, latency.as_secs_f64(), in_slo);
            if let Some(live) = ctx.lane(job.lane) {
                live.requests.fetch_add(1, Ordering::Relaxed);
                if in_slo {
                    live.in_slo.fetch_add(1, Ordering::Relaxed);
                }
                live.latency.record_ns(latency.as_nanos() as u64);
            }
        }
        if let Some(rec) = ctx.side.recorder.as_deref() {
            // The swap timeline: the first served job carrying a new
            // selector generation emits the domain's Swap event.
            rec.note_generation(job.domain as usize, job.resolved.generation);
            let config_code = config_used.map_or(0, |c| c as u64 + 1);
            ctx.event(
                job.trace_seq,
                EventKind::Execute,
                job.tenant.0,
                [
                    config_code | (job.resolved.generation << 32),
                    job.cost_ns,
                    measured_ns,
                ],
            );
            ctx.event(
                job.trace_seq,
                EventKind::Complete,
                job.tenant.0,
                [latency.as_nanos() as u64, u64::from(result.is_ok()), 0],
            );
        }
        // Release the gauge (and the admission reservation) before
        // responding: a blocking caller must see an up-to-date load when
        // it submits its next request.
        load.sub(1, job.cost_ns);
        drop(job.reservation);
        job.completion.complete(GemmResponse {
            result,
            config_used,
            artifact: artifact.clone(),
            latency,
        });
    }
    // Fold this batch into the shard's measured drain rate — the signal
    // admission retry hints are priced on once it is warm.
    load.note_completions(n_jobs, t_batch.elapsed().as_secs_f64());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tenant::SloClass;
    use crate::dataset::config_by_name;
    use crate::engine::sim::host_gemm;
    use crate::util::fill_buffer;
    use std::path::PathBuf;

    fn sim_pool(shards: usize, policy: SelectorPolicy) -> Coordinator {
        Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            policy,
            PoolConfig { shards, ..PoolConfig::default() },
        )
        .expect("coordinator start")
    }

    #[test]
    fn serves_single_request_with_correct_result() {
        let coord = sim_pool(1, SelectorPolicy::Xla);
        let shape = GemmShape::new(64, 64, 64, 1);
        let lhs = fill_buffer(1, 64 * 64);
        let rhs = fill_buffer(2, 64 * 64);
        let resp = coord.call(shape, lhs.clone(), rhs.clone()).unwrap();
        let out = resp.result.expect("gemm result");
        assert_eq!(out, host_gemm(&shape, &lhs, &rhs).unwrap());
        let metrics = coord.stop();
        assert_eq!(metrics.requests, 1);
        assert_eq!(metrics.failures, 0);
    }

    #[test]
    fn every_request_answered_exactly_once_across_shards() {
        let coord = std::sync::Arc::new(sim_pool(4, SelectorPolicy::Xla));
        let n_threads = 4;
        let per_thread = 6;
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(64, 64, 64, 4),
        ];
        let mut joins = Vec::new();
        for t in 0..n_threads {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let mut got = 0;
                for i in 0..per_thread {
                    let shape = shapes[(t + i) % shapes.len()];
                    let lhs =
                        fill_buffer((t * 100 + i) as u32, shape.batch * shape.m * shape.k);
                    let rhs = fill_buffer(
                        (t * 100 + i + 50) as u32,
                        shape.batch * shape.k * shape.n,
                    );
                    let rx = coord.submit(shape, lhs, rhs);
                    let resp = rx.recv().expect("response");
                    assert!(resp.result.is_ok());
                    got += 1;
                }
                got
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, n_threads * per_thread);
        let report = std::sync::Arc::try_unwrap(coord)
            .ok()
            .expect("sole owner")
            .stop_detailed();
        assert_eq!(report.per_shard.len(), 4);
        assert_eq!(report.total.requests, n_threads * per_thread);
        assert_eq!(report.total.failures, 0);
        assert!(report.total.mean_batch_size() >= 1.0);
        // 3 distinct shapes, many lookups: the memoized selector must hit.
        // Concurrent first touches can each count a miss (get-then-insert
        // is not atomic), so the bound is per-thread, not global.
        let worst_case_misses = 3 * n_threads;
        assert!(report.cache_hits >= n_threads * per_thread - worst_case_misses);
        assert_eq!(report.cache_hits + report.cache_misses, n_threads * per_thread);
    }

    #[test]
    fn shape_affinity_concentrates_an_artifact_on_one_shard() {
        // Sequential blocking calls keep every gauge at zero at submit
        // time, so even the default load-aware router must stay on the
        // affinity fast path — the common case keeps caches hot.
        let coord = sim_pool(4, SelectorPolicy::Xla);
        let shape = GemmShape::new(32, 32, 32, 1);
        for i in 0..8 {
            let lhs = fill_buffer(i, 32 * 32);
            let rhs = fill_buffer(i + 9, 32 * 32);
            coord.call(shape, lhs, rhs).unwrap().result.unwrap();
        }
        let report = coord.stop_detailed();
        assert_eq!(report.total.spilled, 0);
        let busy: Vec<usize> = report
            .per_shard
            .iter()
            .filter(|m| m.requests > 0)
            .map(|m| m.requests)
            .collect();
        assert_eq!(busy, vec![8], "one shape must be served by exactly one shard");
    }

    #[test]
    fn unknown_shape_fails_cleanly() {
        let coord = sim_pool(2, SelectorPolicy::Xla);
        let resp = coord
            .call(GemmShape::new(17, 19, 23, 1), vec![0.0; 17 * 19], vec![0.0; 19 * 23])
            .unwrap();
        assert!(resp.result.is_err());
        let metrics = coord.stop();
        assert_eq!(metrics.failures, 1);
        assert_eq!(metrics.requests, 0, "rejected requests never reach a shard");
    }

    #[test]
    fn tuned_policy_uses_deployed_config() {
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let coord = sim_pool(2, SelectorPolicy::Single(best));
        let shape = GemmShape::new(128, 128, 128, 1);
        let resp = coord
            .call(shape, fill_buffer(1, 128 * 128), fill_buffer(2, 128 * 128))
            .unwrap();
        assert_eq!(resp.config_used, Some(best));
        assert!(resp.result.is_ok());
        let metrics = coord.stop();
        assert_eq!(metrics.fallback_config + metrics.fallback_xla, 0);
    }

    #[test]
    fn fallback_resolutions_recorded_per_request() {
        // r1a1c1_wg8x8 is legal but not in the synthetic deployment, so a
        // Single policy for it must fall back to the XLA artifact at every
        // shipped bucket — and the shard must count each fallback.
        let undeployed = config_by_name("r1a1c1_wg8x8").unwrap().index();
        let coord = sim_pool(2, SelectorPolicy::Single(undeployed));
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..3 {
            let resp = coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 7, 64 * 64))
                .unwrap();
            assert!(resp.result.is_ok());
            assert_eq!(resp.config_used, None, "served by the XLA comparator");
        }
        let metrics = coord.stop();
        assert_eq!(metrics.fallback_xla, 3);
        assert_eq!(metrics.fallback_config, 0);
    }

    #[test]
    fn resolution_cache_serves_repeat_shapes() {
        let coord = sim_pool(1, SelectorPolicy::Xla);
        let shape = GemmShape::new(32, 32, 32, 1);
        for i in 0..4 {
            coord
                .call(shape, fill_buffer(i, 32 * 32), fill_buffer(i + 3, 32 * 32))
                .unwrap()
                .result
                .unwrap();
        }
        let (hits, misses) = coord.selector_cache_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 3);
        coord.stop();
    }

    #[test]
    fn multi_shard_handles_mixed_shapes_with_direct_resolutions() {
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let coord = sim_pool(3, SelectorPolicy::Single(best));
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(32, 32, 32, 4),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(64, 64, 64, 4),
        ];
        for (i, shape) in shapes.iter().cycle().take(12).enumerate() {
            let lhs = fill_buffer(i as u32, shape.batch * shape.m * shape.k);
            let rhs = fill_buffer((i + 5) as u32, shape.batch * shape.k * shape.n);
            let resp = coord.call(*shape, lhs, rhs).unwrap();
            assert!(resp.result.is_ok());
        }
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, 12);
        assert_eq!(report.total.failures, 0);
        assert!(report.summary().contains("shard 0:"));
        // Registry resolutions were direct for a deployed config.
        assert_eq!(report.total.fallback_config + report.total.fallback_xla, 0);
    }

    #[test]
    fn routing_flag_roundtrip() {
        assert_eq!(Routing::by_name("affinity"), Some(Routing::Affinity));
        assert_eq!(Routing::by_name("load-aware"), Some(Routing::LoadAware));
        assert_eq!(Routing::by_name("load_aware"), Some(Routing::LoadAware));
        assert_eq!(Routing::by_name("bogus"), None);
        assert_eq!(Routing::default().name(), "load-aware");
    }

    /// Deterministic 90/10-skew request by global submission index.
    fn skewed_input(i: usize) -> (GemmShape, Vec<f32>, Vec<f32>) {
        let hot = GemmShape::new(32, 32, 32, 1);
        let cold = [
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(32, 32, 32, 4),
            GemmShape::new(128, 128, 128, 1),
        ];
        let shape = if i % 10 == 9 { cold[(i / 10) % cold.len()] } else { hot };
        let lhs = fill_buffer(i as u32, shape.batch * shape.m * shape.k);
        let rhs = fill_buffer((i + 13) as u32, shape.batch * shape.k * shape.n);
        (shape, lhs, rhs)
    }

    /// Submit `n` requests of the 90/10 skewed mix asynchronously (all
    /// tickets collected first, then drained), returning every result
    /// in submission order plus the shutdown report.
    fn run_skewed(n: usize, shards: usize, routing: Routing) -> (Vec<Vec<f32>>, PoolReport) {
        run_skewed_with(n, shards, routing, AdmissionPolicy::default())
    }

    fn run_skewed_with(
        n: usize,
        shards: usize,
        routing: Routing,
        admission: AdmissionPolicy,
    ) -> (Vec<Vec<f32>>, PoolReport) {
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig { shards, routing, imbalance: 1.0, admission, ..PoolConfig::default() },
        )
        .expect("coordinator start");
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            let (shape, lhs, rhs) = skewed_input(i);
            rxs.push(coord.submit(shape, lhs, rhs));
        }
        let results: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("response").result.expect("gemm ok"))
            .collect();
        (results, coord.stop_detailed())
    }

    #[test]
    fn skewed_pool_results_bit_identical_to_single_shard() {
        // 1000 requests, 90% one shape: the 4-shard load-aware pool must
        // return bit-identical results to the 1-shard run, and the merged
        // PoolReport counters must equal the per-shard sums (steals and
        // spills included).
        let n = 1000;
        let (base, base_report) = run_skewed(n, 1, Routing::Affinity);
        let (wide, report) = run_skewed(n, 4, Routing::LoadAware);
        assert_eq!(base.len(), n);
        assert_eq!(base, wide, "results must not depend on pool width or routing");
        assert_eq!(base_report.total.requests, n);
        assert_eq!(report.total.requests, n);
        assert_eq!(report.total.failures, 0);
        assert_eq!(report.per_shard.len(), 4);

        // Exact aggregation: merged totals == per-shard sums, field by field.
        let sum = |f: fn(&Metrics) -> usize| -> usize {
            report.per_shard.iter().map(f).sum()
        };
        assert_eq!(report.total.requests, sum(|m| m.requests));
        assert_eq!(report.total.batches, sum(|m| m.batches));
        assert_eq!(report.total.failures, sum(|m| m.failures));
        assert_eq!(report.total.spilled, sum(|m| m.spilled));
        assert_eq!(report.total.steals, sum(|m| m.steals));
        assert_eq!(report.total.stolen_requests, sum(|m| m.stolen_requests));
        assert_eq!(
            report.total.occupancy.iter().sum::<usize>(),
            report
                .per_shard
                .iter()
                .map(|m| m.occupancy.iter().sum::<usize>())
                .sum::<usize>()
        );

        // The burst dwarfs a single shard: the tight imbalance threshold
        // must have spilled part of the hot shape to idle shards.
        assert!(
            report.total.spilled > 0,
            "a 90% hot-shape burst at imbalance=1.0 must spill\n{}",
            report.summary()
        );

        // The default pool has no admission: nothing rejected or shed,
        // and the in-flight peak is never even tracked.
        assert_eq!(report.total.rejected, 0);
        assert_eq!(report.total.shed, 0);
        assert_eq!(report.total.inflight_peak, 0);
    }

    #[test]
    fn explicit_unbounded_admission_bit_identical_to_default_pool() {
        // Satellite acceptance: `AdmissionPolicy::Unbounded` must be the
        // pre-admission behavior exactly — same 1000-request 90/10 mix,
        // same results bit-for-bit, same counter totals, nothing rejected
        // or shed, and the merged counters still equal the per-shard sums.
        let n = 1000;
        let (base, base_report) = run_skewed(n, 4, Routing::LoadAware);
        let (explicit, report) =
            run_skewed_with(n, 4, Routing::LoadAware, AdmissionPolicy::Unbounded);
        assert_eq!(base, explicit, "explicit Unbounded must not change any result");
        assert_eq!(base_report.total.requests, report.total.requests);
        assert_eq!(base_report.total.failures, report.total.failures);
        assert_eq!(report.total.rejected, 0);
        assert_eq!(report.total.shed, 0);
        assert_eq!(report.total.inflight_peak, 0, "Unbounded never scans the gauges");
        let sum = |f: fn(&Metrics) -> usize| -> usize {
            report.per_shard.iter().map(f).sum()
        };
        assert_eq!(report.total.requests, sum(|m| m.requests));
        assert_eq!(report.total.shed, sum(|m| m.shed));
    }

    #[test]
    fn zero_inflight_cap_rejects_everything_without_touching_shards() {
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 2,
                admission: AdmissionPolicy::BoundedQueue {
                    max_inflight: 0,
                    max_queue_ns: u64::MAX,
                },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..50u32 {
            let ticket = coord.submit(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 1, 64 * 64));
            let err = ticket.rejection().expect("every submit must be rejected");
            assert_eq!(
                err.reason(),
                crate::coordinator::admission::RejectReason::QueueFull
            );
            assert!(err.retry_after_hint().is_some());
            let resp = ticket.wait();
            assert!(resp.result.unwrap_err().contains("queue-full"));
        }
        let report = coord.stop_detailed();
        assert_eq!(report.total.rejected, 50);
        assert_eq!(report.total.requests, 0, "rejected requests never reach a shard");
        assert_eq!(report.total.failures, 0, "rejections are not failures");
    }

    #[test]
    fn bounded_queue_rejects_overload_burst_with_typed_outcomes() {
        // An open 40-request burst against max_inflight=2 on one shard:
        // a couple admitted, the rest refused fast with a typed error.
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                admission: AdmissionPolicy::BoundedQueue {
                    max_inflight: 2,
                    max_queue_ns: u64::MAX,
                },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(128, 128, 128, 1);
        let tickets: Vec<Ticket> = (0..40u32)
            .map(|i| {
                coord.submit(shape, fill_buffer(i, 128 * 128), fill_buffer(i + 3, 128 * 128))
            })
            .collect();
        let mut ok = 0usize;
        let mut rejected = 0usize;
        for ticket in tickets {
            if ticket.rejection().is_some() {
                rejected += 1;
                assert!(ticket.wait().result.is_err());
            } else {
                assert!(ticket.wait().result.is_ok());
                ok += 1;
            }
        }
        assert_eq!(ok + rejected, 40);
        assert!(ok >= 2, "at least the first two must be admitted");
        assert!(rejected >= 1, "an open burst against max_inflight=2 must reject");
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, ok);
        assert_eq!(report.total.rejected, rejected);
        assert_eq!(report.total.failures, 0);
        assert!(
            (1..=2).contains(&report.total.inflight_peak),
            "peak {} must respect max_inflight=2",
            report.total.inflight_peak
        );
    }

    #[test]
    fn submit_many_partial_admission_returns_per_request_outcomes() {
        // One same-shape run of 40 against max_inflight=4: the run is
        // judged incrementally against its own admitted prefix, and the
        // jobs only land on the shard after the run is built — so exactly
        // the first 4 are admitted, deterministically.
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                admission: AdmissionPolicy::BoundedQueue {
                    max_inflight: 4,
                    max_queue_ns: u64::MAX,
                },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        let requests: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = (0..40u32)
            .map(|i| (shape, fill_buffer(i, 64 * 64), fill_buffer(i + 9, 64 * 64)))
            .collect();
        let tickets = coord.submit_many(requests);
        assert_eq!(tickets.len(), 40, "every request gets a ticket, admitted or not");
        for (i, ticket) in tickets.into_iter().enumerate() {
            if i < 4 {
                assert!(ticket.rejection().is_none(), "request {i} must be admitted");
                assert!(ticket.wait().result.is_ok());
            } else {
                let err = ticket
                    .rejection()
                    .unwrap_or_else(|| panic!("request {i} must be rejected"));
                assert_eq!(
                    err.reason(),
                    crate::coordinator::admission::RejectReason::QueueFull
                );
            }
        }
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, 4);
        assert_eq!(report.total.rejected, 36);
        assert_eq!(report.total.inflight_peak, 4);
    }

    #[test]
    fn deadline_shed_rejects_unmeetable_and_admits_feasible() {
        // A 1ns deadline can never be met (every cost hint exceeds it).
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                admission: AdmissionPolicy::DeadlineShed { deadline_ns: 1 },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        let ticket = coord.submit(shape, fill_buffer(1, 64 * 64), fill_buffer(2, 64 * 64));
        let err = ticket.rejection().expect("1ns deadline is unmeetable");
        assert_eq!(
            err.reason(),
            crate::coordinator::admission::RejectReason::DeadlineUnmeetable
        );
        let report = coord.stop_detailed();
        assert_eq!(report.total.rejected, 1);
        assert_eq!(report.total.requests, 0);

        // A generous deadline admits sequential traffic entirely.
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                admission: AdmissionPolicy::DeadlineShed { deadline_ns: u64::MAX / 2 },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        for i in 0..8u32 {
            let resp = coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 5, 64 * 64))
                .unwrap();
            assert!(resp.result.is_ok());
        }
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, 8);
        assert_eq!(report.total.rejected, 0);
        assert_eq!(
            report.total.inflight_peak, 0,
            "DeadlineShed never reads the in-flight count, so no peak is tracked"
        );
    }

    #[test]
    fn queued_work_past_budget_is_shed_at_drain_not_served_late() {
        // Paced backend: each 128^3 execute sleeps >= ~0.9ms of simulated
        // device time, so a 12-deep queue against a 2ms wall budget and
        // max_batch=4 guarantees that everything behind the first batch
        // has blown the budget by the time the shard drains again. The
        // admit-side gauge backlog stays under max_queue_ns (12 requests
        // x ~64k gauge-ns < 2M), so all 12 are admitted — this test
        // isolates the shed-on-drain stage.
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                engine: EngineKind::SimPaced { profile: "i7-6700k", permille: 20_000 },
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                },
                admission: AdmissionPolicy::BoundedQueue {
                    max_inflight: 1000,
                    max_queue_ns: 2_000_000, // 2ms
                },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(128, 128, 128, 1);
        let tickets: Vec<Ticket> = (0..12u32)
            .map(|i| {
                coord.submit(shape, fill_buffer(i, 128 * 128), fill_buffer(i + 7, 128 * 128))
            })
            .collect();
        let mut served = 0usize;
        let mut shed = 0usize;
        for ticket in tickets {
            assert!(ticket.rejection().is_none(), "the whole burst fits the admit budget");
            let resp = ticket.wait();
            match resp.result {
                Ok(_) => served += 1,
                Err(e) => {
                    assert!(e.contains("shed"), "only shed errors expected, got: {e}");
                    shed += 1;
                }
            }
        }
        assert_eq!(served + shed, 12, "every ticket resolves exactly once");
        // No `served >= 1` assert: on a heavily descheduled runner the
        // shard's first drain can itself come later than the 2ms wall
        // budget, legitimately shedding everything. `shed >= 1` holds
        // either way — 12 admitted jobs can never all fit the first
        // max_batch=4 batch.
        assert!(shed >= 1, "work behind the first batch must be shed, not served late");
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, served);
        assert_eq!(report.total.shed, shed);
        assert_eq!(report.total.rejected, 0);
    }

    #[test]
    fn concurrent_submit_many_bit_identical_to_sequential_submit() {
        // Tentpole acceptance: the same 1000-request 90/10 workload,
        // submitted as four concurrent `submit_many` batches, must be
        // bit-identical to sequential `submit`, and the folded (striped
        // frontend + per-shard) counters must equal the per-shard sums.
        let n = 1000;
        let per_thread = n / 4;

        // Sequential reference on a single shard.
        let coord = sim_pool(1, SelectorPolicy::Xla);
        let rxs: Vec<Ticket> = (0..n)
            .map(|i| {
                let (shape, lhs, rhs) = skewed_input(i);
                coord.submit(shape, lhs, rhs)
            })
            .collect();
        let base: Vec<Vec<f32>> =
            rxs.into_iter().map(|t| t.wait().result.expect("gemm ok")).collect();
        coord.stop();

        // Concurrent submit_many on a 4-shard pool.
        let coord = std::sync::Arc::new(sim_pool(4, SelectorPolicy::Xla));
        let mut joins = Vec::new();
        for t in 0..4usize {
            let coord = coord.clone();
            joins.push(std::thread::spawn(move || {
                let chunk: Vec<(GemmShape, Vec<f32>, Vec<f32>)> =
                    (t * per_thread..(t + 1) * per_thread).map(skewed_input).collect();
                let tickets = coord.submit_many(chunk);
                assert_eq!(tickets.len(), per_thread);
                tickets
                    .into_iter()
                    .map(|ticket| ticket.wait().result.expect("gemm ok"))
                    .collect::<Vec<Vec<f32>>>()
            }));
        }
        let mut wide: Vec<Vec<f32>> = Vec::with_capacity(n);
        for join in joins {
            wide.extend(join.join().unwrap());
        }
        assert_eq!(base, wide, "submit_many must not change any result");

        let report = std::sync::Arc::try_unwrap(coord)
            .ok()
            .expect("sole owner")
            .stop_detailed();
        assert_eq!(report.total.requests, n);
        assert_eq!(report.total.failures, 0);
        let sum = |f: fn(&Metrics) -> usize| -> usize {
            report.per_shard.iter().map(f).sum()
        };
        assert_eq!(report.total.requests, sum(|m| m.requests));
        assert_eq!(report.total.batches, sum(|m| m.batches));
        assert_eq!(report.total.failures, sum(|m| m.failures));
        assert_eq!(report.total.spilled, sum(|m| m.spilled));
        assert_eq!(report.total.steals, sum(|m| m.steals));
        assert_eq!(report.total.stolen_requests, sum(|m| m.stolen_requests));
    }

    #[test]
    fn submit_many_preserves_order_and_reports_failures_inline() {
        let coord = sim_pool(2, SelectorPolicy::Xla);
        let ok_shape = GemmShape::new(64, 64, 64, 1);
        let bad_shape = GemmShape::new(17, 19, 23, 1); // no artifact
        let requests = vec![
            (ok_shape, fill_buffer(1, 64 * 64), fill_buffer(2, 64 * 64)),
            (ok_shape, fill_buffer(3, 64 * 64), fill_buffer(4, 64 * 64)),
            (bad_shape, vec![0.0; 17 * 19], vec![0.0; 19 * 23]),
            (ok_shape, fill_buffer(5, 64 * 64), fill_buffer(6, 64 * 64)),
        ];
        let tickets = coord.submit_many(requests);
        assert_eq!(tickets.len(), 4);
        let responses: Vec<GemmResponse> = tickets.into_iter().map(|t| t.wait()).collect();
        assert!(responses[0].result.is_ok());
        assert!(responses[1].result.is_ok());
        assert!(responses[2].result.is_err(), "unknown shape fails in place");
        assert!(responses[3].result.is_ok());
        // Same-shape runs share one resolution: 2 requests in the first
        // run hit the cache at most once past the initial miss.
        let metrics = coord.stop();
        assert_eq!(metrics.requests, 3, "only resolvable requests reach shards");
        assert_eq!(metrics.failures, 1);
    }

    #[test]
    fn hot_swap_under_traffic_serves_old_or_new_never_torn_or_stale() {
        // Satellite: N client threads submitting across a swap must only
        // ever observe the old deployment or the new one (never a mix),
        // and once the swap + cache invalidation completed, no resolution
        // from the stale generation may be served.
        let a = config_by_name("r8a4c4_wg16x16").unwrap().index();
        let b = config_by_name("r2a4c8_wg8x32").unwrap().index();
        let coord = std::sync::Arc::new(Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Single(a),
            PoolConfig { shards: 4, ..PoolConfig::default() },
        )
        .expect("coordinator start"));
        let swapped = std::sync::Arc::new(AtomicBool::new(false));
        let shape = GemmShape::new(64, 64, 64, 1);
        let mut joins = Vec::new();
        for t in 0..4u32 {
            let coord = coord.clone();
            let swapped = swapped.clone();
            joins.push(std::thread::spawn(move || {
                let mut stale_after_swap = 0usize;
                for i in 0..60u32 {
                    // Read the marker *before* submitting: if the swap
                    // completed before this request was even created, a
                    // stale resolution would prove the invalidation leaky.
                    let swap_was_done = swapped.load(Ordering::SeqCst);
                    let lhs = fill_buffer(t * 1000 + i, 64 * 64);
                    let rhs = fill_buffer(t * 1000 + i + 7, 64 * 64);
                    let resp = coord.call(shape, lhs, rhs).expect("response");
                    assert!(resp.result.is_ok());
                    let served = resp.config_used.expect("direct resolution");
                    assert!(
                        served == a || served == b,
                        "torn deployment: config {served} is neither old nor new"
                    );
                    if swap_was_done && served == a {
                        stale_after_swap += 1;
                    }
                }
                stale_after_swap
            }));
        }
        std::thread::sleep(Duration::from_millis(3));
        let generation = coord.swap_selector(SelectorPolicy::Single(b));
        assert_eq!(generation, 1);
        swapped.store(true, Ordering::SeqCst);
        let stale: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(stale, 0, "stale-generation resolutions served after invalidation");
        // New traffic resolves under the new deployment.
        let resp = coord
            .call(shape, fill_buffer(1, 64 * 64), fill_buffer(2, 64 * 64))
            .unwrap();
        assert_eq!(resp.config_used, Some(b));
        let report = std::sync::Arc::try_unwrap(coord)
            .ok()
            .expect("sole owner")
            .stop_detailed();
        assert_eq!(report.total.selector_swaps, 1);
        assert_eq!(report.total.failures, 0);
        // Pool totals still equal the per-shard sums for shard counters.
        assert_eq!(
            report.total.requests,
            report.per_shard.iter().map(|m| m.requests).sum::<usize>()
        );
    }

    #[test]
    fn pool_retunes_from_measured_telemetry_and_reports_swaps() {
        // Hints priced on the i7 profile (the "tuning device"), serving
        // simulated on the R9 Nano: drift must trip, and an explicit
        // retune must hot-swap a selector trained on the measured data.
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Single(best),
            PoolConfig {
                shards: 2,
                engine: EngineKind::Sim { profile: "r9-nano" },
                pricing_profile: Some("i7-6700k"),
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(128, 128, 128, 1),
        ];
        for round in 0..3u32 {
            for (si, shape) in shapes.iter().enumerate() {
                let seed = round * 10 + si as u32;
                let lhs = fill_buffer(seed, shape.batch * shape.m * shape.k);
                let rhs = fill_buffer(seed + 5, shape.batch * shape.k * shape.n);
                assert!(coord.call(*shape, lhs, rhs).unwrap().result.is_ok());
            }
        }
        assert_eq!(coord.telemetry().total_samples(), 9);
        let cfg = RetuneConfig { min_cell_samples: 2, ..RetuneConfig::default() };
        let outcome = coord.retune_now(&cfg);
        let RetuneOutcome::Swapped { generation, deployed } = outcome else {
            panic!("expected a swap, got {outcome:?}");
        };
        assert_eq!(generation, 1);
        assert_eq!(coord.selector_generation(), 1);
        let pool = coord.registry().manifest.shipped_configs();
        assert!(deployed.iter().all(|c| pool.contains(c)));
        assert!(coord.retune_stats().drift_trips >= 1);
        // The swapped selector keeps serving correct results.
        for shape in &shapes {
            let lhs = fill_buffer(91, shape.batch * shape.m * shape.k);
            let rhs = fill_buffer(92, shape.batch * shape.k * shape.n);
            let resp = coord.call(*shape, lhs.clone(), rhs.clone()).unwrap();
            let out = resp.result.expect("post-swap gemm");
            assert_eq!(out, host_gemm(shape, &lhs, &rhs).unwrap());
        }
        let report = coord.stop_detailed();
        assert!(report.total.selector_swaps >= 1);
        assert!(report.total.retunes >= 1);
        assert!(report.total.drift_trips >= 1);
        assert_eq!(report.tuning.swaps, report.total.selector_swaps);
        assert!(report.summary().contains("tuning:"));
    }

    #[test]
    fn idle_shards_steal_from_overloaded_peer_under_pure_affinity() {
        // Pure affinity routing pins one expensive shape to one shard; an
        // async burst must be partially drained by the idle shards through
        // the steal path alone (spills are disabled).
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig { shards: 4, routing: Routing::Affinity, ..PoolConfig::default() },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(128, 128, 128, 1);
        let n = 100;
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            let lhs = fill_buffer(i as u32, 128 * 128);
            let rhs = fill_buffer((i + 5) as u32, 128 * 128);
            rxs.push(coord.submit(shape, lhs, rhs));
        }
        for rx in rxs {
            assert!(rx.recv().expect("response").result.is_ok());
        }
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, n);
        assert_eq!(report.total.spilled, 0, "affinity routing never spills");
        assert!(
            report.total.steals > 0,
            "idle shards must steal from the overloaded peer\n{}",
            report.summary()
        );
        assert_eq!(
            report.total.stolen_requests,
            report.per_shard.iter().map(|m| m.stolen_requests).sum::<usize>()
        );
        let busy = report.per_shard.iter().filter(|m| m.requests > 0).count();
        assert!(busy >= 2, "stolen batches must execute on other shards");
    }

    #[test]
    fn cpu_pool_serves_bit_identical_results_through_variant_family() {
        // Tentpole: a native CPU pool (synthetic CPU deployment, thread
        // budget auto-divided across shards) serving through a threaded
        // vectorized variant must return bit-identical results to the
        // reference host GEMM at every shape regime.
        let threaded = crate::engine::cpu::cpu_variants()
            .into_iter()
            .find(|v| v.name() == "cpu_large_pb_vec_tp")
            .expect("variant family member");
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Single(threaded.index),
            PoolConfig {
                shards: 2,
                engine: EngineKind::Cpu { threads: 0 },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        assert_eq!(coord.engine_name(), "cpu");
        let shapes = [
            GemmShape::new(16, 16, 16, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(32, 1024, 24, 1),
        ];
        for (i, shape) in shapes.iter().enumerate() {
            let lhs = fill_buffer(i as u32 + 1, shape.batch * shape.m * shape.k);
            let rhs = fill_buffer(i as u32 + 9, shape.batch * shape.k * shape.n);
            let resp = coord.call(*shape, lhs.clone(), rhs.clone()).unwrap();
            assert_eq!(resp.config_used, Some(threaded.index));
            let out = resp.result.expect("cpu gemm");
            assert_eq!(out, host_gemm(shape, &lhs, &rhs).unwrap(), "bit-exact vs reference");
        }
        let metrics = coord.stop();
        assert_eq!(metrics.requests, 3);
        assert_eq!(metrics.failures, 0);
    }

    #[test]
    fn drain_rate_ewma_warms_from_served_batches() {
        // Unit: the EWMA seeds on the first sample and blends at 1/4.
        let load = ShardLoad::default();
        assert_eq!(load.drain_rate_per_sec(), 0.0);
        load.note_completions(4, 2.0); // 2 jobs/sec seeds directly
        assert!((load.drain_rate_per_sec() - 2.0).abs() < 1e-12);
        load.note_completions(6, 1.0); // blend toward 6/sec: 2 + (6-2)/4
        assert!((load.drain_rate_per_sec() - 3.0).abs() < 1e-12);
        load.note_completions(0, 1.0); // no completions: unchanged
        load.note_completions(3, 0.0); // no elapsed time: unchanged
        assert!((load.drain_rate_per_sec() - 3.0).abs() < 1e-12);

        // Pool: served batches must warm the shard's measured rate — the
        // signal bounded rejections price their retry hints on.
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                admission: AdmissionPolicy::BoundedQueue {
                    max_inflight: 1000,
                    max_queue_ns: u64::MAX,
                },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..6u32 {
            let resp = coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap();
            assert!(resp.result.is_ok());
        }
        assert!(
            coord.queues[0].load.drain_rate_per_sec() > 0.0,
            "served batches must warm the measured drain rate"
        );
        coord.stop();
    }

    #[test]
    fn zero_weight_tenant_is_deterministically_rejected() {
        // A registered tenant with weight 0 is switched off: every submit
        // rejects with QuotaExceeded and no retry hint (no amount of
        // waiting admits it), while weighted and anonymous traffic on the
        // same pool keeps being served.
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                tenants: vec![
                    TenantSpec::new(TenantId(1), "blocked", 0, SloClass::Standard),
                    TenantSpec::new(TenantId(2), "paying", 1, SloClass::Standard),
                ],
                quota_slots: 8,
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..5u32 {
            let ticket = coord.submit_as(
                TenantId(1),
                shape,
                fill_buffer(i, 64 * 64),
                fill_buffer(i + 3, 64 * 64),
            );
            match ticket.rejection() {
                Some(SubmitError::Rejected { reason, retry_after_hint }) => {
                    assert_eq!(reason, RejectReason::QuotaExceeded);
                    assert_eq!(retry_after_hint, None, "zero weight: no hint");
                }
                other => panic!("zero-weight submit must reject, got {other:?}"),
            }
        }
        let resp = coord
            .call_as(TenantId(2), shape, fill_buffer(9, 64 * 64), fill_buffer(10, 64 * 64))
            .unwrap();
        assert!(resp.result.is_ok(), "weighted tenant must still be served");
        let resp =
            coord.call(shape, fill_buffer(11, 64 * 64), fill_buffer(12, 64 * 64)).unwrap();
        assert!(resp.result.is_ok(), "anonymous traffic must still be served");

        let report = coord.stop_detailed();
        let blocked = report.tenants.iter().find(|t| t.id == 1).expect("lane for tenant 1");
        assert_eq!(blocked.rejected, 5);
        assert_eq!(blocked.requests, 0, "nothing from the blocked tenant may execute");
        let paying = report.tenants.iter().find(|t| t.id == 2).expect("lane for tenant 2");
        assert_eq!(paying.requests, 1);
        assert_eq!(paying.rejected, 0);
        assert_eq!(report.total.rejected, 5);
    }

    #[test]
    fn reserved_share_admission_is_deterministic_under_burst() {
        // 4 equal-weight tenants on quota_slots=12 reserve 3 slots each
        // (floor(12/4), remainder 0). A single-tenant burst of 40
        // same-shape requests through `submit_many_as` is judged in one
        // run before any job lands on the shard (push_batch is per run),
        // so the outcome is exact: 3 admitted (below reserve), 37
        // rejected — the shared pool is fully covered by the other
        // tenants' unused reserves (3 + 9 = 12, not < 12). The rejection
        // hint prices draining 1 excess job on the cold queue estimate,
        // which floors at MIN_RETRY_HINT_NS.
        let tenants: Vec<TenantSpec> = (1u32..=4)
            .map(|i| TenantSpec::new(TenantId(i), format!("t{i}"), 1, SloClass::Standard))
            .collect();
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                tenants,
                quota_slots: 12,
                admission: AdmissionPolicy::Unbounded,
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        for i in 1u32..=4 {
            assert_eq!(coord.tenant_reserved(TenantId(i)), Some(3));
        }
        let shape = GemmShape::new(64, 64, 64, 1);
        let burst: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = (0..40)
            .map(|i| (shape, fill_buffer(i, 64 * 64), fill_buffer(i + 50, 64 * 64)))
            .collect();
        let tickets = coord.submit_many_as(TenantId(1), burst);
        assert_eq!(tickets.len(), 40);
        let (admitted, rejected): (Vec<_>, Vec<_>) =
            tickets.into_iter().partition(|t| t.rejection().is_none());
        assert_eq!(admitted.len(), 3, "exactly the reserved share admits");
        assert_eq!(rejected.len(), 37);
        for ticket in &rejected {
            match ticket.rejection() {
                Some(SubmitError::Rejected { reason, retry_after_hint }) => {
                    assert_eq!(reason, RejectReason::QuotaExceeded);
                    assert_eq!(
                        retry_after_hint,
                        Some(Duration::from_nanos(MIN_RETRY_HINT_NS)),
                        "cold-queue hint floors at the minimum"
                    );
                }
                other => panic!("expected quota rejection, got {other:?}"),
            }
        }
        for ticket in admitted {
            assert!(ticket.wait().result.is_ok());
        }
        // Reserved shares are admission-guaranteed: after the burst
        // drains, every in-quota tenant lands its full reserve.
        for t in 2..=4u32 {
            let run: Vec<(GemmShape, Vec<f32>, Vec<f32>)> = (0..3)
                .map(|i| {
                    let seed = t * 100 + i;
                    (shape, fill_buffer(seed, 64 * 64), fill_buffer(seed + 7, 64 * 64))
                })
                .collect();
            for ticket in coord.submit_many_as(TenantId(t), run) {
                assert!(ticket.rejection().is_none(), "within-reserve submits admit");
                assert!(ticket.wait().result.is_ok());
            }
        }
        let report = coord.stop_detailed();
        let hostile = report.tenants.iter().find(|t| t.id == 1).expect("lane");
        assert_eq!(hostile.requests, 3);
        assert_eq!(hostile.rejected, 37);
        for t in 2..=4u32 {
            let lane = report.tenants.iter().find(|l| l.id == t).expect("lane");
            assert_eq!(lane.requests, 3);
            assert_eq!(lane.rejected, 0);
        }
        assert_eq!(report.total.rejected, 37);
    }

    #[test]
    fn anonymous_traffic_bit_identical_with_tenants_registered() {
        // Acceptance: registering tenants (quotas, SLO classes, a pinned
        // retune domain) must not perturb anonymous traffic at all — the
        // 1000-request 90/10 mix returns bit-identical results to the
        // tenant-free pool, with every tenant lane untouched.
        let n = 1000;
        let (base, _) = run_skewed(n, 4, Routing::LoadAware);
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 4,
                routing: Routing::LoadAware,
                imbalance: 1.0,
                tenants: vec![
                    TenantSpec::new(TenantId(1), "quiet", 2, SloClass::Interactive),
                    TenantSpec::new(TenantId(2), "pinned", 1, SloClass::Batch)
                        .with_device("r9-nano"),
                ],
                quota_slots: 16,
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        assert_eq!(coord.domain_count(), 2, "one pinned device = one extra domain");
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            let (shape, lhs, rhs) = skewed_input(i);
            rxs.push(coord.submit(shape, lhs, rhs));
        }
        let results: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("response").result.expect("gemm ok"))
            .collect();
        assert_eq!(base, results, "tenant registration must not change anonymous results");
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, n);
        assert_eq!(report.total.rejected, 0);
        assert_eq!(report.total.shed, 0);
        for lane in &report.tenants {
            assert_eq!(lane.requests, 0, "no lane may see anonymous traffic");
            assert_eq!(lane.rejected, 0);
            assert_eq!(lane.shed, 0);
        }
    }

    #[test]
    fn per_domain_retune_beats_blended_selector_on_own_mix() {
        use crate::coordinator::cache::predict_dispatch_secs;
        use crate::devsim::profile_by_name;
        use crate::runtime::Manifest;

        // Acceptance: two tenants pinned to different device profiles in
        // one pool each get their own telemetry domain; after a per-domain
        // retune, each tenant's hot-swapped selector must beat the
        // selector a single blended domain would have learned from the
        // mixed traffic, scored on the tenant's own mix and device.
        let i7 = profile_by_name("i7-6700k").expect("profile");
        let nano = profile_by_name("r9-nano").expect("profile");
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                tenants: vec![
                    TenantSpec::new(TenantId(1), "cpu-bound", 1, SloClass::Standard)
                        .with_device("i7-6700k"),
                    TenantSpec::new(TenantId(2), "gpu-bound", 1, SloClass::Standard)
                        .with_device("r9-nano"),
                ],
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        assert_eq!(coord.domain_count(), 3);
        let d1 = coord.tenant_domain(TenantId(1));
        let d2 = coord.tenant_domain(TenantId(2));
        assert!(d1 != 0 && d2 != 0 && d1 != d2, "distinct non-default domains");

        // Both tenants serve the same two-bucket mix; what differs is the
        // device their requests are priced on. The devsim table makes the
        // best shipped config differ per device on both buckets.
        let mix = [GemmShape::new(256, 256, 256, 1), GemmShape::new(64, 2304, 128, 1)];
        let pool_cfgs = coord.domain_registry(0).manifest.shipped_configs();

        // Feed each domain its own device's measured times, one sample
        // per (shape, config) cell — the EWMA seeds on the first sample,
        // so every cell is exact.
        for (d, prof) in [(d1, i7), (d2, nano)] {
            let sink = coord.domain_telemetry(d);
            for shape in mix {
                for &config in &pool_cfgs {
                    sink.record(
                        shape,
                        Some(config),
                        predict_dispatch_secs(prof, &shape, Some(config)),
                    );
                }
            }
        }

        // Blended baseline: the selector one undivided domain would learn
        // from the same traffic. Alpha 0.5 with one sample per device
        // lands every cell's EWMA exactly on the mean of the two device
        // times, so the blended per-bucket pick is argmin of the summed
        // times — dominated by whichever device is slower there.
        let manifest = Manifest::synthetic();
        let single = config_by_name(&manifest.single_best).expect("config").index();
        let blended_registry = KernelRegistry::new(manifest, SelectorPolicy::Single(single));
        let blended_cache = ResolutionCache::with_profile(64, "i7-6700k");
        let blended_sink = TelemetrySink::new(1, 0.5);
        for shape in mix {
            for &config in &pool_cfgs {
                for prof in [i7, nano] {
                    blended_sink.record(
                        shape,
                        Some(config),
                        predict_dispatch_secs(prof, &shape, Some(config)),
                    );
                }
            }
        }
        let cfg = RetuneConfig {
            min_shapes: 2,
            min_cell_samples: 1,
            k: Some(2),
            ..RetuneConfig::default()
        };
        let mut blended_stats = RetunerStats::default();
        let outcome = retune_once(
            &cfg,
            true,
            &blended_registry,
            &blended_cache,
            &blended_sink,
            &mut blended_stats,
        );
        assert!(matches!(outcome, RetuneOutcome::Swapped { .. }), "blended must swap");

        let g0 = coord.domain_generation(0);
        for d in [d1, d2] {
            let outcome = coord.retune_domain_now(d, &cfg);
            assert!(
                matches!(outcome, RetuneOutcome::Swapped { .. }),
                "domain {d} must swap, got {outcome:?}"
            );
        }
        assert_eq!(coord.domain_generation(0), g0, "default domain stays untouched");

        let blended_policy = blended_registry.policy();
        for (d, prof) in [(d1, i7), (d2, nano)] {
            let domain_policy = coord.domain_registry(d).policy();
            let mut own = 0.0;
            let mut blended = 0.0;
            for shape in mix {
                let dc = domain_policy.policy.choose(&shape).expect("domain pick");
                let bc = blended_policy.policy.choose(&shape).expect("blended pick");
                own += predict_dispatch_secs(prof, &shape, Some(dc));
                blended += predict_dispatch_secs(prof, &shape, Some(bc));
            }
            // Devsim margins are ~1.39x (i7) and ~1.56x (nano); 1.2x
            // leaves room without weakening the claim.
            assert!(
                own * 1.2 < blended,
                "domain {d} selector must beat the blended one on its own mix: \
                 own={own:.3e}s blended={blended:.3e}s"
            );
        }
        coord.stop();
    }

    #[test]
    fn traced_pool_records_complete_lifecycle_chains() {
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 2,
                trace: Some(TraceConfig::default()),
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..6u32 {
            coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 7, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let rec = coord.recorder().expect("tracing was enabled").clone();
        assert_eq!(rec.dropped(), 0);
        let events = rec.export();
        // Causality: every traced submit chain opens exactly once and
        // reaches exactly one terminal (complete | shed | reject).
        let mut chains: HashMap<u64, (usize, usize)> = HashMap::new();
        for ev in &events {
            let cell = chains.entry(ev.seq).or_default();
            match ev.kind {
                EventKind::Submit => cell.0 += 1,
                EventKind::Complete | EventKind::Shed | EventKind::Reject => cell.1 += 1,
                _ => {}
            }
        }
        chains.remove(&0); // unchained shard events (steal/batch/swap)
        assert_eq!(chains.len(), 6, "one chain per request");
        for (seq, (opened, terminal)) in &chains {
            assert_eq!(
                (*opened, *terminal),
                (1, 1),
                "chain {seq} must open once and close once"
            );
        }
        // Executes carry the measured cost next to the prediction.
        let execs: Vec<_> = events.iter().filter(|e| e.kind == EventKind::Execute).collect();
        assert_eq!(execs.len(), 6);
        assert!(execs.iter().all(|e| e.c > 0), "measured cost must be recorded");
        // Both exports are valid JSON; the native one self-identifies.
        let native = crate::util::json::parse(&rec.to_json().to_string()).expect("trace json");
        assert_eq!(
            native.get("schema").and_then(|s| s.as_str()),
            Some("kernelsel-trace-v1")
        );
        crate::util::json::parse(&rec.to_chrome_json().to_string()).expect("chrome trace json");
        // An untraced pool exposes no recorder.
        assert!(sim_pool(1, SelectorPolicy::Xla).recorder().is_none());
        coord.stop();
    }

    /// Sum one exposition family's samples, optionally filtered to lines
    /// whose label set contains `label` (empty matches every sample).
    #[test]
    fn inert_fault_plan_is_bit_identical_to_unwrapped_pool() {
        // Tentpole acceptance: configuring a fault plan with every rate at
        // zero must be indistinguishable from not configuring one — same
        // 1000-request 90/10 skewed mix, bit-identical results, nothing
        // quarantined, nothing respawned, nothing failed.
        let n = 1000;
        let (base, _) = run_skewed(n, 4, Routing::LoadAware);
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 4,
                routing: Routing::LoadAware,
                imbalance: 1.0,
                fault: Some(FaultPlan::default()),
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            let (shape, lhs, rhs) = skewed_input(i);
            rxs.push(coord.submit(shape, lhs, rhs));
        }
        let faulted: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("response").result.expect("gemm ok"))
            .collect();
        assert_eq!(base, faulted, "an inert fault plan must not perturb results");
        let report = coord.stop_detailed();
        assert_eq!(report.total.requests, n);
        assert_eq!(report.total.failures, 0);
        assert_eq!(report.total.quarantine_trips, 0);
        assert_eq!(report.total.worker_respawns, 0);
    }

    #[test]
    fn seeded_panic_costs_one_batch_then_respawns_and_serves() {
        // Supervision: a worker panic mid-run costs exactly its in-flight
        // batch (sequential blocking calls batch singly), the supervisor
        // respawns the worker on the same queue, and every later request
        // is served correctly by the replacement.
        let plan = FaultPlan { panic_at: Some(8), ..FaultPlan::default() };
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig { shards: 1, fault: Some(plan), ..PoolConfig::default() },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        let mut died = 0;
        for i in 0..12u32 {
            let lhs = fill_buffer(i, 64 * 64);
            let rhs = fill_buffer(i + 3, 64 * 64);
            let resp = coord.call(shape, lhs.clone(), rhs.clone()).unwrap();
            match resp.result {
                Ok(out) => assert_eq!(out, host_gemm(&shape, &lhs, &rhs).unwrap()),
                Err(e) => {
                    assert!(e.contains("worker died"), "unexpected failure: {e}");
                    died += 1;
                    // The synthetic failure is delivered while the worker
                    // is still unwinding; wait for its AliveGuard to clear
                    // the flag so the next submit sees the corpse (instead
                    // of racing a job onto a queue nobody drains yet).
                    let deadline = std::time::Instant::now() + Duration::from_secs(10);
                    while coord.worker_alive(0) && std::time::Instant::now() < deadline {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    assert!(!coord.worker_alive(0), "dead worker must clear its alive flag");
                }
            }
        }
        assert_eq!(died, 1, "the panic must cost exactly its in-flight batch");
        let report = coord.stop_detailed();
        assert!(
            report.total.worker_respawns >= 1,
            "the supervisor must have respawned the dead shard\n{}",
            report.summary()
        );
    }

    #[test]
    fn corruption_surfaces_as_err_never_ok_and_trips_quarantine() {
        // Tentpole acceptance: silent corruption targeted at the deployed
        // config is caught by the integrity canary — delivered as `Err`,
        // never as a plausible `Ok` — and the repeated failures trip the
        // variant into quarantine so resolution routes around it.
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let plan = FaultPlan {
            seed: 7,
            corrupt_permille: 700,
            target_config: Some(best),
            ..FaultPlan::default()
        };
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Single(best),
            PoolConfig { shards: 1, fault: Some(plan), ..PoolConfig::default() },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(128, 128, 128, 1);
        let mut corrupt_errs = 0;
        for i in 0..200u32 {
            let lhs = fill_buffer(i, 128 * 128);
            let rhs = fill_buffer(i + 5, 128 * 128);
            let resp = coord.call(shape, lhs.clone(), rhs.clone()).unwrap();
            match resp.result {
                // Every delivered Ok must be the exact reference result —
                // a corrupted output slipping through as Ok is the one
                // unacceptable outcome.
                Ok(out) => assert_eq!(out, host_gemm(&shape, &lhs, &rhs).unwrap()),
                Err(e) => {
                    assert!(e.contains("corrupt result detected"), "unexpected failure: {e}");
                    corrupt_errs += 1;
                }
            }
        }
        assert!(corrupt_errs >= 1, "a 70% corruption rate must surface failures");
        let report = coord.stop_detailed();
        assert!(
            report.total.quarantine_trips >= 1,
            "repeated canary failures must trip the targeted config\n{}",
            report.summary()
        );
        assert_eq!(report.total.failures, corrupt_errs);
    }

    #[test]
    fn shard_load_reset_clears_gauge_and_sub_saturates() {
        // Unit: the dead-queue gauge reset restores an exact inventory and
        // colds the drain EWMA, and `sub` saturates instead of wrapping
        // when its matching share was already dropped by a reset.
        let load = ShardLoad::default();
        load.add(5, 10_000);
        load.note_completions(4, 2.0);
        assert_eq!(load.depth(), 5);
        assert!(load.drain_rate_per_sec() > 0.0);
        load.reset_to(2, 3_000);
        assert_eq!(load.depth(), 2);
        assert_eq!(load.score_ns(), 3_000 + 2 * QUEUED_OVERHEAD_NS);
        assert_eq!(load.drain_rate_per_sec(), 0.0, "replacement workers start cold");
        // A completion whose add-side share was consumed by the reset:
        // saturate to empty, never underflow into a poisoned score.
        load.sub(5, 10_000);
        assert_eq!(load.depth(), 0);
        assert_eq!(load.score_ns(), 0);
    }

    fn prom_total(text: &str, name: &str, label: &str) -> usize {
        text.lines()
            .filter(|l| !l.starts_with('#'))
            .filter(|l| l.split(['{', ' ']).next() == Some(name) && l.contains(label))
            .map(|l| l.rsplit(' ').next().unwrap().parse::<f64>().unwrap() as usize)
            .sum()
    }

    #[test]
    fn exposition_agrees_with_shutdown_report() {
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 2,
                trace: Some(TraceConfig::default()),
                tenants: vec![
                    TenantSpec::new(TenantId(1), "blocked", 0, SloClass::Standard),
                    TenantSpec::new(TenantId(2), "paying", 1, SloClass::Standard),
                ],
                quota_slots: 8,
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        // Served tenant traffic, anonymous traffic, and quota refusals.
        for i in 0..4u32 {
            coord
                .call_as(TenantId(2), shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        coord
            .call(shape, fill_buffer(9, 64 * 64), fill_buffer(11, 64 * 64))
            .unwrap()
            .result
            .unwrap();
        for i in 0..3u32 {
            let ticket = coord.submit_as(
                TenantId(1),
                shape,
                fill_buffer(i, 64 * 64),
                fill_buffer(i + 5, 64 * 64),
            );
            assert!(ticket.rejection().is_some(), "weight-0 tenant must be refused");
        }
        // One retried refusal: the weight-0 tenant is deterministically
        // rejected on every attempt, so the bounded retry loop spends
        // exactly MAX_RETRY_ATTEMPTS - 1 tokens before giving up.
        let resp = coord
            .call_with_retry_as(
                TenantId(1),
                shape,
                fill_buffer(7, 64 * 64),
                fill_buffer(12, 64 * 64),
            )
            .unwrap();
        assert!(resp.result.is_err(), "weight-0 retries must still be refused");
        let text = coord.metrics_text();
        let report = coord.stop_detailed();
        // Shard lanes fold to the report's exact totals.
        assert_eq!(prom_total(&text, "kernelsel_shard_requests_total", ""), report.total.requests);
        assert_eq!(prom_total(&text, "kernelsel_shard_batches_total", ""), report.total.batches);
        assert_eq!(prom_total(&text, "kernelsel_shard_shed_total", ""), report.total.shed);
        assert_eq!(prom_total(&text, "kernelsel_shard_spilled_total", ""), report.total.spilled);
        assert_eq!(prom_total(&text, "kernelsel_pool_rejected_total", ""), report.total.rejected);
        // Tenant lanes agree counter-for-counter.
        let paying = report.tenants.iter().find(|t| t.name == "paying").unwrap();
        let blocked = report.tenants.iter().find(|t| t.name == "blocked").unwrap();
        let lbl = "tenant=\"paying\"";
        assert_eq!(prom_total(&text, "kernelsel_tenant_requests_total", lbl), paying.requests);
        assert_eq!(prom_total(&text, "kernelsel_tenant_in_slo_total", lbl), paying.in_slo);
        assert_eq!(
            prom_total(&text, "kernelsel_tenant_inflight_peak", lbl),
            paying.inflight_peak
        );
        assert!(paying.inflight_peak >= 1, "served quota traffic must leave a peak");
        assert_eq!(
            prom_total(&text, "kernelsel_tenant_rejected_total", "tenant=\"blocked\""),
            blocked.rejected
        );
        // 3 direct refusals + MAX_RETRY_ATTEMPTS submits of the retried call.
        let refused = 3 + MAX_RETRY_ATTEMPTS as usize;
        assert_eq!(blocked.rejected, refused);
        assert_eq!(
            blocked.rejected_by_reason[RejectReason::QuotaExceeded.code() as usize],
            refused,
            "refusals must land in the quota-exceeded cell"
        );
        assert_eq!(
            prom_total(&text, "kernelsel_tenant_rejected_total", "reason=\"quota-exceeded\""),
            refused
        );
        // Quarantine / self-healing lanes agree counter-for-counter with
        // the shutdown report (zero or not — same source cells).
        assert_eq!(
            prom_total(&text, "kernelsel_quarantine_trips_total", ""),
            report.total.quarantine_trips
        );
        assert_eq!(
            prom_total(&text, "kernelsel_quarantine_probes_total", ""),
            report.total.quarantine_probes
        );
        assert_eq!(
            prom_total(&text, "kernelsel_quarantine_restores_total", ""),
            report.total.quarantine_restores
        );
        assert!(text.contains("kernelsel_quarantine_active 0"));
        assert_eq!(
            prom_total(&text, "kernelsel_worker_respawns", ""),
            report.total.worker_respawns
        );
        assert_eq!(prom_total(&text, "kernelsel_retries_total", ""), report.total.retries);
        assert_eq!(
            prom_total(&text, "kernelsel_retries_denied_total", ""),
            report.total.retries_denied
        );
        assert_eq!(
            report.total.retries,
            MAX_RETRY_ATTEMPTS as usize - 1,
            "a deterministic refusal spends every allowed retry"
        );
        assert_eq!(report.total.retries_denied, 0);
        // The selection-quality and trace families are always exposed.
        assert!(text.contains("kernelsel_selection_regret{domain=\"0\"}"));
        assert!(text.contains("kernelsel_selector_generation{domain=\"0\"}"));
        assert!(text.contains("kernelsel_trace_events_total"));
        // The extended report rendering carries the same split.
        let summary = report.summary();
        assert!(summary.contains("quota-exceeded=6/0"), "summary: {summary}");
        assert!(summary.contains("inflight_peak="), "summary: {summary}");
        assert!(summary.contains("retries(spent/denied)=2/0"), "summary: {summary}");
    }

    fn explore_sim_pool(explore: ExploreConfig) -> Coordinator {
        Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig { shards: 1, explore: Some(explore), ..PoolConfig::default() },
        )
        .expect("coordinator start")
    }

    #[test]
    fn explore_probes_cover_unmeasured_configs_and_expose_counters() {
        // eps=1000: every submit draws a probe; the 64-probe budget
        // comfortably covers the 8 shipped configs x 3-sample warm-up at
        // the single visited bucket.
        let coord = explore_sim_pool(ExploreConfig {
            eps_permille: 1000,
            budget: 64,
            seed: 7,
            top_k: 2,
        });
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..64u32 {
            coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        // Stable mid-run counters: the sequential loop has fully drained,
        // so only the async first-sight worker can still move (and it only
        // moves `first_sight_runs` / telemetry samples).
        let stats = coord.explore_stats();
        assert!(stats.probes_issued > 0, "an unmeasured pool must draw probes");
        assert_eq!(
            stats.probes_completed, stats.probes_issued,
            "sequential submits execute every issued probe"
        );
        assert_eq!(stats.first_sight_shapes, 1, "one bucket, one first-sight");
        // Every healthy shipped config at the visited bucket is measured.
        let (measured, total) = coord.explore_coverage(1);
        assert!(measured >= 8, "all 8 shipped configs measured, got {measured}");
        assert!(total > measured, "unvisited buckets stay uncovered");
        let text = coord.metrics_text();
        assert_eq!(
            prom_total(&text, "kernelsel_explore_probes_total", "outcome=\"issued\""),
            stats.probes_issued as usize
        );
        assert_eq!(
            prom_total(&text, "kernelsel_explore_probes_total", "outcome=\"shed\""),
            stats.probes_shed as usize
        );
        assert_eq!(
            prom_total(&text, "kernelsel_explore_probes_total", "outcome=\"completed\""),
            stats.probes_completed as usize
        );
        assert!(text.contains("kernelsel_explore_probe_budget 64"));
        assert_eq!(
            prom_total(&text, "kernelsel_explore_first_sight_total", "kind=\"shapes\""),
            1
        );
        assert_eq!(
            prom_total(&text, "kernelsel_explore_coverage_pairs", "state=\"measured\""),
            measured
        );
        assert_eq!(
            prom_total(&text, "kernelsel_explore_coverage_pairs", "state=\"total\""),
            total
        );
        // `stop_detailed` drains the first-sight worker, so the report and
        // the telemetry provenance are exact.
        let telemetry = coord.telemetry().clone();
        let report = coord.stop_detailed();
        assert_eq!(report.explore.probes_issued, stats.probes_issued);
        assert!(report.summary().contains("explore:"), "summary: {}", report.summary());
        let snap = telemetry.snapshot();
        let probed_sum: u64 = snap.cells.iter().map(|c| c.probed).sum();
        assert_eq!(
            probed_sum,
            report.explore.probes_completed + report.explore.first_sight_runs,
            "every probe measurement carries provenance, nothing else does"
        );
        for c in &snap.cells {
            assert!(c.probed <= c.count, "provenance can never exceed the sample count");
        }
    }

    /// One deterministic exploration run: prime the bucket's first-sight
    /// sweep through a weight-0 tenant refusal (consumes ordinal 0 without
    /// dispatching), wait for the micro-benchmark worker to go quiet, then
    /// drive a sequential single-shard call loop — every remaining draw,
    /// pick and measurement is a pure function of the explore seed.
    fn deterministic_explore_run(
        n: u32,
    ) -> (ExploreStats, Vec<(usize, usize, usize, usize, Option<usize>, u64, u64)>) {
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                explore: Some(ExploreConfig { eps_permille: 400, budget: 24, seed: 9, top_k: 1 }),
                tenants: vec![
                    TenantSpec::new(TenantId(1), "blocked", 0, SloClass::Standard),
                    TenantSpec::new(TenantId(2), "paying", 1, SloClass::Standard),
                ],
                quota_slots: 8,
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(64, 64, 64, 1);
        let ticket =
            coord.submit_as(TenantId(1), shape, fill_buffer(0, 64 * 64), fill_buffer(1, 64 * 64));
        assert!(ticket.rejection().is_some(), "weight-0 priming submit must be refused");
        for _ in 0..5000 {
            if coord.explore_stats().first_sight_runs >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(
            coord.explore_stats().first_sight_runs >= 1,
            "the cold bucket's first-sight sweep must run"
        );
        for i in 0..n {
            coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 5, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let telemetry = coord.telemetry().clone();
        let report = coord.stop_detailed();
        let mut cells: Vec<(usize, usize, usize, usize, Option<usize>, u64, u64)> = telemetry
            .snapshot()
            .cells
            .iter()
            .map(|c| (c.shape.m, c.shape.k, c.shape.n, c.shape.batch, c.config, c.count, c.probed))
            .collect();
        cells.sort_unstable();
        (report.explore, cells)
    }

    #[test]
    fn explore_identical_seed_replays_identical_schedule() {
        let (stats_a, cells_a) = deterministic_explore_run(300);
        let (stats_b, cells_b) = deterministic_explore_run(300);
        assert!(stats_a.probes_issued > 0, "the schedule under test must contain probes");
        assert_eq!(stats_a, stats_b, "identical seed, identical probe schedule");
        assert_eq!(cells_a, cells_b, "identical seed, identical measured coverage");
    }

    #[test]
    fn explore_overload_sheds_probes_to_zero_before_rejecting_in_quota_work() {
        // max_inflight=1 makes the half-budget probe rule
        // (2 * (inflight + 1) <= max_inflight) unsatisfiable: under this
        // overload every fired draw must shed while organic admission
        // keeps serving and rejecting exactly as without exploration.
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                engine: EngineKind::SimPaced { profile: "i7-6700k", permille: 20_000 },
                admission: AdmissionPolicy::BoundedQueue {
                    max_inflight: 1,
                    max_queue_ns: u64::MAX,
                },
                explore: Some(ExploreConfig {
                    eps_permille: 1000,
                    budget: 1000,
                    seed: 3,
                    top_k: 1,
                }),
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        let shape = GemmShape::new(128, 128, 128, 1);
        let tickets: Vec<Ticket> = (0..40u32)
            .map(|i| {
                coord.submit(shape, fill_buffer(i, 128 * 128), fill_buffer(i + 7, 128 * 128))
            })
            .collect();
        let mut ok = 0usize;
        let mut rejected = 0usize;
        for ticket in tickets {
            if ticket.rejection().is_some() {
                rejected += 1;
                assert!(ticket.wait().result.is_err());
            } else {
                assert!(ticket.wait().result.is_ok());
                ok += 1;
            }
        }
        assert!(ok >= 1, "the pool must keep serving under overload");
        assert!(rejected >= 1, "an open burst against max_inflight=1 must reject");
        let report = coord.stop_detailed();
        assert_eq!(
            report.explore.probes_issued, 0,
            "no probe may occupy capacity in a saturated pool"
        );
        assert_eq!(
            report.explore.probes_shed, 40,
            "every fired draw is shed strictly before in-quota work is rejected"
        );
        assert_eq!(report.total.rejected, rejected);
    }

    #[test]
    fn explore_never_probes_quarantined_variant() {
        // A tripped variant earns traffic only through the breaker's own
        // probation trickle — the explorer must route around it entirely.
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let coord = Coordinator::start_pool(
            PathBuf::from("/nonexistent-artifacts"),
            SelectorPolicy::Xla,
            PoolConfig {
                shards: 1,
                explore: Some(ExploreConfig {
                    eps_permille: 1000,
                    budget: 64,
                    seed: 11,
                    top_k: 2,
                }),
                // A practically-infinite cooloff keeps the tripped variant
                // out of probation for the whole test.
                quarantine: QuarantineConfig { cooloff: 1_000_000, ..QuarantineConfig::default() },
                ..PoolConfig::default()
            },
        )
        .expect("coordinator start");
        for _ in 0..8 {
            coord.quarantine.observe(Some(best), false);
        }
        assert!(coord.quarantine.blocks(best), "8 failures in the window must trip");
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..64u32 {
            coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let telemetry = coord.telemetry().clone();
        let report = coord.stop_detailed();
        assert!(
            report.explore.probes_issued > 0,
            "exploration must stay active around the quarantined variant"
        );
        let snap = telemetry.snapshot();
        for c in &snap.cells {
            if c.config == Some(best) {
                assert_eq!(
                    c.probed, 0,
                    "the quarantined variant must never be probed (cell {:?})",
                    c.shape
                );
            }
        }
        assert!(
            snap.cells.iter().any(|c| c.config.is_some() && c.config != Some(best) && c.probed > 0),
            "healthy siblings must still be probed"
        );
    }

    #[test]
    fn explore_probe_measurements_survive_hot_swap() {
        // Telemetry is keyed by (shape, config), not by selector
        // generation: probe provenance recorded under generation N must
        // survive a hot swap to N+1.
        let coord = explore_sim_pool(ExploreConfig {
            eps_permille: 1000,
            budget: 16,
            seed: 5,
            top_k: 1,
        });
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..16u32 {
            coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let before = coord.telemetry().snapshot();
        let probed_before: u64 = before.cells.iter().map(|c| c.probed).sum();
        assert!(probed_before > 0, "16 all-probe submits must leave provenance");
        let manifest = Manifest::synthetic();
        let best = config_by_name(&manifest.single_best).unwrap().index();
        let generation = coord.swap_selector(SelectorPolicy::Single(best));
        assert!(generation >= 1);
        for i in 0..8u32 {
            coord
                .call(shape, fill_buffer(i + 20, 64 * 64), fill_buffer(i + 23, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let telemetry = coord.telemetry().clone();
        let report = coord.stop_detailed();
        assert!(report.total.selector_swaps >= 1);
        let after = telemetry.snapshot();
        for c in before.cells.iter().filter(|c| c.probed > 0) {
            let kept = after
                .cell(&c.shape, c.config)
                .unwrap_or_else(|| panic!("cell {:?}/{:?} lost across swap", c.shape, c.config));
            assert!(
                kept.probed >= c.probed,
                "probe provenance must survive the generation swap"
            );
        }
    }

    #[test]
    fn inert_explore_config_stays_dark() {
        // eps=0 can never fire: the planner is not even armed, so the
        // pool is bit-identical to one without exploration — no metrics
        // families, no report line, no first-sight worker.
        let coord = explore_sim_pool(ExploreConfig {
            eps_permille: 0,
            budget: 100,
            seed: 1,
            top_k: 3,
        });
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..8u32 {
            coord
                .call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let text = coord.metrics_text();
        assert!(!text.contains("kernelsel_explore"), "inert policy exposes nothing");
        let report = coord.stop_detailed();
        assert_eq!(report.explore, ExploreStats::default());
        assert!(!report.summary().contains("explore:"), "summary: {}", report.summary());
    }

    #[test]
    fn warm_started_pool_issues_zero_live_probes() {
        // Run A explores a bucket to full measured coverage and exports
        // its snapshot over the JSON wire format; run B restores it before
        // serving. B's draws still fire but find nothing unmeasured — the
        // warm-start contract is zero live probes and zero re-benchmarks.
        let explore = ExploreConfig { eps_permille: 1000, budget: 64, seed: 7, top_k: 2 };
        let a = explore_sim_pool(explore);
        let shape = GemmShape::new(64, 64, 64, 1);
        for i in 0..64u32 {
            a.call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let a_telemetry = a.telemetry().clone();
        let report_a = a.stop_detailed();
        assert!(report_a.explore.probes_issued > 0, "run A must have explored");
        let restored =
            crate::tuning::telemetry::TelemetrySnapshot::from_json(&a_telemetry.snapshot().to_json())
                .expect("extended snapshot round-trips");
        let b = explore_sim_pool(explore);
        b.telemetry().absorb(&restored);
        for i in 0..64u32 {
            b.call(shape, fill_buffer(i, 64 * 64), fill_buffer(i + 3, 64 * 64))
                .unwrap()
                .result
                .unwrap();
        }
        let report_b = b.stop_detailed();
        assert_eq!(
            report_b.explore.probes_issued, 0,
            "warm measured coverage leaves nothing to probe"
        );
        assert!(report_b.explore.probes_shed > 0, "the draws still fire; they find no candidates");
        assert_eq!(
            report_b.explore.first_sight_runs, 0,
            "restored buckets are never re-benchmarked"
        );
    }
}
