//! Multi-tenant identity, SLO classes, and the weighted-fair quota
//! predicate.
//!
//! Millions of users are not one user: the pool tags every submit with a
//! [`TenantId`], reserves each registered tenant a weighted share of the
//! admission capacity, and maps the tenant's [`SloClass`] onto the
//! admission deadline budgets — so one tenant's burst cannot starve
//! another, and a `Batch` tenant tolerates queueing an `Interactive`
//! tenant would reject.
//!
//! The quota decision itself is the pure function [`quota_would_admit`]
//! (ported to `tools/devsim_check.py` so the predicate is checkable
//! without a Rust toolchain). The semantics are **strict reservation**:
//! a tenant below its reserved share is always admitted; past its share
//! it may only use the *unreserved remainder* of the capacity — never a
//! peer's reserved-but-currently-free slots. That is what makes the
//! reserved share a guarantee instead of a hint.

use std::time::Duration;

/// Opaque tenant identity carried on every submit. `TenantId(0)` is the
/// [`ANONYMOUS`](TenantId::ANONYMOUS) tenant: the default for
/// `submit`/`call` and exempt from quota accounting, so every pre-tenant
/// call site keeps its exact pre-tenant behavior.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub u32);

impl TenantId {
    /// The default tenant: unregistered, unquota'd, untracked.
    pub const ANONYMOUS: TenantId = TenantId(0);

    /// Whether this is the anonymous (quota-exempt) tenant.
    pub fn is_anonymous(self) -> bool {
        self.0 == 0
    }
}

/// Service-level objective class; maps to a multiplier on the pool's
/// `DeadlineShed`/`BoundedQueue` latency budgets (an `Interactive`
/// tenant keeps the configured budget, `Batch` tolerates 16x).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SloClass {
    /// Latency-critical traffic: the configured admission budget as-is.
    Interactive,
    /// The default class: 4x the configured admission budget.
    #[default]
    Standard,
    /// Throughput traffic: 16x the configured admission budget.
    Batch,
}

impl SloClass {
    /// Multiplier applied to the admission policy's queue/deadline
    /// budget for tenants of this class.
    pub fn deadline_factor(self) -> u64 {
        match self {
            SloClass::Interactive => 1,
            SloClass::Standard => 4,
            SloClass::Batch => 16,
        }
    }

    /// Stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    /// Parse a CLI name back into a class (`None` for unknown names).
    pub fn by_name(name: &str) -> Option<SloClass> {
        match name {
            "interactive" => Some(SloClass::Interactive),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// Registration record for one tenant: identity, fair-share weight, SLO
/// class, and (optionally) a pinned device profile that routes the
/// tenant's measured telemetry into its own retune domain.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    /// The identity requests carry on submit. Must be non-zero to take
    /// effect (`TenantId(0)` is reserved for anonymous traffic).
    pub id: TenantId,
    /// Human-readable name for reports.
    pub name: String,
    /// Weighted-fair share. Zero means the tenant is registered but
    /// blocked: every submit is rejected with `QuotaExceeded`.
    pub weight: u32,
    /// SLO class, scaling the admission latency budgets.
    pub slo: SloClass,
    /// Pinned device profile name: the tenant's telemetry records into
    /// a dedicated per-device retune domain priced on this profile.
    pub device: Option<&'static str>,
    /// Optional end-to-end wall target; completions within it count as
    /// in-SLO goodput in the per-tenant metrics lane (`None`: all
    /// completions count).
    pub slo_wall: Option<Duration>,
}

impl TenantSpec {
    /// A tenant with no pinned device and no wall target.
    pub fn new(id: TenantId, name: impl Into<String>, weight: u32, slo: SloClass) -> Self {
        TenantSpec { id, name: name.into(), weight, slo, device: None, slo_wall: None }
    }

    /// Pin the tenant to a device profile (its own retune domain).
    pub fn with_device(mut self, profile: &'static str) -> Self {
        self.device = Some(profile);
        self
    }

    /// Set the end-to-end wall target that defines in-SLO goodput.
    pub fn with_slo_wall(mut self, wall: Duration) -> Self {
        self.slo_wall = Some(wall);
        self
    }
}

/// Floor-divide `quota_slots` capacity across tenants proportionally to
/// their weights: tenant `i` reserves `floor(quota_slots * w_i / sum_w)`
/// slots. The remainder (from flooring) is the shared pool any tenant
/// past its reserve competes for. All-zero weights reserve nothing.
pub fn reserved_shares(weights: &[u32], quota_slots: usize) -> Vec<usize> {
    let sum: u64 = weights.iter().map(|&w| u64::from(w)).sum();
    if sum == 0 {
        return vec![0; weights.len()];
    }
    weights
        .iter()
        .map(|&w| ((quota_slots as u64).saturating_mul(u64::from(w)) / sum) as usize)
        .collect()
}

/// The weighted-fair admission predicate, strict-reservation flavor
/// (pure — ported verbatim to `tools/devsim_check.py`).
///
/// * `weight` — the tenant's configured weight; zero always rejects.
/// * `tenant_inflight` — the tenant's own in-flight count *before* this
///   request.
/// * `tenant_reserved` — the tenant's reserved share from
///   [`reserved_shares`].
/// * `total_inflight` — in-flight count across all registered tenants.
/// * `others_reserved_free` — `sum(max(0, reserved_j - inflight_j))`
///   over every *other* tenant: capacity that is reserved for peers and
///   currently unused. Excluded from what this tenant may take.
/// * `quota_slots` — total capacity under quota (0 disables quotas:
///   admit everything except weight-zero tenants).
///
/// A tenant below its reserve is admitted unconditionally — that is the
/// guarantee. Past its reserve it is admitted only while total usage
/// plus the peers' idle reservations still fits the capacity, i.e. it
/// can only occupy the unreserved remainder.
pub fn quota_would_admit(
    weight: u32,
    tenant_inflight: usize,
    tenant_reserved: usize,
    total_inflight: usize,
    others_reserved_free: usize,
    quota_slots: usize,
) -> bool {
    if weight == 0 {
        return false;
    }
    if quota_slots == 0 {
        return true;
    }
    if tenant_inflight < tenant_reserved {
        return true;
    }
    total_inflight.saturating_add(others_reserved_free) < quota_slots
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anonymous_is_zero_and_default() {
        assert!(TenantId::ANONYMOUS.is_anonymous());
        assert_eq!(TenantId::default(), TenantId::ANONYMOUS);
        assert!(!TenantId(7).is_anonymous());
    }

    #[test]
    fn slo_names_roundtrip() {
        for slo in [SloClass::Interactive, SloClass::Standard, SloClass::Batch] {
            assert_eq!(SloClass::by_name(slo.name()), Some(slo));
        }
        assert_eq!(SloClass::by_name("bogus"), None);
        assert_eq!(SloClass::default(), SloClass::Standard);
        assert_eq!(SloClass::Interactive.deadline_factor(), 1);
        assert_eq!(SloClass::Standard.deadline_factor(), 4);
        assert_eq!(SloClass::Batch.deadline_factor(), 16);
    }

    #[test]
    fn shares_floor_divide_by_weight() {
        assert_eq!(reserved_shares(&[1, 1, 1, 1], 12), vec![3, 3, 3, 3]);
        assert_eq!(reserved_shares(&[2, 1, 1], 12), vec![6, 3, 3]);
        // Flooring leaves a shared remainder.
        assert_eq!(reserved_shares(&[1, 1, 1], 10), vec![3, 3, 3]);
        // Zero-weight tenants reserve nothing; all-zero reserves nothing.
        assert_eq!(reserved_shares(&[0, 4], 8), vec![0, 8]);
        assert_eq!(reserved_shares(&[0, 0], 8), vec![0, 0]);
    }

    #[test]
    fn zero_weight_always_rejects() {
        assert!(!quota_would_admit(0, 0, 0, 0, 0, 0));
        assert!(!quota_would_admit(0, 0, 5, 0, 0, 100));
    }

    #[test]
    fn zero_capacity_disables_quota() {
        assert!(quota_would_admit(1, 1000, 0, 1000, 0, 0));
    }

    #[test]
    fn reserved_share_is_guaranteed() {
        // Below reserve: admitted even with the pool saturated by peers.
        assert!(quota_would_admit(1, 2, 3, 12, 0, 12));
        // At reserve, zero remainder, peers idle: strict reservation
        // refuses — peers' reserved-but-free slots are not up for grabs.
        // (Q=12, four equal tenants: reserved 3 each, remainder 0.)
        assert!(!quota_would_admit(1, 3, 3, 3, 9, 12));
    }

    #[test]
    fn past_reserve_competes_only_for_remainder() {
        // Q=14, four equal tenants: reserved 3 each, remainder 2.
        // Hostile tenant at its reserve of 3, peers idle (9 reserved
        // free): 3 + 9 < 14 admits — one remainder slot.
        assert!(quota_would_admit(1, 3, 3, 3, 9, 14));
        assert!(quota_would_admit(1, 4, 3, 4, 9, 14));
        // Both remainder slots taken: 5 + 9 = 14, refuse.
        assert!(!quota_would_admit(1, 5, 3, 5, 9, 14));
    }
}
