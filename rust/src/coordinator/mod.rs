//! Layer-4 coordinator: the serving side of the tuned library.
//!
//! * [`selector`] — the deployed-set + decision-tree runtime selector and
//!   the end-to-end `tune_selector` pipeline (paper §4 + §5 combined).
//! * [`cache`] — the memoized selector hot path (bounded, striped shape ->
//!   artifact resolution cache on the submit path).
//! * [`completion`] — pooled completion slots (atomic state + park/unpark),
//!   the allocation-free replacement for per-request channels.
//! * [`admission`] — admission control and overload shedding: typed
//!   submit-path rejections with retry hints, plus the queue-time budget
//!   the shards shed against when the pool is saturated.
//! * [`registry`] — maps GEMM requests to shipped AOT artifacts.
//! * [`batcher`] — dynamic request batching by target executable, with
//!   deadline-preserving handoff for stolen batches and the overload
//!   shed hook.
//! * [`server`] — the executor pool: load-aware router (shape affinity as
//!   a preference, spill on imbalance), work-stealing shards, one engine
//!   backend + batcher + metrics per shard, plus the optional background
//!   retuner wiring (measured telemetry in, hot-swapped selectors out —
//!   see [`crate::tuning`]).
//! * [`tenant`] — the multi-tenant model: tenant identity, SLO classes,
//!   and the weighted-fair admission-quota arithmetic (reserved shares,
//!   the pure admit predicate) the server's quota gate runs.
//! * [`quarantine`] — the per-variant circuit breaker: windowed failure
//!   tracking trips a kernel configuration out of resolution, a cooloff
//!   leads to half-open probation probes, sustained success promotes it
//!   back; the registry, cache and retuner all consult it.
//! * [`vgg`] — the VGG16 inference engine of paper §6 (`pjrt` feature).
//! * [`metrics`] — serving statistics (incl. rejection/shed and
//!   spill/steal/retune counters and occupancy histograms, plus
//!   per-tenant lanes) with exact per-shard aggregation.
//! * [`trace`] — the flight recorder: lock-light striped ring buffers of
//!   fixed-size lifecycle events (submit → admission → route → batch →
//!   execute → complete/shed/reject) exportable as `kernelsel-trace-v1`
//!   JSON or Chrome Trace Event Format.

pub mod admission;
pub mod batcher;
pub mod cache;
pub mod completion;
pub mod metrics;
pub mod quarantine;
pub mod registry;
pub mod selector;
pub mod server;
pub mod tenant;
pub mod trace;
#[cfg(feature = "pjrt")]
pub mod vgg;

pub use admission::{AdmissionPolicy, RejectReason, RetryBudget, SubmitError};
pub use batcher::{Batcher, BatcherConfig};
pub use cache::{ResolutionCache, ResolvedKernel};
pub use completion::{Completion, CompletionPool, Ticket};
pub use metrics::{Metrics, StripedCounter};
pub use quarantine::{QuarantineConfig, QuarantineSet};
pub use registry::{KernelRegistry, Resolution};
pub use selector::{tune_selector, tune_selector_with, SelectorPolicy};
pub use server::{
    Coordinator, GemmRequest, GemmResponse, PoolConfig, PoolReport, Routing, ShardLoad,
    TenantReport,
};
pub use tenant::{SloClass, TenantId, TenantSpec};
pub use trace::{EventKind, FlightRecorder, TraceConfig, TraceEvent};
#[cfg(feature = "pjrt")]
pub use vgg::{LayerTiming, VggEngine};
