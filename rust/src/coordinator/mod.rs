//! Layer-3 coordinator: the serving side of the tuned library.
//!
//! * [`selector`] — the deployed-set + decision-tree runtime selector and
//!   the end-to-end `tune_selector` pipeline (paper §4 + §5 combined).
//! * [`registry`] — maps GEMM requests to shipped AOT artifacts.
//! * [`batcher`] — dynamic request batching by target executable.
//! * [`server`] — the executor thread + channel front-end.
//! * [`vgg`] — the VGG16 inference engine of paper §6.
//! * [`metrics`] — serving statistics.

pub mod batcher;
pub mod metrics;
pub mod registry;
pub mod selector;
pub mod server;
pub mod vgg;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use registry::{KernelRegistry, Resolution};
pub use selector::{tune_selector, SelectorPolicy};
pub use server::{Coordinator, GemmRequest, GemmResponse};
pub use vgg::{LayerTiming, VggEngine};
