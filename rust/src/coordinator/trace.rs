//! Flight recorder: lock-light, preallocated lifecycle tracing for the
//! executor pool.
//!
//! Every request that flows through the pool crosses a fixed set of
//! lifecycle edges — submit, route, admission verdict, batch drain,
//! execute, complete/shed/reject — and operating the pool blind to them
//! makes selection quality an article of faith. The recorder captures
//! each edge as a fixed-size [`TraceEvent`] written **by value** into one
//! of a small set of preallocated ring buffers ([`TRACE_STRIPES`] of
//! them, selected by writer thread), so the warm submit fast path stays
//! allocation-free with tracing enabled. Writers never block: a stripe
//! whose mutex is momentarily contended, or whose ring is full, drops
//! the event and counts the drop instead ([`FlightRecorder::dropped`]).
//!
//! Request events are chained by a `seq` id handed out at submit time
//! ([`FlightRecorder::begin_submit`]); a sampling knob records every Nth
//! request chain (`sample_every`), while pool-level events (batch
//! drains, steals, selector swaps) are always recorded with `seq` 0.
//! Export folds the stripes, sorts by `(t_ns, seq, kind)` and emits
//! either the `kernelsel-trace-v1` JSON document (validated by
//! `tools/trace_check.py`) or Chrome Trace Event Format (load it in
//! `chrome://tracing` / Perfetto).
//!
//! Event ordering within one request chain: `submit` (after a successful
//! resolve) → `route` (the routing decision, spill flagged) → `reject`
//! (admission refused; terminal) — or, for admitted requests, `batch` /
//! `steal` at the shard, then per request `execute` and exactly one
//! terminal `complete` or `shed`. The causality check in
//! `tools/trace_check.py` enforces exactly that, strictly when
//! `dropped == 0`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::metrics::thread_stripe;
use crate::dataset::GemmShape;
use crate::util::json::Json;

/// Independent writer stripes; a writer thread always lands on the same
/// stripe, so per-thread event order is preserved within a ring.
const TRACE_STRIPES: usize = 8;

/// Shard value for events recorded off any shard (client-side submit
/// path, pool-level selector swaps).
pub const NO_SHARD: u16 = u16::MAX;

/// A lifecycle edge kind. The discriminant order mirrors the lifecycle,
/// so sorting ties on `(t_ns, seq)` by kind keeps chains readable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum EventKind {
    /// A request entered the pool (recorded after a successful resolve).
    /// `a` packs the GEMM shape, `b` is the predicted dispatch cost (ns).
    Submit = 0,
    /// The router picked a shard. `a` = 1 when the request spilled off
    /// its affinity shard, 0 otherwise; `shard` is the chosen shard.
    Route = 1,
    /// Admission refused the request (terminal). `a` is the
    /// [`crate::coordinator::admission::RejectReason`] code, `b` the
    /// retry-after hint in ns (0 = none).
    Reject = 2,
    /// An idle shard stole a ready batch. `shard` is the thief, `a` the
    /// victim shard, `b` the number of requests transferred.
    Steal = 3,
    /// A shard drained one batch for execution. `a` is the batch size,
    /// `b` the queue age of its oldest request (ns).
    Batch = 4,
    /// One request executed. `a` packs the chosen variant (config index
    /// + 1; 0 = the XLA comparator) in the low 32 bits and the selector
    /// generation in the high 32; `b` is the predicted cost (ns), `c`
    /// the measured execution time (ns).
    Execute = 5,
    /// A response was delivered (terminal). `a` is the end-to-end
    /// latency (ns), `b` is 1 when execution succeeded, 0 on failure.
    Complete = 6,
    /// The shard shed the request on drain (terminal). `a` is the time
    /// it sat queued (ns), `b` the budget it overran (ns).
    Shed = 7,
    /// A re-tuned selector was hot-swapped in. `a` is the new
    /// generation, `b` the retune domain index.
    Swap = 8,
    /// A variant tripped into quarantine (pool-level). `a` is the config
    /// index, `b` the total trips so far.
    QuarantineTrip = 9,
    /// A probation probe of a quarantined variant was observed
    /// (pool-level). `a` is the config index.
    QuarantineProbe = 10,
    /// A variant was promoted back to healthy (pool-level). `a` is the
    /// config index, `b` the total restores so far.
    QuarantineRestore = 11,
    /// The supervisor respawned a dead shard worker (pool-level; `shard`
    /// is the respawned shard). `a` is the number of requests re-homed
    /// to the replacement worker's queue.
    Respawn = 12,
    /// A rejected or transiently failed call was retried under the
    /// retry budget (pool-level). `a` is the
    /// [`crate::coordinator::admission::RejectReason`] code that caused
    /// it (or `u64::MAX` for a transient execution failure), `b` the
    /// attempt number, `c` the budget level in milli-tokens after
    /// spending.
    Retry = 13,
    /// An exploration probe's measurement reached the telemetry sink
    /// (pool-level; the probe-redirected request's own chain carries the
    /// ordinary submit/execute/complete events). `a` is the config index
    /// probed, `b` the measured execution time (ns).
    ExploreProbe = 14,
}

impl EventKind {
    /// Stable lowercase label used by both export formats.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Route => "route",
            EventKind::Reject => "reject",
            EventKind::Steal => "steal",
            EventKind::Batch => "batch",
            EventKind::Execute => "execute",
            EventKind::Complete => "complete",
            EventKind::Shed => "shed",
            EventKind::Swap => "swap",
            EventKind::QuarantineTrip => "quarantine-trip",
            EventKind::QuarantineProbe => "quarantine-probe",
            EventKind::QuarantineRestore => "quarantine-restore",
            EventKind::Respawn => "respawn",
            EventKind::Retry => "retry",
            EventKind::ExploreProbe => "explore-probe",
        }
    }
}

/// One fixed-size lifecycle event. `Copy`, no heap payload: writing one
/// into the ring is a plain store, which is what keeps the traced submit
/// path zero-alloc.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Nanoseconds since the recorder's epoch (pool start).
    pub t_ns: u64,
    /// Request chain id (from [`FlightRecorder::begin_submit`]); 0 for
    /// pool-level events (batch, steal, swap).
    pub seq: u64,
    /// Which lifecycle edge this is.
    pub kind: EventKind,
    /// Shard the event happened on ([`NO_SHARD`] for client-side ones).
    pub shard: u16,
    /// Tenant attribution (0 = anonymous).
    pub tenant: u32,
    /// First kind-specific payload word (see [`EventKind`]).
    pub a: u64,
    /// Second kind-specific payload word.
    pub b: u64,
    /// Third kind-specific payload word.
    pub c: u64,
}

impl TraceEvent {
    fn zeroed() -> TraceEvent {
        TraceEvent {
            t_ns: 0,
            seq: 0,
            kind: EventKind::Submit,
            shard: NO_SHARD,
            tenant: 0,
            a: 0,
            b: 0,
            c: 0,
        }
    }
}

/// Pack a GEMM shape into one payload word (16 bits per dimension; every
/// manifest bucket fits). The inverse is [`unpack_shape`].
pub fn pack_shape(shape: &GemmShape) -> u64 {
    ((shape.m as u64 & 0xffff) << 48)
        | ((shape.k as u64 & 0xffff) << 32)
        | ((shape.n as u64 & 0xffff) << 16)
        | (shape.batch as u64 & 0xffff)
}

/// Unpack a [`pack_shape`] payload word back into `(m, k, n, batch)`.
pub fn unpack_shape(word: u64) -> (usize, usize, usize, usize) {
    (
        ((word >> 48) & 0xffff) as usize,
        ((word >> 32) & 0xffff) as usize,
        ((word >> 16) & 0xffff) as usize,
        (word & 0xffff) as usize,
    )
}

/// Recorder knobs, set once at pool construction ([`Default`]: 65536
/// events, every request chain sampled).
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Total preallocated event capacity, split evenly across the writer
    /// stripes. Past it, new events drop-and-count.
    pub capacity: usize,
    /// Record every Nth request chain (1 = all). Pool-level events are
    /// always recorded.
    pub sample_every: u64,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig { capacity: 65536, sample_every: 1 }
    }
}

/// One stripe's preallocated ring. `buf` is sized at construction and
/// never grows; `len` stops at capacity (drop-newest — the head of a
/// trace matters more than its tail for post-mortems, and dropped counts
/// are reported in the export header).
struct EventRing {
    buf: Vec<TraceEvent>,
    len: usize,
}

/// The per-pool flight recorder (see the module docs).
pub struct FlightRecorder {
    epoch: Instant,
    sample_every: u64,
    /// Submit-chain counter driving the sampling decision.
    submits: AtomicU64,
    /// Next chain id; ids start at 1 so 0 can mean "untraced".
    next_seq: AtomicU64,
    /// Events dropped (ring full or stripe contended).
    dropped: AtomicU64,
    stripes: Vec<Mutex<EventRing>>,
    /// Highest selector generation seen per retune domain; a raise emits
    /// a [`EventKind::Swap`] timeline event.
    generations: Vec<AtomicU64>,
}

impl FlightRecorder {
    /// A recorder for a pool with `domains` retune domains.
    pub fn new(cfg: TraceConfig, domains: usize) -> FlightRecorder {
        let per_stripe = (cfg.capacity / TRACE_STRIPES).max(16);
        FlightRecorder {
            epoch: Instant::now(),
            sample_every: cfg.sample_every.max(1),
            submits: AtomicU64::new(0),
            next_seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            stripes: (0..TRACE_STRIPES)
                .map(|_| {
                    Mutex::new(EventRing { buf: vec![TraceEvent::zeroed(); per_stripe], len: 0 })
                })
                .collect(),
            generations: (0..domains.max(1)).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Nanoseconds since the recorder epoch (the timestamp domain every
    /// event uses).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Start a request chain: returns the chain id to stamp on every
    /// event of this request, or 0 when the sampling knob skips it (the
    /// caller then records nothing for the request).
    pub fn begin_submit(&self) -> u64 {
        let n = self.submits.fetch_add(1, Ordering::Relaxed);
        if n % self.sample_every != 0 {
            return 0;
        }
        self.next_seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Append one event. Never blocks and never allocates: the writer
    /// `try_lock`s its home stripe first, probes the others on
    /// contention (export restores order by timestamp), and only when
    /// every stripe is contended — or the probed rings are full — drops
    /// the event and counts it.
    pub fn record(&self, ev: TraceEvent) {
        let start = thread_stripe(TRACE_STRIPES);
        for k in 0..TRACE_STRIPES {
            if let Ok(mut ring) = self.stripes[(start + k) % TRACE_STRIPES].try_lock() {
                if ring.len < ring.buf.len() {
                    let at = ring.len;
                    ring.buf[at] = ev;
                    ring.len = at + 1;
                    return;
                }
            }
        }
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// Convenience: record a chain event now, with kind-specific payload
    /// words `[a, b, c]`. No-op when `seq` is 0 for a per-request kind
    /// (the chain was not sampled), so call sites stay branch-free;
    /// pool-level kinds (`Steal`, `Batch`, `Swap`, the quarantine
    /// transitions, `Respawn`, `Retry` and `ExploreProbe`) always
    /// record.
    pub fn event(&self, seq: u64, kind: EventKind, shard: u16, tenant: u32, payload: [u64; 3]) {
        let pool_level = matches!(
            kind,
            EventKind::Swap
                | EventKind::Steal
                | EventKind::Batch
                | EventKind::QuarantineTrip
                | EventKind::QuarantineProbe
                | EventKind::QuarantineRestore
                | EventKind::Respawn
                | EventKind::Retry
                | EventKind::ExploreProbe
        );
        if seq == 0 && !pool_level {
            return;
        }
        let [a, b, c] = payload;
        self.record(TraceEvent { t_ns: self.now_ns(), seq, kind, shard, tenant, a, b, c });
    }

    /// Note the selector generation a just-executed request resolved
    /// under; a raise over the domain's last seen generation emits one
    /// [`EventKind::Swap`] timeline event (how hot swaps land on the
    /// trace without the retuner thread knowing about the recorder).
    pub fn note_generation(&self, domain: usize, generation: u64) {
        let Some(slot) = self.generations.get(domain) else { return };
        let seen = slot.fetch_max(generation, Ordering::Relaxed);
        if generation > seen {
            self.event(0, EventKind::Swap, NO_SHARD, 0, [generation, domain as u64, 0]);
        }
    }

    /// Events dropped so far (ring overflow or momentary stripe
    /// contention). `tools/trace_check.py` relaxes its causality check
    /// when this is non-zero.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Events currently held across all stripes (folds under the stripe
    /// mutexes; an export-path cost, not a hot-path one).
    pub fn recorded(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().len).sum()
    }

    /// Request chains started (sampled or not) — the sampling
    /// denominator for the exposition.
    pub fn chains(&self) -> u64 {
        self.submits.load(Ordering::Relaxed)
    }

    /// Fold the stripes into one timeline, sorted by
    /// `(t_ns, seq, kind)`.
    pub fn export(&self) -> Vec<TraceEvent> {
        let mut events = Vec::with_capacity(self.recorded());
        for stripe in &self.stripes {
            let ring = stripe.lock().unwrap();
            events.extend_from_slice(&ring.buf[..ring.len]);
        }
        events.sort_by_key(|e| (e.t_ns, e.seq, e.kind));
        events
    }

    /// The `kernelsel-trace-v1` document (schema in ARCHITECTURE.md §8;
    /// validated by `tools/trace_check.py`).
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self.export().iter().map(event_to_json).collect();
        Json::obj(vec![
            ("schema", Json::Str("kernelsel-trace-v1".to_string())),
            ("sample_every", Json::Num(self.sample_every as f64)),
            ("dropped", Json::Num(self.dropped() as f64)),
            ("chains", Json::Num(self.chains() as f64)),
            ("events", Json::Arr(events)),
        ])
    }

    /// The same timeline as Chrome Trace Event Format (open in
    /// `chrome://tracing` or Perfetto): `execute` spans as `X` duration
    /// events, everything else as instants, one track per shard.
    pub fn to_chrome_json(&self) -> Json {
        let events: Vec<Json> = self.export().iter().map(event_to_chrome).collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ns".to_string())),
        ])
    }
}

fn shard_json(shard: u16) -> Json {
    if shard == NO_SHARD {
        Json::Null
    } else {
        Json::Num(shard as f64)
    }
}

fn event_to_json(ev: &TraceEvent) -> Json {
    let mut pairs = vec![
        ("t_ns", Json::Num(ev.t_ns as f64)),
        ("seq", Json::Num(ev.seq as f64)),
        ("kind", Json::Str(ev.kind.name().to_string())),
        ("shard", shard_json(ev.shard)),
        ("tenant", Json::Num(ev.tenant as f64)),
    ];
    match ev.kind {
        EventKind::Submit => {
            let (m, k, n, b) = unpack_shape(ev.a);
            pairs.push(("m", Json::Num(m as f64)));
            pairs.push(("k", Json::Num(k as f64)));
            pairs.push(("n", Json::Num(n as f64)));
            pairs.push(("batch", Json::Num(b as f64)));
            pairs.push(("cost_ns", Json::Num(ev.b as f64)));
        }
        EventKind::Route => {
            pairs.push(("spilled", Json::Bool(ev.a != 0)));
        }
        EventKind::Reject => {
            pairs.push((
                "reason",
                Json::Str(crate::coordinator::admission::RejectReason::by_code(ev.a as u8)
                    .map(|r| r.name().to_string())
                    .unwrap_or_else(|| format!("code-{}", ev.a))),
            ));
            pairs.push(("retry_after_ns", Json::Num(ev.b as f64)));
        }
        EventKind::Steal => {
            pairs.push(("victim", Json::Num(ev.a as f64)));
            pairs.push(("requests", Json::Num(ev.b as f64)));
        }
        EventKind::Batch => {
            pairs.push(("size", Json::Num(ev.a as f64)));
            pairs.push(("oldest_queued_ns", Json::Num(ev.b as f64)));
        }
        EventKind::Execute => {
            let config = (ev.a & 0xffff_ffff) as u32;
            pairs.push((
                "config",
                if config == 0 { Json::Null } else { Json::Num((config - 1) as f64) },
            ));
            pairs.push(("generation", Json::Num((ev.a >> 32) as f64)));
            pairs.push(("predicted_ns", Json::Num(ev.b as f64)));
            pairs.push(("measured_ns", Json::Num(ev.c as f64)));
        }
        EventKind::Complete => {
            pairs.push(("latency_ns", Json::Num(ev.a as f64)));
            pairs.push(("ok", Json::Bool(ev.b != 0)));
        }
        EventKind::Shed => {
            pairs.push(("queued_ns", Json::Num(ev.a as f64)));
            pairs.push(("budget_ns", Json::Num(ev.b as f64)));
        }
        EventKind::Swap => {
            pairs.push(("generation", Json::Num(ev.a as f64)));
            pairs.push(("domain", Json::Num(ev.b as f64)));
        }
        EventKind::QuarantineTrip => {
            pairs.push(("config", Json::Num(ev.a as f64)));
            pairs.push(("trips", Json::Num(ev.b as f64)));
        }
        EventKind::QuarantineProbe => {
            pairs.push(("config", Json::Num(ev.a as f64)));
        }
        EventKind::QuarantineRestore => {
            pairs.push(("config", Json::Num(ev.a as f64)));
            pairs.push(("restores", Json::Num(ev.b as f64)));
        }
        EventKind::Respawn => {
            pairs.push(("requests", Json::Num(ev.a as f64)));
        }
        EventKind::Retry => {
            pairs.push((
                "reason",
                if ev.a == u64::MAX {
                    Json::Str("transient".to_string())
                } else {
                    Json::Str(
                        crate::coordinator::admission::RejectReason::by_code(ev.a as u8)
                            .map(|r| r.name().to_string())
                            .unwrap_or_else(|| format!("code-{}", ev.a)),
                    )
                },
            ));
            pairs.push(("attempt", Json::Num(ev.b as f64)));
            pairs.push(("tokens_milli", Json::Num(ev.c as f64)));
        }
        EventKind::ExploreProbe => {
            pairs.push(("config", Json::Num(ev.a as f64)));
            pairs.push(("measured_ns", Json::Num(ev.b as f64)));
        }
    }
    Json::obj(pairs)
}

fn event_to_chrome(ev: &TraceEvent) -> Json {
    // Chrome timestamps are microseconds (f64 keeps sub-us precision).
    let ts = ev.t_ns as f64 / 1e3;
    let tid = if ev.shard == NO_SHARD { 999 } else { ev.shard as usize };
    let mut pairs = vec![
        ("name", Json::Str(ev.kind.name().to_string())),
        ("cat", Json::Str("kernelsel".to_string())),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(tid as f64)),
        ("args", event_to_json(ev)),
    ];
    if ev.kind == EventKind::Execute {
        pairs.push(("ph", Json::Str("X".to_string())));
        pairs.push(("ts", Json::Num(ts - ev.c as f64 / 1e3)));
        pairs.push(("dur", Json::Num(ev.c as f64 / 1e3)));
    } else {
        pairs.push(("ph", Json::Str("i".to_string())));
        pairs.push(("ts", Json::Num(ts)));
        pairs.push(("s", Json::Str("t".to_string())));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_packing_roundtrips() {
        let s = GemmShape::new(512, 784, 512, 3);
        assert_eq!(unpack_shape(pack_shape(&s)), (512, 784, 512, 3));
        let max = GemmShape::new(65535, 1, 65535, 65535);
        assert_eq!(unpack_shape(pack_shape(&max)), (65535, 1, 65535, 65535));
    }

    #[test]
    fn sampling_knob_skips_chains() {
        let rec = FlightRecorder::new(TraceConfig { capacity: 1024, sample_every: 2 }, 1);
        let seqs: Vec<u64> = (0..6).map(|_| rec.begin_submit()).collect();
        // Every other chain sampled; sampled ids are dense from 1.
        assert_eq!(seqs.iter().filter(|&&s| s == 0).count(), 3);
        let sampled: Vec<u64> = seqs.iter().copied().filter(|&s| s != 0).collect();
        assert_eq!(sampled, vec![1, 2, 3]);
        assert_eq!(rec.chains(), 6);
    }

    #[test]
    fn ring_overflow_drops_and_counts() {
        // Tiny capacity: 16 events per stripe minimum, 8 stripes. A
        // writer whose home ring fills probes the others, so the whole
        // 128-event budget is usable; past it, events drop-and-count.
        let rec = FlightRecorder::new(TraceConfig { capacity: 0, sample_every: 1 }, 1);
        for i in 0..140u64 {
            rec.event(i + 1, EventKind::Submit, NO_SHARD, 0, [0, 0, 0]);
        }
        assert_eq!(rec.recorded(), 128);
        assert_eq!(rec.dropped(), 12);
    }

    #[test]
    fn export_sorts_by_time_then_seq() {
        let rec = FlightRecorder::new(TraceConfig::default(), 1);
        let seq = rec.begin_submit();
        let payload = [pack_shape(&GemmShape::new(8, 8, 8, 1)), 100, 0];
        rec.event(seq, EventKind::Submit, NO_SHARD, 7, payload);
        rec.event(seq, EventKind::Route, 1, 7, [0, 0, 0]);
        rec.event(seq, EventKind::Complete, 1, 7, [5000, 1, 0]);
        let events = rec.export();
        assert_eq!(events.len(), 3);
        assert!(events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        assert_eq!(events[0].kind, EventKind::Submit);
        assert_eq!(events[2].kind, EventKind::Complete);
    }

    #[test]
    fn generation_notes_emit_one_swap_event_per_raise() {
        let rec = FlightRecorder::new(TraceConfig::default(), 2);
        rec.note_generation(0, 0); // boot generation: no event
        rec.note_generation(0, 1); // raise: swap event
        rec.note_generation(0, 1); // repeat: no event
        rec.note_generation(1, 3); // other domain: swap event
        rec.note_generation(9, 9); // unknown domain: ignored
        let swaps: Vec<&TraceEvent> =
            rec.export().iter().filter(|e| e.kind == EventKind::Swap).collect::<Vec<_>>();
        assert_eq!(swaps.len(), 2);
        assert_eq!((swaps[0].a, swaps[0].b), (1, 0));
        assert_eq!((swaps[1].a, swaps[1].b), (3, 1));
    }

    #[test]
    fn json_export_carries_schema_and_kind_fields() {
        let rec = FlightRecorder::new(TraceConfig::default(), 1);
        let seq = rec.begin_submit();
        let shape = GemmShape::new(64, 32, 16, 2);
        rec.event(seq, EventKind::Submit, NO_SHARD, 3, [pack_shape(&shape), 1234, 0]);
        rec.event(seq, EventKind::Reject, NO_SHARD, 3, [2, 1000, 0]);
        let doc = rec.to_json();
        assert_eq!(doc.get("schema").and_then(|s| s.as_str()), Some("kernelsel-trace-v1"));
        assert_eq!(doc.get("dropped").and_then(|d| d.as_usize()), Some(0));
        let events = doc.get("events").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(events.len(), 2);
        let submit = &events[0];
        assert_eq!(submit.get("kind").and_then(|k| k.as_str()), Some("submit"));
        assert_eq!(submit.get("m").and_then(|v| v.as_usize()), Some(64));
        assert_eq!(submit.get("batch").and_then(|v| v.as_usize()), Some(2));
        assert!(submit.get("shard").unwrap().is_null());
        let reject = &events[1];
        assert_eq!(reject.get("reason").and_then(|r| r.as_str()), Some("quota-exceeded"));
        assert_eq!(reject.get("retry_after_ns").and_then(|v| v.as_usize()), Some(1000));
    }

    #[test]
    fn chrome_export_is_a_trace_events_document() {
        let rec = FlightRecorder::new(TraceConfig::default(), 1);
        let seq = rec.begin_submit();
        rec.event(seq, EventKind::Execute, 0, 0, [(2 << 32) | 5, 100, 2000]);
        rec.event(seq, EventKind::Complete, 0, 0, [9000, 1, 0]);
        let doc = rec.to_chrome_json();
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(events.len(), 2);
        let exec = &events[0];
        assert_eq!(exec.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert_eq!(exec.get("dur").and_then(|d| d.as_f64()), Some(2.0));
        assert_eq!(exec.path(&["args", "config"]).and_then(|c| c.as_usize()), Some(4));
        assert_eq!(exec.path(&["args", "generation"]).and_then(|g| g.as_usize()), Some(2));
        assert_eq!(events[1].get("ph").and_then(|p| p.as_str()), Some("i"));
    }

    #[test]
    fn concurrent_writers_lose_nothing_with_headroom() {
        let rec = std::sync::Arc::new(FlightRecorder::new(
            TraceConfig { capacity: 65536, sample_every: 1 },
            1,
        ));
        let mut joins = Vec::new();
        for t in 0..4u32 {
            let rec = rec.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let seq = rec.begin_submit();
                    rec.event(seq, EventKind::Submit, NO_SHARD, t, [i, 0, 0]);
                    rec.event(seq, EventKind::Complete, 0, t, [i, 1, 0]);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        // Distinct threads write distinct stripes: nothing contends, so
        // nothing drops (each stripe holds 8192 >= 1000 events).
        assert_eq!(rec.dropped(), 0);
        assert_eq!(rec.recorded(), 4000);
        assert_eq!(rec.chains(), 2000);
    }
}
