//! Memoized selector hot path: a bounded shape -> resolved-artifact cache.
//!
//! The registry's resolution (decision-tree walk + deployed-set
//! reconciliation) is cheap but not free, and it sits on every request's
//! submit path — which, with the sharded pool, runs on *client* threads.
//! Serving traffic is heavily repetitive in shape (a model's GEMMs recur
//! every inference), so a small FIFO-evicted map in front of
//! [`KernelRegistry::resolve`] turns the hot path into one hash lookup and
//! an `Arc` clone.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use crate::coordinator::registry::{KernelRegistry, Resolution};
use crate::dataset::{config_by_index, config_by_name, GemmShape};
use crate::devsim::{profile_by_name, simulate, DeviceProfile};
use crate::runtime::ArtifactMeta;

/// A successful registry resolution, shared between the cache, the
/// load-aware router and the shard that executes the request.
#[derive(Clone, Debug)]
pub struct ResolvedKernel {
    pub meta: ArtifactMeta,
    pub resolution: Resolution,
    /// Estimated execution cost of one dispatch (seconds), from the devsim
    /// analytical model. Feeds the router's per-shard load gauges; a hint,
    /// not a promise — only relative magnitudes matter for load balancing.
    pub cost_hint_secs: f64,
}

impl ResolvedKernel {
    /// The cost hint in integer nanoseconds, the unit the shard load
    /// gauges accumulate atomically. Clamped to at least 1ns so every
    /// queued request registers on the gauge.
    pub fn cost_hint_ns(&self) -> u64 {
        (self.cost_hint_secs * 1e9).max(1.0) as u64
    }
}

/// Estimate the device-seconds one dispatch of `meta` at `shape` costs,
/// using the same analytical model the SimBackend executes against. The
/// XLA comparator artifact (no config index) is priced as a well-rounded
/// proxy configuration, mirroring `SimBackend::simulated_secs`.
pub fn estimate_cost_secs(
    profile: &DeviceProfile,
    meta: &ArtifactMeta,
    shape: &GemmShape,
) -> f64 {
    let cfg = meta
        .config_index
        .map(config_by_index)
        .unwrap_or_else(|| config_by_name("r4a4c4_wg16x16").expect("proxy config"));
    let gflops = simulate(profile, shape, &cfg).max(1e-3);
    shape.flops() / (gflops * 1e9)
}

pub struct ResolutionCache {
    cap: usize,
    /// Device profile used to price resolutions for the load gauges.
    profile: &'static DeviceProfile,
    /// RwLock, not Mutex: the steady state is ~100% hits, and a hit only
    /// needs a read guard — concurrent submitters must not serialize on
    /// the map once every bucket is resolved.
    inner: RwLock<Inner>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

#[derive(Default)]
struct Inner {
    map: HashMap<GemmShape, Arc<ResolvedKernel>>,
    /// Insertion order for FIFO eviction (shapes are never re-inserted, so
    /// FIFO == LRU-by-first-touch, which is plenty for bucketed traffic).
    order: VecDeque<GemmShape>,
}

impl ResolutionCache {
    pub fn new(capacity: usize) -> ResolutionCache {
        ResolutionCache::with_profile(capacity, "i7-6700k")
    }

    /// A cache whose cost hints are priced against a specific devsim
    /// profile (falls back to the default profile for unknown names —
    /// hints only need to be relatively consistent, not exact).
    pub fn with_profile(capacity: usize, profile_name: &str) -> ResolutionCache {
        let profile = profile_by_name(profile_name)
            .or_else(|| profile_by_name("i7-6700k"))
            .expect("default devsim profile exists");
        ResolutionCache {
            cap: capacity.max(1),
            profile,
            inner: RwLock::new(Inner::default()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Cached resolution, or walk the registry and memoize the result.
    /// Failures are not cached: unknown shapes are expected to be rare and
    /// should re-report the registry's (possibly changing) error.
    pub fn resolve(
        &self,
        registry: &KernelRegistry,
        shape: &GemmShape,
    ) -> Result<Arc<ResolvedKernel>, String> {
        if let Some(hit) = self.get(shape) {
            return Ok(hit);
        }
        let (meta, resolution) = registry.resolve(shape)?;
        let cost_hint_secs = estimate_cost_secs(self.profile, meta, shape);
        let resolved = Arc::new(ResolvedKernel {
            meta: meta.clone(),
            resolution,
            cost_hint_secs,
        });
        self.insert(*shape, resolved.clone());
        Ok(resolved)
    }

    pub fn get(&self, shape: &GemmShape) -> Option<Arc<ResolvedKernel>> {
        let inner = self.inner.read().unwrap();
        match inner.map.get(shape) {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, shape: GemmShape, resolved: Arc<ResolvedKernel>) {
        let mut inner = self.inner.write().unwrap();
        if inner.map.insert(shape, resolved).is_none() {
            inner.order.push_back(shape);
            while inner.order.len() > self.cap {
                if let Some(evict) = inner.order.pop_front() {
                    inner.map.remove(&evict);
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses) since construction.
    pub fn stats(&self) -> (usize, usize) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selector::SelectorPolicy;
    use crate::runtime::Manifest;

    fn registry() -> KernelRegistry {
        KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla)
    }

    #[test]
    fn memoizes_resolutions() {
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let shape = GemmShape::new(128, 128, 128, 1);
        let a = cache.resolve(&reg, &shape).unwrap();
        let b = cache.resolve(&reg, &shape).unwrap();
        assert_eq!(a.meta.path, b.meta.path);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must be the cached Arc");
        let (hits, misses) = cache.stats();
        assert_eq!((hits, misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn bounded_by_capacity_fifo() {
        let reg = registry();
        let cache = ResolutionCache::new(2);
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(128, 128, 128, 1),
        ];
        for s in &shapes {
            cache.resolve(&reg, s).unwrap();
        }
        assert_eq!(cache.len(), 2);
        // The first-inserted shape was evicted; the later two remain.
        assert!(cache.get(&shapes[0]).is_none());
        assert!(cache.get(&shapes[1]).is_some());
        assert!(cache.get(&shapes[2]).is_some());
    }

    #[test]
    fn cost_hints_positive_and_grow_with_shape() {
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let small = cache.resolve(&reg, &GemmShape::new(32, 32, 32, 1)).unwrap();
        let large = cache.resolve(&reg, &GemmShape::new(512, 784, 512, 1)).unwrap();
        assert!(small.cost_hint_secs > 0.0);
        assert!(small.cost_hint_ns() >= 1);
        assert!(
            large.cost_hint_secs > small.cost_hint_secs,
            "a 512x784x512 GEMM must be priced above a 32^3 one \
             ({} vs {})",
            large.cost_hint_secs,
            small.cost_hint_secs
        );
    }

    #[test]
    fn unknown_profile_falls_back_to_default() {
        let reg = registry();
        let cache = ResolutionCache::with_profile(16, "not-a-device");
        let r = cache.resolve(&reg, &GemmShape::new(64, 64, 64, 1)).unwrap();
        assert!(r.cost_hint_secs > 0.0);
    }

    #[test]
    fn failures_not_cached() {
        let reg = registry();
        let cache = ResolutionCache::new(4);
        let unknown = GemmShape::new(17, 19, 23, 1);
        assert!(cache.resolve(&reg, &unknown).is_err());
        assert!(cache.resolve(&reg, &unknown).is_err());
        assert_eq!(cache.len(), 0);
    }
}
