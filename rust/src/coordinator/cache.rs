//! Memoized selector hot path: a bounded, striped shape -> resolved-artifact
//! cache.
//!
//! The registry's resolution (decision-tree walk + deployed-set
//! reconciliation) is cheap but not free, and it sits on every request's
//! submit path — which, with the sharded pool, runs on *client* threads.
//! Serving traffic is heavily repetitive in shape (a model's GEMMs recur
//! every inference), so a small FIFO-evicted map in front of
//! [`KernelRegistry::resolve`] turns the hot path into one hash lookup and
//! an `Arc` clone.
//!
//! The map is **striped**: entries land in one of 16 independent
//! stripes by shape hash, and each stripe publishes an immutable snapshot
//! (`Arc<HashMap>`) behind a briefly-held `RwLock` — the same hand-rolled
//! `ArcSwap` stand-in as [`crate::tuning::swap::SelectorHandle`]. A hit
//! clones the stripe's snapshot `Arc` (one refcount bump, no allocation)
//! and looks the shape up lock-free, so concurrent hits on different
//! shapes touch disjoint cache lines and scale with the submitter count
//! instead of serializing on one reader-count word. Writes (misses,
//! generation refreshes, invalidation) are copy-on-write per stripe and
//! serialize on a global FIFO-order mutex that the hit path never takes.
//!
//! Entries are tagged with the selector generation they were resolved
//! under. A hot swap bumps the registry's generation, so stale entries
//! turn into misses on their next lookup (and are purged eagerly by
//! [`ResolutionCache::invalidate_stale`]) — a resolution from an old
//! deployment is never served after a swap.
//!
//! Cost hints follow a measured-over-modeled handoff: once the telemetry
//! sink has enough samples for a (shape, config) cell, the EWMA of
//! measured dispatch times replaces the devsim estimate feeding the
//! router's load gauges; cold cells keep the devsim prior.

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, VecDeque};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::metrics::StripedCounter;
use crate::coordinator::quarantine::QuarantineSet;
use crate::coordinator::registry::{KernelRegistry, Resolution};
use crate::dataset::{config_by_index, config_by_name, GemmShape};
use crate::devsim::{profile_by_name, simulate, DeviceProfile};
use crate::runtime::ArtifactMeta;
use crate::tuning::telemetry::TelemetrySink;

/// Submits between telemetry refreshes of a resolved kernel's cached
/// dispatch-cost hint (see [`ResolutionCache::dispatch_cost_ns`]).
pub const COST_REFRESH_PERIOD: u64 = 32;

/// Independent stripes of the resolution map.
const STRIPES: usize = 16;

/// A successful registry resolution, shared between the cache, the
/// load-aware router and the shard that executes the request. `meta` sits
/// behind an `Arc`, so cloning a `ResolvedKernel` (and the route/steal
/// paths that used to deep-copy artifact paths) is allocation-free.
#[derive(Debug)]
pub struct ResolvedKernel {
    /// The shipped artifact serving this resolution (shared, not copied).
    pub meta: Arc<ArtifactMeta>,
    /// How the registry resolved it (direct hit vs fallback).
    pub resolution: Resolution,
    /// Estimated execution cost of one dispatch (seconds), from the devsim
    /// analytical model. Feeds the router's per-shard load gauges; a hint,
    /// not a promise — only relative magnitudes matter for load balancing.
    pub cost_hint_secs: f64,
    /// Selector generation this resolution was produced under.
    pub generation: u64,
    /// Shared batching key (the artifact path), cloned per job without
    /// allocating.
    artifact: Arc<str>,
    /// Memoized hash of the artifact path: the router's shape-affinity
    /// preference, computed once per resolution instead of per submit.
    affinity: u64,
    /// Memoized dispatch-cost hint (ns; 0 = not yet computed), refreshed
    /// from telemetry every [`COST_REFRESH_PERIOD`] submits so the hot
    /// submit path reads one atomic instead of locking a telemetry stripe
    /// that executors are writing into.
    cached_cost_ns: AtomicU64,
    /// Submit counter driving the periodic refresh.
    hint_tick: AtomicU64,
}

impl Clone for ResolvedKernel {
    fn clone(&self) -> ResolvedKernel {
        ResolvedKernel {
            meta: self.meta.clone(),
            resolution: self.resolution,
            cost_hint_secs: self.cost_hint_secs,
            generation: self.generation,
            artifact: self.artifact.clone(),
            affinity: self.affinity,
            cached_cost_ns: AtomicU64::new(self.cached_cost_ns.load(Ordering::Relaxed)),
            hint_tick: AtomicU64::new(0),
        }
    }
}

impl ResolvedKernel {
    /// The cost hint in integer nanoseconds, the unit the shard load
    /// gauges accumulate atomically. Clamped to at least 1ns so every
    /// queued request registers on the gauge.
    pub fn cost_hint_ns(&self) -> u64 {
        (self.cost_hint_secs * 1e9).max(1.0) as u64
    }

    /// The shared batching key: the artifact path this request resolved to.
    pub fn artifact(&self) -> &Arc<str> {
        &self.artifact
    }

    /// Memoized hash of the artifact path (the router's shape-affinity
    /// preference).
    pub fn affinity(&self) -> u64 {
        self.affinity
    }
}

/// Predict the device-seconds one dispatch of `config` at `shape` costs on
/// `profile`, via the devsim analytical model. `None` (the XLA comparator
/// artifact) is priced as a well-rounded proxy configuration, mirroring
/// `SimBackend::simulated_secs`. Shared by cost-hint pricing and the
/// tuning subsystem's drift/prior math.
pub fn predict_dispatch_secs(
    profile: &DeviceProfile,
    shape: &GemmShape,
    config: Option<usize>,
) -> f64 {
    let cfg = config
        .map(config_by_index)
        .unwrap_or_else(|| config_by_name("r4a4c4_wg16x16").expect("proxy config"));
    let gflops = simulate(profile, shape, &cfg).max(1e-3);
    shape.flops() / (gflops * 1e9)
}

/// Estimate the device-seconds one dispatch of `meta` at `shape` costs.
pub fn estimate_cost_secs(
    profile: &DeviceProfile,
    meta: &ArtifactMeta,
    shape: &GemmShape,
) -> f64 {
    predict_dispatch_secs(profile, shape, meta.config_index)
}

/// How dispatches are priced before telemetry warms up: the model behind
/// resolution cost hints, drift detection and the retuner's prior on
/// unmeasured cells. Each backend family has its own notion of "predicted
/// cost" — devsim profiles for the simulated backends, the analytic CPU
/// prior for the native backend — and everything downstream of the cache
/// prices through this enum instead of assuming a device profile exists.
#[derive(Clone, Copy, Debug)]
pub enum CostModel {
    /// The devsim analytical model on a device profile (simulated
    /// backends, or native backends priced against a reference device).
    Devsim(&'static DeviceProfile),
    /// The analytic prior for the native CPU backend's GEMM variant
    /// family ([`crate::engine::cpu::predict_cpu_secs`]).
    CpuAnalytic,
}

impl CostModel {
    /// The devsim model for a named profile, falling back to the default
    /// profile for unknown names (hints only need to be relatively
    /// consistent, not exact).
    pub fn devsim(profile_name: &str) -> CostModel {
        let profile = profile_by_name(profile_name)
            .or_else(|| profile_by_name("i7-6700k"))
            .expect("default devsim profile exists");
        CostModel::Devsim(profile)
    }

    /// Predicted device-seconds of one dispatch of `config` at `shape`.
    /// Total: `None` configs price as the comparator backend; always
    /// positive and finite.
    pub fn predict_secs(&self, shape: &GemmShape, config: Option<usize>) -> f64 {
        match self {
            CostModel::Devsim(profile) => predict_dispatch_secs(profile, shape, config),
            CostModel::CpuAnalytic => crate::engine::cpu::predict_cpu_secs(shape, config),
        }
    }

    /// Stable label (reports, logs).
    pub fn name(&self) -> &'static str {
        match self {
            CostModel::Devsim(_) => "devsim",
            CostModel::CpuAnalytic => "cpu-analytic",
        }
    }
}

type StripeMap = HashMap<GemmShape, Arc<ResolvedKernel>>;

/// The memoized selector hot path: a bounded, striped shape ->
/// resolved-artifact map with generation-tagged entries and
/// measured-over-modeled cost hints (see the module docs).
pub struct ResolutionCache {
    cap: usize,
    /// Cost model used to price resolutions for the load gauges.
    model: CostModel,
    /// Measured-time source for the cost-hint handoff (None = devsim only).
    telemetry: Option<Arc<TelemetrySink>>,
    /// The pool's variant circuit breaker: hits on a quarantined config
    /// are treated as misses — invalidation equivalent to a generation
    /// bump, without walking the stripes.
    quarantine: Option<Arc<QuarantineSet>>,
    /// Striped read-mostly map; see the module docs for the epoch scheme.
    stripes: Vec<RwLock<Arc<StripeMap>>>,
    /// Global FIFO insertion order (shapes are re-inserted only on a
    /// generation refresh, which keeps their original slot, so FIFO ==
    /// LRU-by-first-touch, which is plenty for bucketed traffic). Only
    /// the write paths take this mutex; hits never do.
    order: Mutex<VecDeque<GemmShape>>,
    /// Hit/miss counters are per-thread-striped: a warm hit must not
    /// bounce one shared counter cache line between submitter cores.
    hits: StripedCounter,
    misses: StripedCounter,
}

impl ResolutionCache {
    /// A cache of `capacity` entries priced on the default devsim profile.
    pub fn new(capacity: usize) -> ResolutionCache {
        ResolutionCache::with_profile(capacity, "i7-6700k")
    }

    /// A cache whose cost hints are priced against a specific devsim
    /// profile (falls back to the default profile for unknown names —
    /// hints only need to be relatively consistent, not exact).
    pub fn with_profile(capacity: usize, profile_name: &str) -> ResolutionCache {
        ResolutionCache::with_model(capacity, CostModel::devsim(profile_name))
    }

    /// A cache whose cost hints are priced by an explicit [`CostModel`]
    /// (how CPU-backed pools avoid pricing native kernels on a simulated
    /// GPU).
    pub fn with_model(capacity: usize, model: CostModel) -> ResolutionCache {
        ResolutionCache {
            cap: capacity.max(1),
            model,
            telemetry: None,
            quarantine: None,
            stripes: (0..STRIPES).map(|_| RwLock::new(Arc::new(StripeMap::new()))).collect(),
            order: Mutex::new(VecDeque::new()),
            hits: StripedCounter::new(),
            misses: StripedCounter::new(),
        }
    }

    /// Attach a telemetry sink: measured EWMA dispatch times override the
    /// devsim cost hints once warm.
    pub fn with_telemetry(mut self, telemetry: Arc<TelemetrySink>) -> ResolutionCache {
        self.telemetry = Some(telemetry);
        self
    }

    /// Attach the pool's quarantine set: cached hits on a quarantined
    /// config re-resolve through the registry (which falls down the
    /// healthy ladder) instead of serving the blocked variant. Shares
    /// the registry's `Arc` so trip/restore state is pool-wide.
    pub fn with_quarantine(mut self, quarantine: Arc<QuarantineSet>) -> ResolutionCache {
        self.quarantine = Some(quarantine);
        self
    }

    /// The cost model hints are priced against (also the drift baseline
    /// and the retuner's prior for unmeasured cells).
    pub fn cost_model(&self) -> CostModel {
        self.model
    }

    fn stripe_of(&self, shape: &GemmShape) -> usize {
        let mut hasher = DefaultHasher::new();
        shape.hash(&mut hasher);
        (hasher.finish() as usize) % STRIPES
    }

    /// The stripe's current immutable snapshot (brief read lock + `Arc`
    /// clone — the hit path's only synchronization).
    fn snapshot(&self, stripe: usize) -> Arc<StripeMap> {
        self.stripes[stripe].read().unwrap().clone()
    }

    /// Copy-on-write edit of one stripe: clone the snapshot, apply the
    /// edit, publish the new snapshot. Caller holds the `order` mutex, so
    /// concurrent edits never interleave.
    fn rebuild(&self, stripe: usize, edit: impl FnOnce(&mut StripeMap)) {
        let mut slot = self.stripes[stripe].write().unwrap();
        let mut map = (**slot).clone();
        edit(&mut map);
        *slot = Arc::new(map);
    }

    /// Cached resolution, or walk the registry and memoize the result.
    /// Entries from an older selector generation are treated as misses and
    /// re-resolved. Failures are not cached: unknown shapes are expected
    /// to be rare and should re-report the registry's (possibly changing)
    /// error.
    pub fn resolve(
        &self,
        registry: &KernelRegistry,
        shape: &GemmShape,
    ) -> Result<Arc<ResolvedKernel>, String> {
        if let Some(hit) = self.lookup(shape, registry.generation()) {
            return Ok(hit);
        }
        let (meta, resolution, generation) = registry.resolve(shape)?;
        let cost_hint_secs = self.model.predict_secs(shape, meta.config_index);
        let artifact: Arc<str> = Arc::from(meta.path.as_str());
        let mut hasher = DefaultHasher::new();
        meta.path.hash(&mut hasher);
        let resolved = Arc::new(ResolvedKernel {
            meta: Arc::new(meta.clone()),
            resolution,
            cost_hint_secs,
            generation,
            artifact,
            affinity: hasher.finish(),
            cached_cost_ns: AtomicU64::new(0),
            hint_tick: AtomicU64::new(0),
        });
        self.insert(*shape, resolved.clone());
        Ok(resolved)
    }

    /// Resolve `shape` to one *specific* shipped config, bypassing the
    /// selector and the memoized map entirely — the exploration-probe
    /// path. The result is never inserted into the cache (a probe must
    /// not poison the organic hot path), and quarantine is consulted
    /// with the pure `blocks` read, never `screen`: the breaker's
    /// probation trickle belongs to the organic resolve path alone.
    /// Returns `None` when the config is blocked or not shipped at the
    /// shape.
    pub fn resolve_probe(
        &self,
        registry: &KernelRegistry,
        shape: &GemmShape,
        config: usize,
    ) -> Option<Arc<ResolvedKernel>> {
        if self.quarantine.as_ref().is_some_and(|q| q.blocks(config)) {
            return None;
        }
        let meta = registry
            .manifest
            .find_matmul(Some(config), shape.m, shape.k, shape.n, shape.batch)?;
        let cost_hint_secs = self.model.predict_secs(shape, meta.config_index);
        let artifact: Arc<str> = Arc::from(meta.path.as_str());
        let mut hasher = DefaultHasher::new();
        meta.path.hash(&mut hasher);
        Some(Arc::new(ResolvedKernel {
            meta: Arc::new(meta.clone()),
            resolution: Resolution::Direct,
            cost_hint_secs,
            generation: registry.generation(),
            artifact,
            affinity: hasher.finish(),
            cached_cost_ns: AtomicU64::new(0),
            hint_tick: AtomicU64::new(0),
        }))
    }

    /// The per-dispatch cost hint (ns) the router should charge for a
    /// resolved request: the measured EWMA once the telemetry cell is
    /// warm, the devsim estimate while cold. The hint is memoized on the
    /// `ResolvedKernel` and re-read from telemetry only every
    /// [`COST_REFRESH_PERIOD`] submits, keeping the hot submit path to a
    /// pair of relaxed atomics instead of a stripe lock shared with the
    /// executors.
    pub fn dispatch_cost_ns(&self, resolved: &ResolvedKernel) -> u64 {
        let tick = resolved.hint_tick.fetch_add(1, Ordering::Relaxed);
        let cached = resolved.cached_cost_ns.load(Ordering::Relaxed);
        if cached != 0 && tick % COST_REFRESH_PERIOD != 0 {
            return cached;
        }
        let meta = &resolved.meta;
        let shape = GemmShape::new(meta.m, meta.k, meta.n, meta.b);
        let hint = self
            .telemetry
            .as_ref()
            .and_then(|t| t.measured_cost_secs(&shape, meta.config_index))
            .map(|secs| (secs * 1e9).max(1.0) as u64)
            .unwrap_or_else(|| resolved.cost_hint_ns());
        resolved.cached_cost_ns.store(hint, Ordering::Relaxed);
        hint
    }

    /// Fresh cached entry for `shape`, counting a hit; stale-generation
    /// entries count as misses (the caller re-resolves and replaces them).
    fn lookup(&self, shape: &GemmShape, generation: u64) -> Option<Arc<ResolvedKernel>> {
        let map = self.snapshot(self.stripe_of(shape));
        match map.get(shape) {
            Some(r) if r.generation == generation && !self.hit_quarantined(r) => {
                self.hits.incr();
                Some(r.clone())
            }
            _ => {
                self.misses.incr();
                None
            }
        }
    }

    /// Is this cached entry's config currently quarantined? Costs one
    /// relaxed load while nothing is tripped; a blocked entry turns the
    /// hit into a miss so the registry re-resolves down the healthy
    /// ladder (the replacement entry then overwrites this one in place).
    fn hit_quarantined(&self, r: &ResolvedKernel) -> bool {
        match self.quarantine.as_ref() {
            Some(q) if q.is_active() => {
                r.meta.config_index.is_some_and(|c| q.blocks(c))
            }
            _ => false,
        }
    }

    /// Cached entry regardless of generation (tests/inspection; counts
    /// hits and misses like a lookup).
    pub fn get(&self, shape: &GemmShape) -> Option<Arc<ResolvedKernel>> {
        let map = self.snapshot(self.stripe_of(shape));
        match map.get(shape) {
            Some(r) => {
                self.hits.incr();
                Some(r.clone())
            }
            None => {
                self.misses.incr();
                None
            }
        }
    }

    /// Memoize a resolution for `shape`, FIFO-evicting past capacity. A
    /// racing stale-generation insert never clobbers a fresher entry; a
    /// same-shape generation refresh keeps its original FIFO slot.
    pub fn insert(&self, shape: GemmShape, resolved: Arc<ResolvedKernel>) {
        let mut order = self.order.lock().unwrap();
        let stripe = self.stripe_of(&shape);
        match self.snapshot(stripe).get(&shape).map(|existing| existing.generation) {
            // Never let a racing stale resolution clobber a fresher one.
            Some(existing_gen) if existing_gen > resolved.generation => {}
            Some(_) => {
                // Generation refresh: replace in place, keep the FIFO slot.
                self.rebuild(stripe, |map| {
                    map.insert(shape, resolved);
                });
            }
            None => {
                self.rebuild(stripe, |map| {
                    map.insert(shape, resolved);
                });
                order.push_back(shape);
                while order.len() > self.cap {
                    if let Some(evict) = order.pop_front() {
                        self.rebuild(self.stripe_of(&evict), |map| {
                            map.remove(&evict);
                        });
                    }
                }
            }
        }
    }

    /// Drop every entry resolved under a generation older than
    /// `generation`. Called after a hot swap; lazy generation checks on
    /// lookup make this a memory-hygiene step rather than a correctness
    /// requirement.
    pub fn invalidate_stale(&self, generation: u64) {
        let mut order = self.order.lock().unwrap();
        for stripe in 0..STRIPES {
            if self.snapshot(stripe).values().any(|r| r.generation < generation) {
                self.rebuild(stripe, |map| map.retain(|_, r| r.generation >= generation));
            }
        }
        order.retain(|shape| self.snapshot(self.stripe_of(shape)).contains_key(shape));
    }

    /// Entries currently cached across every stripe.
    pub fn len(&self) -> usize {
        (0..STRIPES).map(|stripe| self.snapshot(stripe).len()).sum()
    }

    /// Whether no entry is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses) since construction (striped cells folded at read).
    pub fn stats(&self) -> (usize, usize) {
        (self.hits.sum(), self.misses.sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selector::SelectorPolicy;
    use crate::runtime::Manifest;

    fn registry() -> KernelRegistry {
        KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla)
    }

    #[test]
    fn memoizes_resolutions() {
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let shape = GemmShape::new(128, 128, 128, 1);
        let a = cache.resolve(&reg, &shape).unwrap();
        let b = cache.resolve(&reg, &shape).unwrap();
        assert_eq!(a.meta.path, b.meta.path);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must be the cached Arc");
        let (hits, misses) = cache.stats();
        assert_eq!((hits, misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn bounded_by_capacity_fifo() {
        let reg = registry();
        let cache = ResolutionCache::new(2);
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(128, 128, 128, 1),
        ];
        for s in &shapes {
            cache.resolve(&reg, s).unwrap();
        }
        assert_eq!(cache.len(), 2);
        // The first-inserted shape was evicted (the FIFO order is global,
        // not per stripe); the later two remain.
        assert!(cache.get(&shapes[0]).is_none());
        assert!(cache.get(&shapes[1]).is_some());
        assert!(cache.get(&shapes[2]).is_some());
    }

    #[test]
    fn cost_hints_positive_and_grow_with_shape() {
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let small = cache.resolve(&reg, &GemmShape::new(32, 32, 32, 1)).unwrap();
        let large = cache.resolve(&reg, &GemmShape::new(512, 784, 512, 1)).unwrap();
        assert!(small.cost_hint_secs > 0.0);
        assert!(small.cost_hint_ns() >= 1);
        assert!(
            large.cost_hint_secs > small.cost_hint_secs,
            "a 512x784x512 GEMM must be priced above a 32^3 one \
             ({} vs {})",
            large.cost_hint_secs,
            small.cost_hint_secs
        );
    }

    #[test]
    fn resolved_kernel_clone_shares_meta_and_artifact() {
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let resolved = cache.resolve(&reg, &GemmShape::new(64, 64, 64, 1)).unwrap();
        let cloned = resolved.as_ref().clone();
        assert!(Arc::ptr_eq(&resolved.meta, &cloned.meta), "meta must be shared, not deep-copied");
        assert!(Arc::ptr_eq(resolved.artifact(), cloned.artifact()));
        assert_eq!(resolved.affinity(), cloned.affinity());
        assert_eq!(&**resolved.artifact(), resolved.meta.path.as_str());
    }

    #[test]
    fn unknown_profile_falls_back_to_default() {
        let reg = registry();
        let cache = ResolutionCache::with_profile(16, "not-a-device");
        let r = cache.resolve(&reg, &GemmShape::new(64, 64, 64, 1)).unwrap();
        assert!(r.cost_hint_secs > 0.0);
    }

    #[test]
    fn cpu_model_prices_cpu_manifest_resolutions() {
        let reg = KernelRegistry::new(Manifest::synthetic_cpu(), SelectorPolicy::Xla);
        let cache = ResolutionCache::with_model(16, CostModel::CpuAnalytic);
        assert_eq!(cache.cost_model().name(), "cpu-analytic");
        let small = cache.resolve(&reg, &GemmShape::new(16, 16, 16, 1)).unwrap();
        let large = cache.resolve(&reg, &GemmShape::new(192, 192, 192, 1)).unwrap();
        assert!(small.cost_hint_secs > 0.0);
        assert!(large.cost_hint_secs > small.cost_hint_secs);
    }

    #[test]
    fn failures_not_cached() {
        let reg = registry();
        let cache = ResolutionCache::new(4);
        let unknown = GemmShape::new(17, 19, 23, 1);
        assert!(cache.resolve(&reg, &unknown).is_err());
        assert!(cache.resolve(&reg, &unknown).is_err());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn swap_invalidates_stale_entries() {
        let best = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let shape = GemmShape::new(64, 64, 64, 1);
        let old = cache.resolve(&reg, &shape).unwrap();
        assert_eq!(old.generation, 0);
        assert_eq!(old.meta.config_index, None, "XLA policy");

        // Hot swap: the stale entry must never be served again.
        let generation = reg.swap_policy(SelectorPolicy::Single(best));
        let fresh = cache.resolve(&reg, &shape).unwrap();
        assert_eq!(fresh.generation, generation);
        assert_eq!(fresh.meta.config_index, Some(best));
        // The refreshed entry replaced the stale one in place.
        assert_eq!(cache.len(), 1);
        assert!(Arc::ptr_eq(&cache.resolve(&reg, &shape).unwrap(), &fresh));
    }

    #[test]
    fn invalidate_stale_purges_old_generations() {
        let best = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let shapes = [GemmShape::new(32, 32, 32, 1), GemmShape::new(64, 64, 64, 1)];
        for s in &shapes {
            cache.resolve(&reg, s).unwrap();
        }
        assert_eq!(cache.len(), 2);
        let generation = reg.swap_policy(SelectorPolicy::Single(best));
        // Refresh one shape under the new generation, then purge.
        cache.resolve(&reg, &shapes[0]).unwrap();
        cache.invalidate_stale(generation);
        assert_eq!(cache.len(), 1, "only the refreshed entry survives");
        assert!(cache.get(&shapes[0]).is_some());
        assert!(cache.get(&shapes[1]).is_none());
    }

    #[test]
    fn stale_insert_never_clobbers_fresh_entry() {
        let best = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let reg = registry();
        let cache = ResolutionCache::new(16);
        let shape = GemmShape::new(64, 64, 64, 1);
        let stale = cache.resolve(&reg, &shape).unwrap();
        reg.swap_policy(SelectorPolicy::Single(best));
        let fresh = cache.resolve(&reg, &shape).unwrap();
        // A racing thread re-inserting its old resolution must lose.
        cache.insert(shape, stale);
        let now = cache.get(&shape).unwrap();
        assert!(Arc::ptr_eq(&now, &fresh));
    }

    #[test]
    fn concurrent_hits_share_the_cached_entry() {
        let reg = std::sync::Arc::new(registry());
        let cache = std::sync::Arc::new(ResolutionCache::new(16));
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(128, 128, 128, 1),
            GemmShape::new(64, 64, 64, 4),
        ];
        let warm: Vec<Arc<ResolvedKernel>> =
            shapes.iter().map(|s| cache.resolve(&reg, s).unwrap()).collect();
        let mut joins = Vec::new();
        for t in 0..4usize {
            let reg = reg.clone();
            let cache = cache.clone();
            let expected = warm[t].clone();
            let shape = shapes[t];
            joins.push(std::thread::spawn(move || {
                for _ in 0..2000 {
                    let hit = cache.resolve(&reg, &shape).unwrap();
                    assert!(Arc::ptr_eq(&hit, &expected), "hit must be the cached Arc");
                }
            }));
        }
        for join in joins {
            join.join().unwrap();
        }
        let (hits, misses) = cache.stats();
        assert_eq!(hits, 4 * 2000);
        assert_eq!(misses, 4);
    }

    #[test]
    fn quarantined_hit_invalidates_like_a_generation_bump() {
        use crate::coordinator::quarantine::{QuarantineConfig, QuarantineSet};
        let best = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let q = Arc::new(QuarantineSet::new(QuarantineConfig::default()));
        let reg = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Single(best))
            .with_quarantine(q.clone());
        let cache = ResolutionCache::new(16).with_quarantine(q.clone());
        let shape = GemmShape::new(64, 64, 64, 1);
        let warm = cache.resolve(&reg, &shape).unwrap();
        assert_eq!(warm.meta.config_index, Some(best));
        assert!(Arc::ptr_eq(&cache.resolve(&reg, &shape).unwrap(), &warm));
        // Trip the config: the cached entry must stop being served (a
        // miss, like a generation bump) and re-resolve down the ladder.
        for _ in 0..QuarantineConfig::default().trip_failures {
            q.observe(Some(best), false);
        }
        let (_, misses_before) = cache.stats();
        let healed = cache.resolve(&reg, &shape).unwrap();
        assert_ne!(healed.meta.config_index, Some(best));
        let (_, misses_after) = cache.stats();
        assert_eq!(misses_after, misses_before + 1, "blocked hit must count as a miss");
        // The healthy replacement is served from cache thereafter.
        assert!(Arc::ptr_eq(&cache.resolve(&reg, &shape).unwrap(), &healed));
    }

    #[test]
    fn measured_cost_hint_overrides_devsim_once_warm() {
        let reg = registry();
        let telemetry = Arc::new(TelemetrySink::new(2, 1.0));
        let cache = ResolutionCache::with_profile(16, "i7-6700k")
            .with_telemetry(telemetry.clone());
        let shape = GemmShape::new(64, 64, 64, 1);
        let resolved = cache.resolve(&reg, &shape).unwrap();
        // Cold: devsim estimate (first call computes and memoizes it).
        assert_eq!(cache.dispatch_cost_ns(&resolved), resolved.cost_hint_ns());
        // One sample is below min_samples: still devsim.
        telemetry.record(shape, resolved.meta.config_index, 5e-3);
        assert_eq!(cache.dispatch_cost_ns(&resolved), resolved.cost_hint_ns());
        // Warm: within one refresh period the measured EWMA takes over.
        telemetry.record(shape, resolved.meta.config_index, 5e-3);
        let warmed = (0..=COST_REFRESH_PERIOD)
            .map(|_| cache.dispatch_cost_ns(&resolved))
            .last()
            .unwrap();
        assert_eq!(warmed, 5_000_000);
    }
}
