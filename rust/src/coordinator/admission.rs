//! Admission control and overload shedding for the executor pool.
//!
//! The serving story of the paper — a small deployed kernel set plus a
//! cheap learned selector — only holds in production if the dispatch
//! layer stays predictable when offered load exceeds capacity. Without
//! admission control an open burst queues without bound: every request is
//! eventually served, but every request also waits behind the whole
//! backlog, so latency collapses for all of them (classic congestion
//! collapse). This module bounds the damage by refusing work the pool
//! cannot serve in time, *before* it costs anything:
//!
//! * admission runs on the submit path **after** routing picked a shard
//!   (so the backlog estimate is the gauge of the shard that would serve
//!   the request) and **before** a completion slot is taken — a rejected
//!   request allocates nothing, takes no slab capacity and never touches
//!   an injector;
//! * rejections surface as a typed [`SubmitError`] carried inside the
//!   returned [`crate::coordinator::completion::Ticket`], so callers get
//!   per-request outcomes (including from `submit_many` partial
//!   admission) and a `retry_after_hint` they can feed into client-side
//!   backoff;
//! * work that was admitted but then aged past its queue budget while
//!   waiting is **shed** by the owning shard at drain time (see
//!   [`crate::coordinator::batcher::Batcher::shed_overdue`]) instead of
//!   being served pointlessly late.
//!
//! All cost/backlog arithmetic is integer nanoseconds on the same scale
//! as the [`crate::coordinator::server::ShardLoad`] gauges (devsim-priced
//! hints, measured-EWMA once telemetry warms). The [`DeadlineShed`]
//! predicate is a pure function ([`deadline_would_shed`]) so the
//! toolchain-free Python port in `tools/devsim_check.py` can verify it on
//! a grid of synthetic gauge states.
//!
//! [`DeadlineShed`]: AdmissionPolicy::DeadlineShed

use std::time::Duration;

/// Floor for `retry_after_hint` values so a hint is never zero (a zero
/// hint reads as "retry immediately", which defeats backoff).
pub const MIN_RETRY_HINT_NS: u64 = 1_000;

/// Why the admission policy refused a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The pool-wide in-flight count or the routed shard's queue-time
    /// budget is exhausted ([`AdmissionPolicy::BoundedQueue`]).
    QueueFull,
    /// The routed shard's backlog plus this request's own cost already
    /// exceeds the deadline budget ([`AdmissionPolicy::DeadlineShed`]):
    /// even if admitted now, the response would arrive too late.
    DeadlineUnmeetable,
    /// The submitting tenant is past its weighted-fair reserved share
    /// and the unreserved remainder of the quota capacity is exhausted
    /// (see [`crate::coordinator::tenant::quota_would_admit`]). Decided
    /// before the pool-wide admission policy runs, so a hostile tenant's
    /// overflow never competes with in-quota peers for the shared
    /// budgets.
    QuotaExceeded,
}

/// Number of distinct [`RejectReason`] values — sizes the per-reason
/// counter arrays in the metrics lanes and the exposition.
pub const REJECT_REASONS: usize = 3;

impl RejectReason {
    /// Stable lower-case label (metrics, logs, bench JSON).
    pub fn name(&self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue-full",
            RejectReason::DeadlineUnmeetable => "deadline-unmeetable",
            RejectReason::QuotaExceeded => "quota-exceeded",
        }
    }

    /// Stable small-integer code (`0..`[`REJECT_REASONS`]): the index
    /// into per-reason counter arrays and the wire value flight-recorder
    /// `reject` events carry.
    pub fn code(&self) -> u8 {
        match self {
            RejectReason::QueueFull => 0,
            RejectReason::DeadlineUnmeetable => 1,
            RejectReason::QuotaExceeded => 2,
        }
    }

    /// The inverse of [`RejectReason::code`] (trace/exposition decoding).
    pub fn by_code(code: u8) -> Option<RejectReason> {
        match code {
            0 => Some(RejectReason::QueueFull),
            1 => Some(RejectReason::DeadlineUnmeetable),
            2 => Some(RejectReason::QuotaExceeded),
            _ => None,
        }
    }

    /// Every reason, in [`RejectReason::code`] order (exposition render).
    pub fn all() -> [RejectReason; REJECT_REASONS] {
        [RejectReason::QueueFull, RejectReason::DeadlineUnmeetable, RejectReason::QuotaExceeded]
    }
}

/// A typed submit-path refusal, delivered through the returned
/// [`crate::coordinator::completion::Ticket`] without allocating.
///
/// `Copy` is deliberate: constructing and returning a rejection must not
/// disturb the PR-4 zero-allocation submit fast path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission policy refused the request before it was queued.
    Rejected {
        /// Which budget was exhausted.
        reason: RejectReason,
        /// Rough estimate of when the refused budget may have drained
        /// enough for a retry to be admitted. A hint, not a promise —
        /// derived from the same gauge estimates admission itself uses.
        retry_after_hint: Option<Duration>,
    },
}

impl SubmitError {
    /// The rejection reason.
    pub fn reason(&self) -> RejectReason {
        match self {
            SubmitError::Rejected { reason, .. } => *reason,
        }
    }

    /// The backoff hint, if the policy could estimate one.
    pub fn retry_after_hint(&self) -> Option<Duration> {
        match self {
            SubmitError::Rejected { retry_after_hint, .. } => *retry_after_hint,
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Rejected { reason, retry_after_hint } => {
                write!(f, "admission rejected: {}", reason.name())?;
                if let Some(hint) = retry_after_hint {
                    write!(f, " (retry after ~{}us)", hint.as_micros())?;
                }
                Ok(())
            }
        }
    }
}

/// The pure [`AdmissionPolicy::DeadlineShed`] reject predicate: a request
/// whose routed shard already owes `backlog_ns` of estimated work cannot
/// finish its own `cost_ns` dispatch within `deadline_ns`. Saturating, so
/// pathological gauge values reject rather than wrap.
///
/// Kept as a free function so `tools/devsim_check.py` can port and verify
/// it bit-for-bit on a grid of synthetic gauge states.
pub fn deadline_would_shed(cost_ns: u64, backlog_ns: u64, deadline_ns: u64) -> bool {
    backlog_ns.saturating_add(cost_ns) > deadline_ns
}

/// How the pool decides whether to accept a request at submit time.
///
/// Budgets are integer nanoseconds on the shard-load-gauge scale: the
/// devsim-priced dispatch cost hints (measured EWMA once telemetry is
/// warm) plus the fixed per-queued-request overhead the gauges charge.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Accept everything — the pre-admission behavior and the default.
    /// The submit path takes a zero-cost early exit: no gauge scans, no
    /// peak tracking, bit-identical dispatch to a pool without admission.
    #[default]
    Unbounded,
    /// Bound both the pool-wide in-flight count and the routed shard's
    /// estimated queue time. Work that was admitted but has waited longer
    /// than `max_queue_ns` *wall-clock* by the time its shard drains it
    /// is shed there instead of served late (the drain-side half of the
    /// same budget). The shards clamp the shed budget to at least twice
    /// the batcher's `max_wait`: time spent inside the deliberate
    /// batching window is never treated as overload.
    BoundedQueue {
        /// Pool-wide cap on requests in flight (queued + executing).
        max_inflight: usize,
        /// Per-shard backlog budget: compared against the *gauge* score
        /// at admit and against *wall-clock* wait at shed-on-drain. For
        /// native backends the two scales coincide once telemetry warms
        /// (measured wall EWMAs feed the gauges); under the unpaced
        /// `SimBackend` they deliberately diverge — gauges carry
        /// simulated device-seconds while the host GEMM sets wall time —
        /// so budgets there bound the two halves on different clocks.
        max_queue_ns: u64,
    },
    /// Reject any request whose estimated completion time — the routed
    /// shard's backlog plus the request's own cost hint — already exceeds
    /// this deadline. The admitted subset is therefore latency-bounded
    /// *to the accuracy of the backlog estimate*: the gauge is read
    /// without a reservation (that is what keeps this policy at one
    /// atomic load per submit), so N submitters racing through admission
    /// — or one `submit_many` run, which judges its requests against a
    /// per-run snapshot advanced locally — can each admit against the
    /// same snapshot and overshoot the deadline by up to the other
    /// racers' admitted work. Everything else fails fast with a retry
    /// hint; there is no drain-side shed (see
    /// [`AdmissionPolicy::queue_budget`]).
    DeadlineShed {
        /// End-to-end deadline budget (gauge ns).
        deadline_ns: u64,
    },
}

impl AdmissionPolicy {
    /// Stable policy label (flags, metrics, bench JSON).
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Unbounded => "unbounded",
            AdmissionPolicy::BoundedQueue { .. } => "bounded-queue",
            AdmissionPolicy::DeadlineShed { .. } => "deadline-shed",
        }
    }

    /// Parse a `--admission` style flag value; `max_inflight` and
    /// `budget_ns` fill the knobs of the bounded policies (`budget_ns` is
    /// `max_queue_ns` for `bounded-queue`, `deadline_ns` for
    /// `deadline-shed`).
    pub fn by_name(name: &str, max_inflight: usize, budget_ns: u64) -> Option<AdmissionPolicy> {
        match name {
            "unbounded" => Some(AdmissionPolicy::Unbounded),
            "bounded" | "bounded-queue" | "bounded_queue" => {
                Some(AdmissionPolicy::BoundedQueue { max_inflight, max_queue_ns: budget_ns })
            }
            "deadline-shed" | "deadline_shed" => {
                Some(AdmissionPolicy::DeadlineShed { deadline_ns: budget_ns })
            }
            _ => None,
        }
    }

    /// Whether this policy ever rejects anything. `Unbounded` pools use
    /// this to skip admission bookkeeping entirely on the hot path.
    pub fn is_unbounded(&self) -> bool {
        matches!(self, AdmissionPolicy::Unbounded)
    }

    /// Whether this policy reads the pool-wide in-flight count. Only
    /// `BoundedQueue` does — the coordinator maintains its reservation
    /// counter (and the `inflight_peak` metric) exclusively for such
    /// policies, so `DeadlineShed` costs one gauge read per submit, not
    /// a contended pool-global RMW pair.
    pub fn caps_inflight(&self) -> bool {
        matches!(self, AdmissionPolicy::BoundedQueue { .. })
    }

    /// The wall-clock queue-time budget the owning shard sheds against at
    /// drain time, if this policy defines one. Only `BoundedQueue` does:
    /// `DeadlineShed` enforces its budget at admit time alone, accepting
    /// the estimate races documented on the variant in exchange for a
    /// submit path that never touches shared admission state.
    pub fn queue_budget(&self) -> Option<Duration> {
        match self {
            AdmissionPolicy::BoundedQueue { max_queue_ns, .. } => {
                Some(Duration::from_nanos(*max_queue_ns))
            }
            _ => None,
        }
    }

    /// This policy with its latency budgets scaled by an SLO-class
    /// factor (see [`crate::coordinator::tenant::SloClass`]): a `Batch`
    /// tenant tolerates 16x the configured `max_queue_ns`/`deadline_ns`
    /// an `Interactive` tenant gets. Saturating, so a huge factor means
    /// "effectively unbounded budget", never a wrapped-around tiny one.
    /// In-flight caps are *not* scaled (they bound memory, not latency),
    /// and the drain-side shed budget the shards enforce stays the
    /// pool-configured one — SLO scaling shapes admission decisions
    /// only. Factor 1 returns the policy unchanged.
    pub fn for_slo_factor(&self, factor: u64) -> AdmissionPolicy {
        match *self {
            AdmissionPolicy::BoundedQueue { max_inflight, max_queue_ns } if factor != 1 => {
                AdmissionPolicy::BoundedQueue {
                    max_inflight,
                    max_queue_ns: max_queue_ns.saturating_mul(factor),
                }
            }
            AdmissionPolicy::DeadlineShed { deadline_ns } if factor != 1 => {
                AdmissionPolicy::DeadlineShed { deadline_ns: deadline_ns.saturating_mul(factor) }
            }
            other => other,
        }
    }

    /// Decide one request: `cost_ns` is its dispatch-cost hint,
    /// `backlog_ns` the routed shard's load-gauge score, `inflight` the
    /// pool-wide in-flight count *before* this request (the coordinator
    /// reserves a slot atomically before asking, so concurrent
    /// submitters cannot race past `max_inflight`). Pure — all side
    /// effects (reservation, peak tracking, counters) belong to the
    /// caller.
    ///
    /// Retry hints from this entry point are priced on the *gauge*
    /// estimate alone — equivalent to [`AdmissionPolicy::admit_with_drain`]
    /// with no measured drain rate.
    pub fn admit(
        &self,
        cost_ns: u64,
        backlog_ns: u64,
        inflight: usize,
    ) -> Result<(), SubmitError> {
        self.admit_with_drain(cost_ns, backlog_ns, inflight, 0, 0.0)
    }

    /// [`AdmissionPolicy::admit`] with the routed shard's measured drain
    /// rate. The accept/reject *decision* is identical; only the
    /// `retry_after_hint` on rejections changes. With `drain_per_sec > 0`
    /// (the shard's EWMA of completions per second over served batches)
    /// each limb converts "how many completions must drain before a retry
    /// can be admitted" into wall-clock at the measured rate — a hint
    /// grounded in how fast the shard actually drains, not in the gauge's
    /// cost estimates (which the drift detector exists to distrust).
    /// `queued_depth` is the routed shard's queue depth behind
    /// `backlog_ns`, used to estimate the backlog's per-job share. With
    /// `drain_per_sec == 0.0` (no batch served yet) the hints fall back
    /// to the gauge-estimate formulas bit-for-bit.
    pub fn admit_with_drain(
        &self,
        cost_ns: u64,
        backlog_ns: u64,
        inflight: usize,
        queued_depth: usize,
        drain_per_sec: f64,
    ) -> Result<(), SubmitError> {
        let measured = drain_per_sec > 0.0;
        match self {
            AdmissionPolicy::Unbounded => Ok(()),
            AdmissionPolicy::BoundedQueue { max_inflight, max_queue_ns } => {
                if inflight >= *max_inflight {
                    // Retry once enough in-flight slots have drained for
                    // this request to fit under the cap: measured rate
                    // when available, else the mean per-request share of
                    // the estimated backlog.
                    let hint = if measured {
                        let jobs = (inflight - *max_inflight + 1) as u64;
                        drain_hint_ns(jobs, drain_per_sec)
                    } else {
                        (backlog_ns / inflight.max(1) as u64).max(MIN_RETRY_HINT_NS)
                    };
                    return Err(SubmitError::Rejected {
                        reason: RejectReason::QueueFull,
                        retry_after_hint: Some(Duration::from_nanos(hint)),
                    });
                }
                if backlog_ns > *max_queue_ns {
                    // Gauge ns over budget, converted to "jobs to drain"
                    // via the backlog's mean per-job share, then to
                    // wall-clock at the measured rate.
                    let hint = if measured {
                        let per_job = (backlog_ns / queued_depth.max(1) as u64).max(1);
                        let jobs = (backlog_ns - *max_queue_ns).div_ceil(per_job).max(1);
                        drain_hint_ns(jobs, drain_per_sec)
                    } else {
                        (backlog_ns - *max_queue_ns).max(MIN_RETRY_HINT_NS)
                    };
                    return Err(SubmitError::Rejected {
                        reason: RejectReason::QueueFull,
                        retry_after_hint: Some(Duration::from_nanos(hint)),
                    });
                }
                Ok(())
            }
            AdmissionPolicy::DeadlineShed { deadline_ns } => {
                if deadline_would_shed(cost_ns, backlog_ns, *deadline_ns) {
                    let excess = backlog_ns
                        .saturating_add(cost_ns)
                        .saturating_sub(*deadline_ns);
                    // The queued fraction of the estimated completion time
                    // that must drain before the deadline becomes
                    // meetable, as a job count at the measured rate.
                    let hint = if measured {
                        let total = backlog_ns.saturating_add(cost_ns).max(1);
                        let jobs = (queued_depth.max(1) as u64)
                            .saturating_mul(excess)
                            .div_ceil(total)
                            .max(1);
                        drain_hint_ns(jobs, drain_per_sec)
                    } else {
                        excess.max(MIN_RETRY_HINT_NS)
                    };
                    return Err(SubmitError::Rejected {
                        reason: RejectReason::DeadlineUnmeetable,
                        retry_after_hint: Some(Duration::from_nanos(hint)),
                    });
                }
                Ok(())
            }
        }
    }
}

/// Milli-tokens one retry costs from a [`RetryBudget`] bucket.
pub const RETRY_TOKEN_MILLI: u64 = 1_000;

/// The pure token-bucket drain: one retry spends [`RETRY_TOKEN_MILLI`]
/// milli-tokens, saturating at empty. Kept as a free function (with
/// [`retry_budget_after_success`] and [`retry_allowed`]) so
/// `tools/devsim_check.py` can port and grid-check the bucket arithmetic
/// bit-for-bit, PR-5/PR-7 style.
pub fn retry_budget_after_failure(tokens_milli: u64) -> u64 {
    tokens_milli.saturating_sub(RETRY_TOKEN_MILLI)
}

/// The pure token-bucket refill: every *successful* call restores
/// `refill_permille` milli-tokens (1000 = one full token per success),
/// capped at `capacity` whole tokens. Refilling on success — not on wall
/// clock — is what makes the budget admission-aware: a pool in overload
/// completes little, so retries earn nothing back and stay shed.
pub fn retry_budget_after_success(tokens_milli: u64, capacity: u64, refill_permille: u64) -> u64 {
    tokens_milli
        .saturating_add(refill_permille)
        .min(capacity.saturating_mul(RETRY_TOKEN_MILLI))
}

/// The pure retry gate: retries are allowed only while the bucket holds
/// *more than half* its capacity. The half-capacity threshold (rather
/// than "more than one token") is what makes retries shed **first**
/// under load: a burst of failures drains the bucket to the threshold
/// after `capacity / 2` retries and every further retry is refused while
/// first-try traffic is still being served — retried work can never
/// amplify an overload.
pub fn retry_allowed(tokens_milli: u64, capacity: u64) -> bool {
    tokens_milli > capacity.saturating_mul(RETRY_TOKEN_MILLI) / 2
}

/// A concurrent token bucket bounding submit retries (see the pure
/// functions [`retry_budget_after_failure`] /
/// [`retry_budget_after_success`] / [`retry_allowed`] for the exact
/// arithmetic). Shared across every retrying caller of one pool so the
/// bound is global, not per thread.
#[derive(Debug)]
pub struct RetryBudget {
    tokens_milli: std::sync::atomic::AtomicU64,
    capacity: u64,
    refill_permille: u64,
}

impl Default for RetryBudget {
    /// 8 tokens of capacity, refilled one-tenth of a token per success:
    /// ~4 retries ride out a transient blip, and sustained failure (or
    /// sustained rejection) keeps the bucket below threshold until
    /// roughly 40 successes have drained through.
    fn default() -> RetryBudget {
        RetryBudget::new(8, 100)
    }
}

impl RetryBudget {
    /// A full bucket of `capacity` tokens refilling `refill_permille`
    /// milli-tokens per observed success.
    pub fn new(capacity: u64, refill_permille: u64) -> RetryBudget {
        RetryBudget {
            tokens_milli: std::sync::atomic::AtomicU64::new(
                capacity.saturating_mul(RETRY_TOKEN_MILLI),
            ),
            capacity,
            refill_permille,
        }
    }

    /// Try to spend one retry token. Returns `true` (and drains the
    /// bucket) when the retry may proceed; `false` sheds the retry.
    pub fn try_spend(&self) -> bool {
        use std::sync::atomic::Ordering;
        let mut current = self.tokens_milli.load(Ordering::Relaxed);
        loop {
            if !retry_allowed(current, self.capacity) {
                return false;
            }
            let next = retry_budget_after_failure(current);
            match self.tokens_milli.compare_exchange_weak(
                current,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => current = seen,
            }
        }
    }

    /// Record a successful (non-retried or retried-and-served) call,
    /// refilling the bucket.
    pub fn on_success(&self) {
        use std::sync::atomic::Ordering;
        let mut current = self.tokens_milli.load(Ordering::Relaxed);
        loop {
            let next =
                retry_budget_after_success(current, self.capacity, self.refill_permille);
            if next == current {
                return;
            }
            match self.tokens_milli.compare_exchange_weak(
                current,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => current = seen,
            }
        }
    }

    /// Current bucket level in milli-tokens (telemetry, trace events).
    pub fn tokens_milli(&self) -> u64 {
        self.tokens_milli.load(std::sync::atomic::Ordering::Relaxed)
    }
}

/// Convert "wait for `jobs` completions at `drain_per_sec`" into a retry
/// hint in nanoseconds, floored at [`MIN_RETRY_HINT_NS`]. Saturates on
/// non-finite or overflowing products (a pathological rate must never
/// wrap into a tiny hint). Crate-visible so the coordinator can price
/// per-tenant quota rejections on the same drain-rate scale.
pub(crate) fn drain_hint_ns(jobs: u64, drain_per_sec: f64) -> u64 {
    let ns = jobs.max(1) as f64 * 1e9 / drain_per_sec;
    if ns.is_finite() && ns < u64::MAX as f64 {
        (ns as u64).max(MIN_RETRY_HINT_NS)
    } else {
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_unbounded_and_always_admits() {
        let policy = AdmissionPolicy::default();
        assert!(policy.is_unbounded());
        assert_eq!(policy.name(), "unbounded");
        assert_eq!(policy.queue_budget(), None);
        assert_eq!(policy.admit(u64::MAX, u64::MAX, usize::MAX), Ok(()));
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(
            AdmissionPolicy::by_name("unbounded", 9, 9),
            Some(AdmissionPolicy::Unbounded)
        );
        assert_eq!(
            AdmissionPolicy::by_name("bounded", 64, 1_000),
            Some(AdmissionPolicy::BoundedQueue { max_inflight: 64, max_queue_ns: 1_000 })
        );
        assert_eq!(
            AdmissionPolicy::by_name("bounded-queue", 1, 2),
            Some(AdmissionPolicy::BoundedQueue { max_inflight: 1, max_queue_ns: 2 })
        );
        assert_eq!(
            AdmissionPolicy::by_name("deadline-shed", 0, 5_000),
            Some(AdmissionPolicy::DeadlineShed { deadline_ns: 5_000 })
        );
        assert_eq!(AdmissionPolicy::by_name("bogus", 0, 0), None);
    }

    #[test]
    fn slo_factor_scales_latency_budgets_only() {
        let bounded = AdmissionPolicy::BoundedQueue { max_inflight: 8, max_queue_ns: 1_000 };
        assert_eq!(bounded.for_slo_factor(1), bounded);
        assert_eq!(
            bounded.for_slo_factor(16),
            AdmissionPolicy::BoundedQueue { max_inflight: 8, max_queue_ns: 16_000 },
            "queue budget scales, inflight cap does not"
        );
        let shed = AdmissionPolicy::DeadlineShed { deadline_ns: u64::MAX / 2 };
        assert_eq!(
            shed.for_slo_factor(4),
            AdmissionPolicy::DeadlineShed { deadline_ns: u64::MAX },
            "saturates instead of wrapping"
        );
        assert_eq!(AdmissionPolicy::Unbounded.for_slo_factor(16), AdmissionPolicy::Unbounded);
    }

    #[test]
    fn bounded_queue_rejects_on_inflight_then_on_backlog() {
        let policy = AdmissionPolicy::BoundedQueue { max_inflight: 4, max_queue_ns: 100_000 };
        assert_eq!(policy.admit(10_000, 0, 0), Ok(()));
        assert_eq!(policy.admit(10_000, 100_000, 3), Ok(()), "at the backlog edge");
        let full = policy.admit(10_000, 50_000, 4).unwrap_err();
        assert_eq!(full.reason(), RejectReason::QueueFull);
        assert!(full.retry_after_hint().unwrap() >= Duration::from_nanos(MIN_RETRY_HINT_NS));
        let deep = policy.admit(10_000, 100_001, 1).unwrap_err();
        assert_eq!(deep.reason(), RejectReason::QueueFull);
        assert_eq!(policy.queue_budget(), Some(Duration::from_nanos(100_000)));
    }

    #[test]
    fn zero_inflight_cap_rejects_everything_deterministically() {
        let policy = AdmissionPolicy::BoundedQueue { max_inflight: 0, max_queue_ns: u64::MAX };
        for backlog in [0u64, 1, 1 << 40] {
            let err = policy.admit(1, backlog, 0).unwrap_err();
            assert_eq!(err.reason(), RejectReason::QueueFull);
        }
    }

    #[test]
    fn deadline_shed_predicate_matches_policy_decisions() {
        let policy = AdmissionPolicy::DeadlineShed { deadline_ns: 200_000 };
        // The policy must agree with the pure predicate on a grid of
        // synthetic gauge states — the same grid tools/devsim_check.py
        // walks against its Python port.
        for cost in [1u64, 20_000, 44_000, 150_000, 300_000] {
            for backlog in [0u64, 44_000, 64_000, 199_999, 200_000, 1 << 40] {
                let want_shed = deadline_would_shed(cost, backlog, 200_000);
                assert_eq!(
                    policy.admit(cost, backlog, 7).is_err(),
                    want_shed,
                    "cost={cost} backlog={backlog}"
                );
            }
        }
        // No drain-side budget: the admitted subset is feasible already.
        assert_eq!(policy.queue_budget(), None);
        let err = policy.admit(150_000, 100_000, 0).unwrap_err();
        assert_eq!(err.reason(), RejectReason::DeadlineUnmeetable);
        assert_eq!(err.retry_after_hint(), Some(Duration::from_nanos(50_000)));
    }

    #[test]
    fn deadline_shed_saturates_instead_of_wrapping() {
        // Pathological gauges must never wrap into a false admit; a
        // u64::MAX deadline is effectively unbounded (the saturating sum
        // reaches it, never exceeds it).
        assert!(deadline_would_shed(u64::MAX, u64::MAX, u64::MAX - 1));
        assert!(!deadline_would_shed(u64::MAX, u64::MAX, u64::MAX));
        assert!(!deadline_would_shed(0, 0, 0));
        assert!(deadline_would_shed(1, 0, 0));
    }

    #[test]
    fn zero_drain_rate_matches_plain_admit_bit_for_bit() {
        // Until a shard serves its first batch the drain EWMA is 0.0 and
        // the measured-hint path must be a no-op: same decisions, same
        // hints as the gauge-estimate formulas.
        let policies = [
            AdmissionPolicy::Unbounded,
            AdmissionPolicy::BoundedQueue { max_inflight: 4, max_queue_ns: 100_000 },
            AdmissionPolicy::DeadlineShed { deadline_ns: 200_000 },
        ];
        for policy in policies {
            for cost in [1u64, 20_000, 150_000] {
                for backlog in [0u64, 64_000, 199_999, 1 << 40] {
                    for inflight in [0usize, 3, 4, 9] {
                        assert_eq!(
                            policy.admit(cost, backlog, inflight),
                            policy.admit_with_drain(cost, backlog, inflight, 7, 0.0),
                            "{policy:?} cost={cost} backlog={backlog} inflight={inflight}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn measured_drain_prices_inflight_hint_in_jobs_over_rate() {
        let policy = AdmissionPolicy::BoundedQueue { max_inflight: 4, max_queue_ns: 100_000 };
        // 6 in flight over a cap of 4: 3 completions must drain (2 excess
        // plus this request's own slot) at 1000 jobs/sec = 3ms.
        let err = policy.admit_with_drain(10_000, 50_000, 6, 5, 1000.0).unwrap_err();
        assert_eq!(err.reason(), RejectReason::QueueFull);
        assert_eq!(err.retry_after_hint(), Some(Duration::from_nanos(3_000_000)));
        // The decision itself is unchanged: under the cap still admits.
        assert_eq!(policy.admit_with_drain(10_000, 50_000, 3, 5, 1000.0), Ok(()));
    }

    #[test]
    fn measured_drain_prices_backlog_hint_from_queue_depth() {
        let policy = AdmissionPolicy::BoundedQueue { max_inflight: 64, max_queue_ns: 100_000 };
        // 150k gauge ns over 5 queued jobs = 30k per job; 50k of excess
        // needs ceil(50/30) = 2 drains at 1000 jobs/sec = 2ms.
        let err = policy.admit_with_drain(10_000, 150_000, 1, 5, 1000.0).unwrap_err();
        assert_eq!(err.reason(), RejectReason::QueueFull);
        assert_eq!(err.retry_after_hint(), Some(Duration::from_nanos(2_000_000)));
    }

    #[test]
    fn measured_drain_prices_deadline_hint_and_floors_it() {
        let policy = AdmissionPolicy::DeadlineShed { deadline_ns: 200_000 };
        // Excess 50k of a 250k completion estimate over 4 queued jobs:
        // ceil(4 * 50/250) = 1 drain. At 1e6 jobs/sec that is 1000ns —
        // exactly the MIN_RETRY_HINT_NS floor.
        let err = policy.admit_with_drain(150_000, 100_000, 0, 4, 1_000_000.0).unwrap_err();
        assert_eq!(err.reason(), RejectReason::DeadlineUnmeetable);
        assert_eq!(err.retry_after_hint(), Some(Duration::from_nanos(MIN_RETRY_HINT_NS)));
        // A slow measured drain stretches the same rejection's hint far
        // past what the gauge formula (excess = 50us) would claim.
        let slow = policy.admit_with_drain(150_000, 100_000, 0, 4, 10.0).unwrap_err();
        assert_eq!(slow.retry_after_hint(), Some(Duration::from_nanos(100_000_000)));
    }

    #[test]
    fn retry_budget_pure_functions_pinned_examples() {
        // Pinned worked examples, ported to tools/devsim_check.py.
        assert_eq!(retry_budget_after_failure(8_000), 7_000);
        assert_eq!(retry_budget_after_failure(400), 0);
        assert_eq!(retry_budget_after_success(7_000, 8, 100), 7_100);
        assert_eq!(retry_budget_after_success(7_950, 8, 100), 8_000, "caps at capacity");
        assert_eq!(retry_budget_after_success(0, 8, 1000), 1_000);
        // The gate: strictly more than half capacity.
        assert!(retry_allowed(4_001, 8));
        assert!(!retry_allowed(4_000, 8));
        assert!(!retry_allowed(0, 8));
        assert!(retry_allowed(1, 0), "zero capacity: any token allows");
    }

    #[test]
    fn retry_budget_sheds_after_half_capacity_and_refills_on_success() {
        let budget = RetryBudget::new(8, 100);
        // 4 retries drain 8000 -> 4000 milli-tokens; the 5th is refused.
        for i in 0..4 {
            assert!(budget.try_spend(), "retry {i} within budget");
        }
        assert!(!budget.try_spend(), "retries shed at half capacity");
        assert_eq!(budget.tokens_milli(), 4_000);
        // Each success earns a tenth of a token back; 11 successes cross
        // the threshold again.
        for _ in 0..11 {
            budget.on_success();
        }
        assert_eq!(budget.tokens_milli(), 5_100);
        assert!(budget.try_spend());
    }

    #[test]
    fn submit_error_display_names_reason_and_hint() {
        let err = SubmitError::Rejected {
            reason: RejectReason::QueueFull,
            retry_after_hint: Some(Duration::from_micros(250)),
        };
        let text = err.to_string();
        assert!(text.contains("queue-full"), "{text}");
        assert!(text.contains("250us"), "{text}");
    }
}
