//! The runtime kernel selector: the deployed configuration set plus the
//! compiled decision tree that maps GEMM shapes to one of them (paper §5).

use crate::classify::codegen::CompiledTree;
use crate::classify::{ClassifierKind, KernelClassifier};
use crate::dataset::{GemmShape, Normalization, PerfDataset};
use crate::selection::{select, Method};

/// How the coordinator picks a kernel configuration per request.
#[derive(Clone, Debug)]
pub enum SelectorPolicy {
    /// The paper's deployment: decision tree over the deployed set.
    Tree(CompiledTree),
    /// A single fixed configuration (the CLBlast-style comparator).
    Single(usize),
    /// Always the XLA-dot backend (the vendor-BLAS comparator).
    Xla,
}

impl SelectorPolicy {
    /// The configuration chosen for a shape; `None` = XLA backend.
    pub fn choose(&self, shape: &GemmShape) -> Option<usize> {
        match self {
            SelectorPolicy::Tree(tree) => Some(tree.predict_config(&shape.features())),
            SelectorPolicy::Single(cfg) => Some(*cfg),
            SelectorPolicy::Xla => None,
        }
    }

    /// The configuration indices this policy can pick from (the deployed
    /// set; empty for the pure-XLA comparator).
    pub fn deployed(&self) -> Vec<usize> {
        match self {
            SelectorPolicy::Tree(tree) => tree.deployed.clone(),
            SelectorPolicy::Single(cfg) => vec![*cfg],
            SelectorPolicy::Xla => vec![],
        }
    }

    /// Stable policy label (flags, logs, reports).
    pub fn name(&self) -> &'static str {
        match self {
            SelectorPolicy::Tree(_) => "tuned-tree",
            SelectorPolicy::Single(_) => "single-config",
            SelectorPolicy::Xla => "xla-gemm",
        }
    }
}

/// End-to-end tuning: benchmark data -> PCA+K-means selection -> decision
/// tree -> compiled selector. This is the "completely automated" pipeline
/// of the paper's conclusion, in one call.
pub fn tune_selector(
    train: &PerfDataset,
    k: usize,
    norm: Normalization,
    seed: u64,
) -> (Vec<usize>, CompiledTree) {
    tune_selector_with(Method::PcaKMeans, ClassifierKind::DecisionTreeB, train, k, norm, seed)
        .expect("decision tree compiles")
}

/// [`tune_selector`] with the selection method and classifier kind
/// exposed — the knobs the online retuner turns (it defaults to the
/// unbounded DecisionTreeA so the tiny live dataset is fitted exactly).
/// Returns `None` when `classifier` is not a compilable decision tree.
pub fn tune_selector_with(
    method: Method,
    classifier: ClassifierKind,
    train: &PerfDataset,
    k: usize,
    norm: Normalization,
    seed: u64,
) -> Option<(Vec<usize>, CompiledTree)> {
    let deployed = select(method, train, norm, k, seed);
    let clf = KernelClassifier::fit(classifier, train, &deployed, seed);
    let tree = CompiledTree::compile(&clf)?;
    Some((deployed, tree))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::benchmark_shapes;
    use crate::devsim::{generate_dataset, profile_by_name};

    #[test]
    fn tuned_selector_chooses_deployed_configs() {
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(5).collect();
        let ds = generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes);
        let (deployed, tree) = tune_selector(&ds, 6, Normalization::Standard, 1);
        assert_eq!(deployed.len(), 6);
        let policy = SelectorPolicy::Tree(tree);
        for s in &shapes {
            let cfg = policy.choose(s).unwrap();
            assert!(deployed.contains(&cfg));
        }
    }

    #[test]
    fn tune_with_exact_tree_fits_training_set() {
        // DecisionTreeA (unbounded) must reproduce the per-shape argmax of
        // the training data exactly — the property online retuning relies
        // on to converge to measured-best picks.
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(23).collect();
        let ds = generate_dataset(profile_by_name("r9-nano").unwrap(), &shapes);
        let (deployed, tree) = tune_selector_with(
            Method::PcaKMeans,
            crate::classify::ClassifierKind::DecisionTreeA,
            &ds,
            4,
            Normalization::Standard,
            3,
        )
        .unwrap();
        assert_eq!(deployed.len(), 4);
        for (i, s) in ds.shapes.iter().enumerate() {
            let best_deployed = *deployed
                .iter()
                .max_by(|&&a, &&b| {
                    ds.gflops[(i, a)].partial_cmp(&ds.gflops[(i, b)]).unwrap()
                })
                .unwrap();
            assert_eq!(
                tree.predict_config(&s.features()),
                best_deployed,
                "shape {s:?} not fitted exactly"
            );
        }
    }

    #[test]
    fn non_tree_classifier_returns_none() {
        let shapes: Vec<GemmShape> =
            benchmark_shapes().into_iter().step_by(23).collect();
        let ds = generate_dataset(profile_by_name("i7-6700k").unwrap(), &shapes);
        assert!(tune_selector_with(
            Method::TopN,
            crate::classify::ClassifierKind::NearestNeighbor1,
            &ds,
            2,
            Normalization::Standard,
            1,
        )
        .is_none());
    }

    #[test]
    fn policies_report_identity() {
        assert_eq!(SelectorPolicy::Xla.name(), "xla-gemm");
        assert_eq!(SelectorPolicy::Xla.choose(&GemmShape::new(8, 8, 8, 1)), None);
        let single = SelectorPolicy::Single(42);
        assert_eq!(single.choose(&GemmShape::new(8, 8, 8, 1)), Some(42));
        assert_eq!(single.deployed(), vec![42]);
    }
}
