//! VGG16 inference engine (paper §6): chains per-layer AOT executables with
//! device-resident activations, choosing a kernel configuration per layer
//! through the runtime selector — the SYCL-DNN integration scenario.

use std::rc::Rc;

use crate::coordinator::selector::SelectorPolicy;
use crate::dataset::GemmShape;
use crate::runtime::{ArtifactMeta, Manifest, Runtime};
use crate::util::fill::layer_weights;

/// Per-layer timing of one inference.
#[derive(Clone, Debug)]
pub struct LayerTiming {
    /// Layer label (e.g. `conv1_1`).
    pub layer: String,
    /// Kernel configuration that served the layer (None = XLA backend).
    pub config: Option<usize>,
    /// The im2col GEMM the layer lowered to.
    pub gemm_shape: GemmShape,
    /// Wall-clock execution seconds for the layer.
    pub secs: f64,
}

/// The VGG16 inference engine: per-layer AOT executables chained over
/// device-resident activations on one PJRT runtime.
pub struct VggEngine<'rt> {
    runtime: &'rt Runtime,
    network: String,
    policy_name: &'static str,
    layers: Vec<LoadedLayer>,
}

struct LoadedLayer {
    meta: ArtifactMeta,
    exe: Rc<xla::PjRtLoadedExecutable>,
    weights: xla::PjRtBuffer,
    bias: xla::PjRtBuffer,
}

/// Seed base matching `python/compile/model.py::network_forward`.
const WEIGHT_SEED: u32 = 7;

impl<'rt> VggEngine<'rt> {
    /// Load every layer of `network` under a selector policy. Weights are
    /// the deterministic synthetic set shared with the Python reference,
    /// uploaded to the device once.
    pub fn load(
        runtime: &'rt Runtime,
        manifest: &Manifest,
        network: &str,
        policy: &SelectorPolicy,
    ) -> Result<VggEngine<'rt>, String> {
        let metas = manifest.network_layers(network, |_, probe| {
            let shape = GemmShape::new(probe.m, probe.k, probe.n, 1);
            policy.choose(&shape)
        })?;
        let mut layers = Vec::with_capacity(metas.len());
        for (i, meta) in metas.into_iter().enumerate() {
            let exe = runtime
                .load(&meta.path)
                .map_err(|e| format!("loading layer {}: {e}", meta.path))?;
            // inputs = [x, w, b]; fan_in/out from the weight shape.
            let wshape = &meta.inputs[1];
            let (fan_in, fan_out) = (wshape[0], wshape[1]);
            let (w, b) = layer_weights(WEIGHT_SEED + 2 * i as u32, fan_in, fan_out);
            let weights = runtime.upload(&w, wshape)?;
            let bias = runtime.upload(&b, &meta.inputs[2])?;
            layers.push(LoadedLayer { meta: meta.clone(), exe, weights, bias });
        }
        Ok(VggEngine {
            runtime,
            network: network.to_string(),
            policy_name: policy.name(),
            layers,
        })
    }

    /// Name of the loaded network (from the manifest).
    pub fn network(&self) -> &str {
        &self.network
    }

    /// Label of the selector policy the layers were resolved with.
    pub fn backend(&self) -> &'static str {
        self.policy_name
    }

    /// Number of chained layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Distinct kernel configurations the selector assigned across layers
    /// (paper §6.2 reports SYCL-DNN using 4 of the 8 deployed on Mali).
    pub fn distinct_configs(&self) -> usize {
        let mut set = std::collections::HashSet::new();
        for l in &self.layers {
            if let Some(c) = l.meta.config_index {
                set.insert(c);
            }
        }
        set.len()
    }

    /// The image shape expected by layer 0: (1, hw, hw, cin).
    pub fn input_shape(&self) -> &[usize] {
        &self.layers[0].meta.inputs[0]
    }

    /// Run one inference; activations stay on the device between layers.
    pub fn infer(&self, image: &[f32]) -> Result<(Vec<f32>, Vec<LayerTiming>), String> {
        let mut timings = Vec::with_capacity(self.layers.len());
        let mut act = self.runtime.upload(image, self.input_shape())?;
        for (i, layer) in self.layers.iter().enumerate() {
            let t0 = std::time::Instant::now();
            // FC layers expect (1, k): the flatten between conv5 and fc6 is
            // a pure reshape, free on row-major buffers — re-upload shape
            // metadata by downloading once at the boundary.
            if layer.meta.kind == crate::runtime::ArtifactKind::FcLayer
                && i > 0
                && self.layers[i - 1].meta.kind == crate::runtime::ArtifactKind::ConvLayer
            {
                // conv5 -> fc6 flatten: a pure reshape; PJRT wants the
                // exact input shape, so round-trip the (tiny) activation.
                let host = self.runtime.download(&act)?;
                act = self.runtime.upload(&host, &layer.meta.inputs[0])?;
            }
            // Outputs are plain arrays (return_tuple=False), so the result
            // buffer feeds the next layer without leaving the device.
            act = self
                .runtime
                .execute_buffers(&layer.exe, &[&act, &layer.weights, &layer.bias])
                .map_err(|e| format!("layer {}: {e}", layer.meta.path))?;
            timings.push(LayerTiming {
                layer: layer.meta.layer.clone().unwrap_or_default(),
                config: layer.meta.config_index,
                gemm_shape: GemmShape::new(layer.meta.m, layer.meta.k, layer.meta.n, 1),
                secs: t0.elapsed().as_secs_f64(),
            });
        }
        let logits = self.runtime.download(&act)?;
        Ok((logits, timings))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selector::tune_selector;
    use crate::dataset::{benchmark_shapes, Normalization};
    use crate::devsim::{generate_dataset, profile_by_name};
    use crate::util::fill_buffer;
    use std::path::PathBuf;

    /// Real PJRT bindings + artifacts required; skip against the stub.
    fn setup() -> Option<(Runtime, Manifest)> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Some((Runtime::new(&dir).ok()?, Manifest::load(&dir).ok()?))
    }

    fn image() -> Vec<f32> {
        fill_buffer(99, 32 * 32 * 3)
    }

    #[test]
    fn xla_backend_inference_runs() {
        let Some((rt, mf)) = setup() else { return };
        let engine = VggEngine::load(&rt, &mf, "vgg16-tiny", &SelectorPolicy::Xla).unwrap();
        assert_eq!(engine.n_layers(), 16);
        let (logits, timings) = engine.infer(&image()).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(timings.len(), 16);
        assert!(timings.iter().all(|t| t.secs >= 0.0));
    }

    #[test]
    fn pallas_single_config_matches_xla_numerics() {
        let Some((rt, mf)) = setup() else { return };
        let best = crate::dataset::config_by_name(&mf.single_best).unwrap().index();
        let xla = VggEngine::load(&rt, &mf, "vgg16-tiny", &SelectorPolicy::Xla).unwrap();
        let pallas =
            VggEngine::load(&rt, &mf, "vgg16-tiny", &SelectorPolicy::Single(best)).unwrap();
        let (lx, _) = xla.infer(&image()).unwrap();
        let (lp, _) = pallas.infer(&image()).unwrap();
        for (a, b) in lx.iter().zip(&lp) {
            assert!((a - b).abs() < 5e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn tuned_selector_end_to_end() {
        let Some((rt, mf)) = setup() else { return };
        // Tune on simulated CPU data, restrict to shipped configs.
        let shapes: Vec<_> = benchmark_shapes().into_iter().step_by(5).collect();
        let ds = generate_dataset(profile_by_name("i7-6700k").unwrap(), &shapes);
        let (_deployed, _tree) = tune_selector(&ds, 6, Normalization::Standard, 1);
        // The shipped deployment is the manifest's; use a tree over it.
        let deployed_idx: Vec<usize> = mf
            .deployed
            .iter()
            .map(|n| crate::dataset::config_by_name(n).unwrap().index())
            .collect();
        let clf = crate::classify::KernelClassifier::fit(
            crate::classify::ClassifierKind::DecisionTreeB,
            &ds,
            &deployed_idx,
            1,
        );
        let tree = crate::classify::codegen::CompiledTree::compile(&clf).unwrap();
        let engine =
            VggEngine::load(&rt, &mf, "vgg16-tiny", &SelectorPolicy::Tree(tree)).unwrap();
        let (logits, timings) = engine.infer(&image()).unwrap();
        assert_eq!(logits.len(), 10);
        // The tuned engine must be using at least 2 distinct kernels
        // across the 16 layers (the paper's Mali observation).
        assert!(
            engine.distinct_configs() >= 2,
            "selector collapsed to {} configs",
            engine.distinct_configs()
        );
        assert_eq!(timings.len(), 16);
    }
}
