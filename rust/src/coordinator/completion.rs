//! Pooled completion slots: the allocation-free replacement for the
//! per-request `mpsc::channel()` pair on the submit hot path.
//!
//! Every submitted request needs a rendezvous between the client thread
//! (which waits for the response) and whichever executor shard ends up
//! serving it. A fresh channel per request costs a heap allocation and a
//! teardown per dispatch; at serving rates that is pure coordination
//! overhead. This module keeps a fixed slab of reusable slots instead:
//! checking one out, completing it and waiting on it touch only atomics,
//! a briefly-held per-slot mutex and `thread::park`/`unpark` — no heap
//! traffic at all once the pool exists.
//!
//! Free slots are tracked in per-lane Treiber stacks (version-tagged
//! `AtomicU64` heads, so the classic ABA race cannot double-lease a
//! slot). Each client thread is assigned a home lane round-robin, so in
//! steady state checkout/release traffic stays on thread-private cache
//! lines — the same striping idea `TelemetrySink` uses for its mutexes.
//!
//! Delivery protocol per use (all safe code):
//!
//! 1. the producer stores the response and takes the registered waiter
//!    under the slot mutex, publishes `READY`, drops the lock, then
//!    unparks the waiter from a local handle — after the unlock it never
//!    touches the slot again;
//! 2. the consumer re-acquires the same mutex to take the value, so its
//!    release of the slot is ordered strictly after the producer's last
//!    touch;
//! 3. `park` wakeups are re-checked against the state word, so banked
//!    unpark permits from earlier uses are harmless.
//!
//! Dropping a [`Completion`] without completing it delivers a synthetic
//! failure response (the worker died mid-batch), so a [`Ticket`] can
//! never wait forever — the same liveness the dropped-`Sender` error of
//! the old channel pair provided.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::Thread;
use std::time::Duration;

use crate::coordinator::admission::SubmitError;
use crate::coordinator::metrics::thread_stripe;
use crate::coordinator::server::GemmResponse;

/// Free-list lanes; checkout prefers the calling thread's home lane.
const LANES: usize = 8;

/// Free-list terminator (no slot index is ever `u32::MAX`).
const NIL: u32 = u32::MAX;

/// Slot states: checked out, response not yet delivered.
const PENDING: u32 = 0;
/// Response delivered; the waiter may consume and release the slot.
const READY: u32 = 1;

struct SlotInner {
    value: Option<GemmResponse>,
    waiter: Option<Thread>,
    /// Set when the consumer dropped its [`Ticket`] before the producer
    /// delivered: the producer then recycles the slot itself, so a
    /// fire-and-forget submit never leaks slab capacity.
    abandoned: bool,
}

struct Slot {
    /// `PENDING` until the producer stores a response, `READY` after.
    state: AtomicU32,
    /// Free-list link: index of the next free slot in this slot's lane.
    next_free: AtomicU32,
    inner: Mutex<SlotInner>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            state: AtomicU32::new(PENDING),
            next_free: AtomicU32::new(NIL),
            inner: Mutex::new(SlotInner { value: None, waiter: None, abandoned: false }),
        }
    }
}

/// A fixed slab of reusable completion slots.
pub struct CompletionPool {
    slots: Vec<Slot>,
    /// Per-lane free stacks. Each head packs `(version << 32) | index`;
    /// the version bumps on every successful push and pop, which defeats
    /// the ABA race a plain index-CAS Treiber stack would suffer.
    lanes: Vec<AtomicU64>,
}

impl CompletionPool {
    /// A pool of `capacity` reusable slots (at least one per lane).
    pub fn new(capacity: usize) -> Arc<CompletionPool> {
        let capacity = capacity.max(LANES);
        let slots: Vec<Slot> = (0..capacity).map(|_| Slot::new()).collect();
        let lanes: Vec<AtomicU64> = (0..LANES).map(|_| AtomicU64::new(NIL as u64)).collect();
        let pool = CompletionPool { slots, lanes };
        for idx in (0..capacity as u32).rev() {
            pool.push_free(idx);
        }
        Arc::new(pool)
    }

    /// Number of reusable slots in the slab (fixed at construction).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    fn push_free(&self, idx: u32) {
        let lane = &self.lanes[idx as usize % LANES];
        loop {
            let head = lane.load(Ordering::Relaxed);
            self.slots[idx as usize].next_free.store(head as u32, Ordering::Relaxed);
            let tagged = (((head >> 32).wrapping_add(1)) << 32) | idx as u64;
            let done = lane
                .compare_exchange_weak(head, tagged, Ordering::Release, Ordering::Relaxed)
                .is_ok();
            if done {
                return;
            }
        }
    }

    fn pop_free(&self, lane_idx: usize) -> Option<u32> {
        let lane = &self.lanes[lane_idx];
        loop {
            let head = lane.load(Ordering::Acquire);
            let idx = head as u32;
            if idx == NIL {
                return None;
            }
            let next = self.slots[idx as usize].next_free.load(Ordering::Relaxed);
            let tagged = (((head >> 32).wrapping_add(1)) << 32) | next as u64;
            let done = lane
                .compare_exchange_weak(head, tagged, Ordering::Acquire, Ordering::Relaxed)
                .is_ok();
            if done {
                return Some(idx);
            }
        }
    }

    /// Check a slot out of the pool: the producer half goes to the shard,
    /// the consumer half to the caller. `None` when every slot is in
    /// flight (the caller falls back to a one-shot heap slot).
    /// (An associated fn, not a method: the halves each hold an
    /// `Arc` to the pool, and `&Arc<Self>` is not a stable receiver.)
    pub fn checkout(pool: &Arc<CompletionPool>) -> Option<(Completion, Ticket)> {
        let start = thread_stripe(LANES);
        for k in 0..LANES {
            if let Some(idx) = pool.pop_free((start + k) % LANES) {
                let completion =
                    Completion { slot: SlotRef::Pooled { pool: pool.clone(), idx }, done: false };
                let ticket = Ticket {
                    slot: Some(SlotRef::Pooled { pool: pool.clone(), idx }),
                    rejected: None,
                };
                return Some((completion, ticket));
            }
        }
        None
    }
}

enum SlotRef {
    /// A slab slot, returned to the free list after `wait`.
    Pooled { pool: Arc<CompletionPool>, idx: u32 },
    /// Overflow fallback: a one-shot heap slot (pool exhausted).
    Owned(Arc<Slot>),
}

impl SlotRef {
    fn slot(&self) -> &Slot {
        match self {
            SlotRef::Pooled { pool, idx } => &pool.slots[*idx as usize],
            SlotRef::Owned(slot) => slot,
        }
    }
}

/// Producer half: delivers exactly one [`GemmResponse`]. Dropping it
/// undelivered completes the slot with a synthetic failure instead, so
/// the paired [`Ticket`] never hangs.
pub struct Completion {
    slot: SlotRef,
    done: bool,
}

impl Completion {
    /// A detached (non-pooled) pair, used when the pool is exhausted.
    pub fn oneshot() -> (Completion, Ticket) {
        let slot = Arc::new(Slot::new());
        let completion = Completion { slot: SlotRef::Owned(slot.clone()), done: false };
        (completion, Ticket { slot: Some(SlotRef::Owned(slot)), rejected: None })
    }

    /// Deliver the response and wake the waiter, if one is parked.
    pub fn complete(mut self, value: GemmResponse) {
        self.deliver(value);
    }

    fn deliver(&mut self, value: GemmResponse) {
        self.done = true;
        let slot = self.slot.slot();
        let mut inner = slot.inner.lock().unwrap();
        if inner.abandoned {
            // The consumer dropped its ticket before delivery: nobody
            // will ever wait, so the producer recycles the slot and the
            // response is discarded (state is still PENDING).
            inner.abandoned = false;
            inner.waiter = None;
            drop(inner);
            if let SlotRef::Pooled { pool, idx } = &self.slot {
                pool.push_free(*idx);
            }
            return;
        }
        inner.value = Some(value);
        let waiter = inner.waiter.take();
        // Publish READY while still holding the lock: the consumer only
        // recycles the slot after re-acquiring this mutex, which orders
        // the recycle strictly after our final touch of the slot.
        slot.state.store(READY, Ordering::Release);
        drop(inner);
        if let Some(thread) = waiter {
            thread.unpark();
        }
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        if !self.done {
            self.deliver(GemmResponse {
                result: Err("request dropped before completion (worker died)".to_string()),
                config_used: None,
                artifact: Arc::from(""),
                latency: Duration::ZERO,
            });
        }
    }
}

/// Consumer half: blocks until the paired [`Completion`] delivers.
/// Dropping a ticket without waiting is safe and leak-free: the slot is
/// recycled immediately when the response already arrived, or marked
/// abandoned so the producer recycles it on delivery — fire-and-forget
/// submits never shrink the slab.
///
/// A ticket can also be born **rejected** by the admission policy
/// ([`Ticket::rejection`]): such a ticket owns no slot at all — the
/// refusal cost neither a heap allocation nor slab capacity — and
/// [`Ticket::wait`] materializes the typed error into a failure response.
pub struct Ticket {
    /// `Some` until consumed by [`Ticket::wait`] (`Drop` then no-ops).
    slot: Option<SlotRef>,
    /// Set when admission refused the request before it was queued; the
    /// ticket then has no slot and resolves immediately.
    rejected: Option<SubmitError>,
}

impl Ticket {
    /// A slot-less ticket carrying an admission refusal. Allocation-free
    /// (`SubmitError` is `Copy`), preserving the zero-alloc submit path.
    pub(crate) fn rejected(err: SubmitError) -> Ticket {
        Ticket { slot: None, rejected: Some(err) }
    }

    /// The admission refusal this ticket carries, if it was rejected at
    /// submit time (`None` for a dispatched request — including one that
    /// later fails execution; those report through the response).
    pub fn rejection(&self) -> Option<SubmitError> {
        self.rejected
    }

    /// Block until the response arrives. Always returns — an undelivered
    /// producer completes with a failure response on drop, and a rejected
    /// ticket resolves immediately with the admission error.
    pub fn wait(mut self) -> GemmResponse {
        if let Some(err) = self.rejected.take() {
            return GemmResponse {
                result: Err(err.to_string()),
                config_used: None,
                artifact: Arc::from(""),
                latency: Duration::ZERO,
            };
        }
        let slot_ref = self.slot.take().expect("ticket consumed once");
        let slot = slot_ref.slot();
        if slot.state.load(Ordering::Acquire) != READY {
            {
                let mut inner = slot.inner.lock().unwrap();
                inner.waiter = Some(std::thread::current());
            }
            // Banked unpark permits from earlier slot uses make park
            // return spuriously; the state word is the source of truth.
            while slot.state.load(Ordering::Acquire) != READY {
                std::thread::park();
            }
        }
        let value = {
            let mut inner = slot.inner.lock().unwrap();
            inner.waiter = None;
            inner.value.take().expect("completed slot holds a response")
        };
        if let SlotRef::Pooled { pool, idx } = &slot_ref {
            slot.state.store(PENDING, Ordering::Relaxed);
            pool.push_free(*idx);
        }
        value
    }

    /// `Receiver::recv`-shaped convenience so existing call sites keep
    /// their `.recv().expect(..)` form. Never returns `Err` — a dropped
    /// producer surfaces as a failure inside the response instead.
    pub fn recv(self) -> Result<GemmResponse, String> {
        Ok(self.wait())
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        let Some(slot_ref) = self.slot.take() else { return };
        let slot = slot_ref.slot();
        let mut inner = slot.inner.lock().unwrap();
        if inner.value.is_some() {
            // Delivered but never waited on: consume and recycle now.
            inner.value = None;
            inner.waiter = None;
            drop(inner);
            if let SlotRef::Pooled { pool, idx } = &slot_ref {
                slot.state.store(PENDING, Ordering::Relaxed);
                pool.push_free(*idx);
            }
        } else {
            // Not delivered yet: the producer recycles on delivery.
            inner.abandoned = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn response(tag: usize) -> GemmResponse {
        GemmResponse {
            result: Ok(vec![tag as f32]),
            config_used: Some(tag),
            artifact: Arc::from("test-artifact"),
            latency: Duration::ZERO,
        }
    }

    #[test]
    fn roundtrip_same_thread() {
        let pool = CompletionPool::new(4);
        let (completion, ticket) = CompletionPool::checkout(&pool).unwrap();
        completion.complete(response(7));
        let resp = ticket.wait();
        assert_eq!(resp.config_used, Some(7));
        assert_eq!(resp.result.unwrap(), vec![7.0]);
    }

    #[test]
    fn wait_parks_until_a_late_producer_delivers() {
        let pool = CompletionPool::new(4);
        let (completion, ticket) = CompletionPool::checkout(&pool).unwrap();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            completion.complete(response(3));
        });
        let resp = ticket.wait();
        assert_eq!(resp.config_used, Some(3));
        producer.join().unwrap();
    }

    #[test]
    fn dropped_completion_delivers_a_failure() {
        let pool = CompletionPool::new(4);
        let (completion, ticket) = CompletionPool::checkout(&pool).unwrap();
        drop(completion);
        let resp = ticket.wait();
        assert!(resp.result.is_err());
        assert!(resp.result.unwrap_err().contains("dropped"));
    }

    #[test]
    fn slots_recycle_far_past_capacity() {
        let pool = CompletionPool::new(8);
        for round in 0..1000usize {
            let (completion, ticket) =
                CompletionPool::checkout(&pool).expect("recycled slot available");
            completion.complete(response(round));
            assert_eq!(ticket.wait().config_used, Some(round));
        }
        assert_eq!(pool.capacity(), 8);
    }

    #[test]
    fn exhausted_pool_reports_none_and_oneshot_fallback_works() {
        let pool = CompletionPool::new(LANES); // minimum size
        let held: Vec<(Completion, Ticket)> =
            (0..LANES).map(|_| CompletionPool::checkout(&pool).expect("slot")).collect();
        assert!(CompletionPool::checkout(&pool).is_none(), "every slot is in flight");
        let (completion, ticket) = Completion::oneshot();
        completion.complete(response(1));
        assert_eq!(ticket.wait().config_used, Some(1));
        for (completion, ticket) in held {
            completion.complete(response(2));
            ticket.wait();
        }
        assert!(CompletionPool::checkout(&pool).is_some(), "slots returned to the free list");
    }

    #[test]
    fn dropped_tickets_do_not_leak_slab_capacity() {
        let pool = CompletionPool::new(LANES); // minimum size: leaks would bite fast
        // Abandon before delivery: the producer recycles on complete().
        for round in 0..100usize {
            let (completion, ticket) = CompletionPool::checkout(&pool).expect("slot");
            drop(ticket);
            completion.complete(response(round));
        }
        // Abandon after delivery: the consumer-side drop recycles.
        for round in 0..100usize {
            let (completion, ticket) = CompletionPool::checkout(&pool).expect("slot");
            completion.complete(response(round));
            drop(ticket);
        }
        // Every slot is back on the free lists.
        let held: Vec<(Completion, Ticket)> =
            (0..LANES).map(|_| CompletionPool::checkout(&pool).expect("slot")).collect();
        assert_eq!(held.len(), LANES);
    }

    #[test]
    fn rejected_ticket_owns_no_slot_and_resolves_immediately() {
        use crate::coordinator::admission::{RejectReason, SubmitError};
        let err = SubmitError::Rejected {
            reason: RejectReason::QueueFull,
            retry_after_hint: Some(Duration::from_micros(10)),
        };
        let ticket = Ticket::rejected(err);
        assert_eq!(ticket.rejection(), Some(err));
        let resp = ticket.wait();
        let msg = resp.result.unwrap_err();
        assert!(msg.contains("queue-full"), "{msg}");
        // Dropping an unconsumed rejected ticket is a no-op (no slot).
        let ticket = Ticket::rejected(err);
        drop(ticket);
    }

    #[test]
    fn concurrent_producers_and_consumers_lose_nothing() {
        let pool = CompletionPool::new(16);
        let mut joins = Vec::new();
        for t in 0..4usize {
            let pool = pool.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..500usize {
                    let tag = t * 1000 + i;
                    let (completion, ticket) =
                        CompletionPool::checkout(&pool).expect("slot available");
                    let producer = std::thread::spawn(move || completion.complete(response(tag)));
                    assert_eq!(ticket.wait().config_used, Some(tag));
                    producer.join().unwrap();
                }
            }));
        }
        for join in joins {
            join.join().unwrap();
        }
    }
}
