//! Dynamic batcher: groups queued GEMM requests that resolved to the same
//! executable so the executor amortizes dispatch overhead, with a bounded
//! per-request wait (the vLLM-style continuous-batching compromise scaled
//! to this library's needs).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A queued unit of work, tagged with the executable it resolved to. The
/// tag is a shared `Arc<str>` (cloned from the resolution), so tagging and
/// regrouping never copy path strings.
#[derive(Debug)]
pub struct Pending<T> {
    /// The executable this unit resolved to (the batching key).
    pub artifact: Arc<str>,
    /// When the request was submitted; deadlines derive from this stamp
    /// and survive work-stealing handoffs.
    pub enqueued: Instant,
    /// The queued unit itself (the server's `Job`).
    pub payload: T,
}

/// Batching knobs: how large a batch may grow and how long a request may
/// wait for peers before its group is drained anyway.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Max requests per drained batch.
    pub max_batch: usize,
    /// A request older than this forces a drain of its group.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(2) }
    }
}

/// Groups queued work by target executable and decides when each group is
/// due (full batch or oldest-entry deadline), draining in EDF order.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    /// An empty batcher with the given knobs.
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        Batcher { cfg, queue: VecDeque::new() }
    }

    /// Enqueue a fresh unit; its wait-clock starts now.
    pub fn push(&mut self, artifact: Arc<str>, payload: T) {
        self.push_pending(Pending { artifact, enqueued: Instant::now(), payload });
    }

    /// Enqueue a unit whose wait-clock is already running — the
    /// work-stealing handoff. The original `enqueued` stamp is preserved so
    /// a batch migrating between shards keeps its deadline instead of
    /// re-arming it; entries may therefore arrive out of age order.
    pub fn push_pending(&mut self, pending: Pending<T>) {
        self.queue.push_back(pending);
    }

    /// Queued units not yet drained.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// The overload-shedding hook: remove and return every queued unit
    /// that has already waited longer than `budget` — work the admission
    /// policy's queue-time budget declares not worth serving anymore.
    /// Order within the returned vec is queue order. The caller (the
    /// executor shard, at drain time) owns completing the shed units with
    /// a rejection and releasing their load-gauge share.
    pub fn shed_overdue(&mut self, budget: Duration) -> Vec<Pending<T>> {
        // One clock snapshot for both passes: cheaper than per-entry
        // `elapsed()` on this per-batch path, and the pre-scan and the
        // rebuild can never disagree about a boundary entry.
        let now = Instant::now();
        let blown = |p: &Pending<T>| now.saturating_duration_since(p.enqueued) > budget;
        if !self.queue.iter().any(blown) {
            return Vec::new(); // common case: nothing blown, no rebuild
        }
        let mut shed = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(p) = self.queue.pop_front() {
            if blown(&p) {
                shed.push(p);
            } else {
                rest.push_back(p);
            }
        }
        self.queue = rest;
        shed
    }

    /// Time until the oldest request exceeds its wait budget (drives the
    /// executor's poll timeout). `None` when idle. Scans the whole queue,
    /// not just the front: stolen handoffs keep their original enqueue
    /// stamps, so the oldest entry need not sit at the front.
    pub fn next_deadline(&self) -> Option<Duration> {
        self.queue
            .iter()
            .map(|p| self.cfg.max_wait.saturating_sub(p.enqueued.elapsed()))
            .min()
    }

    /// Drain a batch if one is due — a group is due when it reached
    /// `max_batch` or any of its requests exceeded the wait budget — and
    /// pick among due groups in **EDF order**: the group holding the
    /// oldest enqueue stamp (the earliest deadline) drains first, not
    /// whichever group a scan happened to find. A pre-aged group arriving
    /// via a work-stealing handoff therefore jumps ahead of a younger
    /// group that merely filled up, and a group whose deadline passed
    /// while another artifact's batch was executing drains on the very
    /// next call instead of being re-armed with a fresh `max_wait`.
    pub fn drain_due(&mut self) -> Option<(Arc<str>, Vec<Pending<T>>)> {
        if self.queue.is_empty() {
            return None;
        }
        // Per artifact group: (size, oldest enqueue stamp). Keys are `Arc`
        // clones of the shared tags — no string copies.
        let mut groups: std::collections::HashMap<Arc<str>, (usize, Instant)> =
            std::collections::HashMap::new();
        for p in &self.queue {
            let entry = groups
                .entry(p.artifact.clone())
                .or_insert((0, p.enqueued));
            entry.0 += 1;
            entry.1 = entry.1.min(p.enqueued);
        }
        let target = groups
            .into_iter()
            .filter(|(_, (size, oldest))| {
                *size >= self.cfg.max_batch || oldest.elapsed() >= self.cfg.max_wait
            })
            .min_by_key(|&(_, (_, oldest))| oldest)
            .map(|(artifact, _)| artifact)?;
        let group = self.take_group(&target);
        Some((target, group))
    }

    /// Remove and return the oldest group unconditionally (up to
    /// `max_batch` units), due or not — the flush/shutdown path. Callers
    /// flushing a whole queue loop this one batch at a time, interleaving
    /// the shed hook, so budget-blown work is never served late just
    /// because a flush was in progress.
    pub fn drain_next(&mut self) -> Option<(Arc<str>, Vec<Pending<T>>)> {
        let artifact = self.queue.front()?.artifact.clone();
        let group = self.take_group(&artifact);
        Some((artifact, group))
    }

    fn take_group(&mut self, artifact: &str) -> Vec<Pending<T>> {
        let mut group = Vec::new();
        let mut rest = VecDeque::with_capacity(self.queue.len());
        while let Some(p) = self.queue.pop_front() {
            if &*p.artifact == artifact && group.len() < self.cfg.max_batch {
                group.push(p);
            } else {
                rest.push_back(p);
            }
        }
        self.queue = rest;
        group
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_wait_ms: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_wait: Duration::from_millis(max_wait_ms) }
    }

    #[test]
    fn groups_by_artifact() {
        let mut b: Batcher<u32> = Batcher::new(cfg(2, 1000));
        b.push("a".into(), 1);
        b.push("b".into(), 2);
        b.push("a".into(), 3);
        // Group "a" reached max_batch=2.
        let (artifact, group) = b.drain_due().unwrap();
        assert_eq!(&*artifact, "a");
        assert_eq!(group.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn respects_max_batch() {
        let mut b: Batcher<u32> = Batcher::new(cfg(3, 1000));
        for i in 0..7 {
            b.push("a".into(), i);
        }
        let (_, group) = b.drain_due().unwrap();
        assert_eq!(group.len(), 3);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn timeout_forces_drain() {
        let mut b: Batcher<u32> = Batcher::new(cfg(100, 0));
        b.push("a".into(), 1);
        std::thread::sleep(Duration::from_millis(1));
        let (artifact, group) = b.drain_due().unwrap();
        assert_eq!(&*artifact, "a");
        assert_eq!(group.len(), 1);
    }

    #[test]
    fn next_deadline_zero_once_oldest_exceeds_max_wait() {
        let mut b: Batcher<u32> = Batcher::new(cfg(100, 1));
        assert!(b.next_deadline().is_none(), "idle batcher has no deadline");
        b.push("a".into(), 1);
        std::thread::sleep(Duration::from_millis(3));
        // The oldest request is already past its wait budget: the deadline
        // must saturate at zero (not underflow / panic), so the executor's
        // poll returns immediately and the group drains.
        assert_eq!(b.next_deadline(), Some(Duration::ZERO));
        let (_, group) = b.drain_due().expect("expired group drains");
        assert_eq!(group.len(), 1);
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn deadline_passed_during_foreign_batch_drains_immediately() {
        // Regression: group "b" must drain on the loop iteration right
        // after its deadline passes, even though that deadline expired
        // while the executor was busy running group "a"'s batch — the
        // batcher must not re-arm "b" with a fresh max_wait.
        let mut b: Batcher<u32> = Batcher::new(cfg(2, 5));
        b.push("a".into(), 1);
        b.push("b".into(), 2);
        b.push("a".into(), 3);
        // "a" reached max_batch and drains first (the "executing" batch).
        let (art, group) = b.drain_due().unwrap();
        assert_eq!(&*art, "a");
        assert_eq!(group.len(), 2);
        // The deadline of "b" passes while "a" executes.
        std::thread::sleep(Duration::from_millis(6));
        assert_eq!(
            b.next_deadline(),
            Some(Duration::ZERO),
            "expired leftover must make the next poll immediate"
        );
        let (art, group) = b.drain_due().expect("b is overdue, must drain now");
        assert_eq!(&*art, "b");
        assert_eq!(group.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![2]);
        assert!(b.is_empty());
    }

    #[test]
    fn stolen_handoff_keeps_original_deadline() {
        // A pre-aged entry arriving via push_pending sits *behind* a fresh
        // front entry; both the deadline and the drain decision must still
        // honor the older stamp.
        let mut b: Batcher<u32> = Batcher::new(cfg(10, 50));
        b.push("fresh".into(), 1);
        b.push_pending(Pending {
            artifact: "stolen".into(),
            enqueued: Instant::now() - Duration::from_millis(60),
            payload: 2,
        });
        assert_eq!(
            b.next_deadline(),
            Some(Duration::ZERO),
            "the stolen entry is already past its wait budget"
        );
        let (art, group) = b.drain_due().expect("overdue stolen group drains");
        assert_eq!(&*art, "stolen");
        assert_eq!(group.len(), 1);
        assert_eq!(b.len(), 1, "the fresh entry stays queued");
        assert!(b.next_deadline().unwrap() > Duration::ZERO);
    }

    #[test]
    fn edf_preaged_stolen_group_jumps_a_full_group() {
        // EDF drain order: a stolen group whose deadline already passed
        // must drain before a younger group that merely hit max_batch —
        // the full group is not the earliest deadline in the queue.
        let mut b: Batcher<u32> = Batcher::new(cfg(2, 50));
        b.push("fresh".into(), 1);
        b.push("fresh".into(), 2); // "fresh" reaches max_batch = 2
        b.push_pending(Pending {
            artifact: "stolen".into(),
            enqueued: Instant::now() - Duration::from_millis(60),
            payload: 3,
        });
        let (art, group) = b.drain_due().expect("stolen group is overdue");
        assert_eq!(&*art, "stolen", "EDF: oldest deadline drains first");
        assert_eq!(group.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![3]);
        // The full group drains right after.
        let (art, group) = b.drain_due().expect("full group still due");
        assert_eq!(&*art, "fresh");
        assert_eq!(group.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn edf_orders_two_expired_groups_by_age() {
        let mut b: Batcher<u32> = Batcher::new(cfg(10, 5));
        b.push("young".into(), 1);
        b.push_pending(Pending {
            artifact: "old".into(),
            enqueued: Instant::now() - Duration::from_millis(30),
            payload: 2,
        });
        std::thread::sleep(Duration::from_millis(6));
        // Both groups are now past the wait budget; the older drains first.
        let (art, _) = b.drain_due().unwrap();
        assert_eq!(&*art, "old");
        let (art, _) = b.drain_due().unwrap();
        assert_eq!(&*art, "young");
    }

    #[test]
    fn shed_overdue_takes_only_blown_entries_in_queue_order() {
        let mut b: Batcher<u32> = Batcher::new(cfg(10, 10_000));
        b.push("fresh".into(), 1);
        b.push_pending(Pending {
            artifact: "old".into(),
            enqueued: Instant::now() - Duration::from_millis(30),
            payload: 2,
        });
        b.push_pending(Pending {
            artifact: "older".into(),
            enqueued: Instant::now() - Duration::from_millis(60),
            payload: 3,
        });
        // Generous budget: nothing shed, queue untouched.
        assert!(b.shed_overdue(Duration::from_secs(1)).is_empty());
        assert_eq!(b.len(), 3);
        // 10ms budget: both pre-aged entries shed, fresh one stays.
        let shed = b.shed_overdue(Duration::from_millis(10));
        assert_eq!(shed.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(b.len(), 1);
        assert_eq!(b.queue.front().unwrap().payload, 1);
        // The survivor still drains normally.
        assert!(b.drain_due().is_none(), "fresh underfull entry not due");
    }

    #[test]
    fn not_due_when_fresh_and_underfull() {
        let mut b: Batcher<u32> = Batcher::new(cfg(10, 10_000));
        b.push("a".into(), 1);
        assert!(b.drain_due().is_none());
        assert!(b.next_deadline().unwrap() > Duration::from_secs(5));
    }

    #[test]
    fn drain_next_empties_fifo_by_oldest_group() {
        let mut b: Batcher<u32> = Batcher::new(cfg(10, 10_000));
        for (art, v) in [("a", 1u32), ("b", 2), ("a", 3), ("c", 4)] {
            b.push(art.into(), v);
        }
        let mut all = Vec::new();
        while let Some(group) = b.drain_next() {
            all.push(group);
        }
        assert!(b.is_empty());
        assert!(b.drain_next().is_none());
        assert_eq!(all.len(), 3);
        assert_eq!(&*all[0].0, "a"); // oldest group first
        assert_eq!(all[0].1.len(), 2);
        // Every payload appears exactly once.
        let total: usize = all.iter().map(|(_, g)| g.len()).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn drain_next_respects_max_batch_leaving_the_rest_queued() {
        let mut b: Batcher<u32> = Batcher::new(cfg(2, 10_000));
        for i in 0..5 {
            b.push("a".into(), i);
        }
        let (_, group) = b.drain_next().unwrap();
        assert_eq!(group.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 3, "the overflow stays queued for the next flush step");
    }

    #[test]
    fn fifo_within_group() {
        let mut b: Batcher<u32> = Batcher::new(cfg(4, 0));
        for i in 0..4 {
            b.push("a".into(), i);
        }
        let (_, group) = b.drain_due().unwrap();
        let order: Vec<u32> = group.iter().map(|p| p.payload).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }
}
