//! Kernel registry: resolves a GEMM request to a concrete AOT artifact.
//!
//! The selector proposes a configuration; the registry reconciles that with
//! what was actually shipped (the deployed artifact set), falling back in
//! order: chosen config at the exact shape -> any deployed config at the
//! shape -> the XLA-dot backend at the shape. Shapes with no artifact at
//! all are rejected — like a SYCL library, we can only run what was
//! compiled in.
//!
//! The policy lives behind a generation-counted [`SelectorHandle`] so the
//! background retuner can hot-swap it under traffic. Every resolution
//! reads exactly one policy snapshot — the proposed config and the
//! deployed fallback set always come from the same deployment, never a
//! torn mix — and reports the snapshot's generation so the selector cache
//! can tag (and later invalidate) what it memoized.

use std::sync::Arc;

use crate::coordinator::quarantine::QuarantineSet;
use crate::coordinator::selector::SelectorPolicy;
use crate::dataset::GemmShape;
use crate::runtime::{ArtifactMeta, Manifest};
use crate::tuning::swap::{DeployedSelector, SelectorHandle};

/// Maps GEMM requests to shipped AOT artifacts through the current
/// selector deployment (see the module docs for the fallback order).
pub struct KernelRegistry {
    /// The shipped deployment: artifact paths, deployed configs, buckets.
    pub manifest: Manifest,
    selector: SelectorHandle,
    /// The pool-wide variant circuit breaker, when fault tolerance is
    /// wired in: quarantined configs are skipped by the fallback ladder
    /// (except for sampled probation probes) and masked out of
    /// [`KernelRegistry::healthy_shipped_configs`].
    quarantine: Option<Arc<QuarantineSet>>,
}

/// The outcome of a resolution, for metrics/inspection. `Copy`: cloning a
/// [`crate::coordinator::cache::ResolvedKernel`] must stay allocation-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resolution {
    /// The selector's first choice was shipped.
    Direct,
    /// Fell back to another deployed configuration.
    FallbackConfig,
    /// Fell back to the XLA backend artifact.
    FallbackXla,
}

impl KernelRegistry {
    /// A registry serving `manifest` through `policy` (generation 0).
    pub fn new(manifest: Manifest, policy: SelectorPolicy) -> KernelRegistry {
        KernelRegistry { manifest, selector: SelectorHandle::new(policy), quarantine: None }
    }

    /// Builder: consult `quarantine` during resolution. Shared (one
    /// `Arc`) across every retune domain's registry, so a variant that
    /// trips anywhere is skipped everywhere.
    pub fn with_quarantine(mut self, quarantine: Arc<QuarantineSet>) -> KernelRegistry {
        self.quarantine = Some(quarantine);
        self
    }

    /// The quarantine set this registry consults, if any.
    pub fn quarantine(&self) -> Option<&Arc<QuarantineSet>> {
        self.quarantine.as_ref()
    }

    /// The shipped configuration pool minus currently quarantined
    /// variants — what the background retuner re-selects from, so a
    /// tripped variant cannot be re-deployed while blocked. Degrades to
    /// the full shipped pool if everything is blocked (selection needs a
    /// non-empty candidate set, and the XLA floor still serves traffic).
    pub fn healthy_shipped_configs(&self) -> Vec<usize> {
        let shipped = self.manifest.shipped_configs();
        let Some(q) = self.quarantine.as_ref() else {
            return shipped;
        };
        let healthy: Vec<usize> =
            shipped.iter().copied().filter(|&c| !q.blocks(c)).collect();
        if healthy.is_empty() {
            shipped
        } else {
            healthy
        }
    }

    /// The current policy deployment snapshot.
    pub fn policy(&self) -> Arc<DeployedSelector> {
        self.selector.load()
    }

    /// The current deployment generation (0 = the boot policy).
    pub fn generation(&self) -> u64 {
        self.selector.generation()
    }

    /// Hot-swap the selector policy; returns the new generation. Callers
    /// that also hold the selector cache should go through
    /// [`crate::tuning::swap::deploy_policy`] so stale cache entries are
    /// invalidated in the same step.
    pub fn swap_policy(&self, policy: SelectorPolicy) -> u64 {
        self.selector.swap(policy)
    }

    /// Resolve a GEMM shape to an artifact. Returns the artifact, how the
    /// resolution fell back, and the generation of the policy snapshot
    /// that produced it.
    ///
    /// With a quarantine set wired in, the selector's choice is screened
    /// first — a quarantined variant is skipped (falling through the
    /// ladder to the next-best healthy config) except on the sampled
    /// probation trickle, which lets the variant prove itself again —
    /// and quarantined configs never serve as `FallbackConfig`. The XLA
    /// comparator is the untracked healthy floor.
    pub fn resolve(
        &self,
        shape: &GemmShape,
    ) -> Result<(&ArtifactMeta, Resolution, u64), String> {
        let (m, k, n, b) = (shape.m, shape.k, shape.n, shape.batch);
        // One snapshot for the whole resolution: `want` and the fallback
        // set can never come from different deployments.
        let snapshot = self.selector.load();
        let want = snapshot.policy.choose(shape);
        if let Some(q) = self.quarantine.as_ref() {
            // Screening (unlike the pure `blocks` reads below) advances
            // the chosen variant's cooloff/probe state: the variant the
            // selector keeps proposing is the one that earns probes.
            if let Some(cfg) = want {
                if q.is_active() && !q.screen(cfg) {
                    for cfg in snapshot.policy.deployed() {
                        if q.blocks(cfg) {
                            continue;
                        }
                        if let Some(meta) = self.manifest.find_matmul(Some(cfg), m, k, n, b)
                        {
                            return Ok((meta, Resolution::FallbackConfig, snapshot.generation));
                        }
                    }
                    if let Some(meta) = self.manifest.find_matmul(None, m, k, n, b) {
                        return Ok((meta, Resolution::FallbackXla, snapshot.generation));
                    }
                    return Err(format!(
                        "no healthy artifact for GEMM {m}x{k}x{n} (batch {b})"
                    ));
                }
            }
        }
        if let Some(meta) = self.manifest.find_matmul(want, m, k, n, b) {
            return Ok((meta, Resolution::Direct, snapshot.generation));
        }
        // Any other deployed config at this shape.
        let quarantine = self.quarantine.as_deref();
        for cfg in snapshot.policy.deployed() {
            if Some(cfg) != want && !quarantine.is_some_and(|q| q.blocks(cfg)) {
                if let Some(meta) = self.manifest.find_matmul(Some(cfg), m, k, n, b) {
                    return Ok((meta, Resolution::FallbackConfig, snapshot.generation));
                }
            }
        }
        if let Some(meta) = self.manifest.find_matmul(None, m, k, n, b) {
            return Ok((meta, Resolution::FallbackXla, snapshot.generation));
        }
        Err(format!(
            "no artifact for GEMM {m}x{k}x{n} (batch {b}); \
             known buckets: {}",
            self.manifest.matmul_shapes().len()
        ))
    }

    /// The shape buckets this registry can serve.
    pub fn buckets(&self) -> Vec<GemmShape> {
        self.manifest
            .matmul_shapes()
            .into_iter()
            .map(|(m, k, n, b)| GemmShape::new(m, k, n, b))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classify::codegen::CompiledTree;
    use crate::runtime::{ArtifactKind, ArtifactMeta};
    use std::path::PathBuf;

    fn registry(policy: SelectorPolicy) -> KernelRegistry {
        KernelRegistry::new(Manifest::synthetic(), policy)
    }

    #[test]
    fn resolves_xla_backend() {
        let reg = registry(SelectorPolicy::Xla);
        let (meta, res, generation) =
            reg.resolve(&GemmShape::new(128, 128, 128, 1)).unwrap();
        assert_eq!(res, Resolution::Direct);
        assert!(meta.config_index.is_none());
        assert_eq!(generation, 0);
    }

    #[test]
    fn resolves_single_config_with_fallback() {
        // Config index 0 is not in the synthetic deployment, so a Single
        // policy for it must fall back at shipped shapes.
        let reg = registry(SelectorPolicy::Single(0));
        let (_, res, _) = reg.resolve(&GemmShape::new(128, 128, 128, 1)).unwrap();
        assert_eq!(res, Resolution::FallbackXla);
        // The shipped single-best config resolves directly.
        let best = crate::dataset::config_by_name(&reg.manifest.single_best)
            .unwrap()
            .index();
        let reg2 = registry(SelectorPolicy::Single(best));
        let (meta, res, _) = reg2.resolve(&GemmShape::new(128, 128, 128, 1)).unwrap();
        assert_eq!(res, Resolution::Direct);
        assert_eq!(meta.config_index, Some(best));
    }

    #[test]
    fn unknown_shape_rejected() {
        let reg = registry(SelectorPolicy::Xla);
        assert!(reg.resolve(&GemmShape::new(17, 19, 23, 1)).is_err());
    }

    #[test]
    fn buckets_nonempty_and_sorted_unique() {
        let reg = registry(SelectorPolicy::Xla);
        let buckets = reg.buckets();
        assert!(buckets.len() > 10);
        let set: std::collections::HashSet<_> =
            buckets.iter().map(|s| s.label()).collect();
        assert_eq!(set.len(), buckets.len());
    }

    #[test]
    fn swap_changes_resolution_and_generation() {
        let best = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let reg = registry(SelectorPolicy::Xla);
        let shape = GemmShape::new(64, 64, 64, 1);
        let (meta, _, generation) = reg.resolve(&shape).unwrap();
        assert_eq!(meta.config_index, None);
        assert_eq!(generation, 0);
        assert_eq!(reg.swap_policy(SelectorPolicy::Single(best)), 1);
        assert_eq!(reg.generation(), 1);
        let (meta, res, generation) = reg.resolve(&shape).unwrap();
        assert_eq!(meta.config_index, Some(best));
        assert_eq!(res, Resolution::Direct);
        assert_eq!(generation, 1);
        assert_eq!(reg.policy().policy.name(), "single-config");
    }

    // --- full fallback-ordering coverage on a hand-built manifest ---------

    fn matmul_meta(config_index: Option<usize>, m: usize, k: usize, n: usize) -> ArtifactMeta {
        ArtifactMeta {
            path: format!("test/{config_index:?}/m{m}k{k}n{n}.hlo.txt"),
            kind: ArtifactKind::Matmul,
            config_index,
            config_name: None,
            m,
            k,
            n,
            b: 1,
            flops: 2.0 * (m * k * n) as f64,
            network: None,
            layer: None,
            layer_index: None,
            pool: false,
            relu: false,
            inputs: vec![vec![1, m, k], vec![1, k, n]],
            output: vec![1, m, n],
        }
    }

    /// A selector that always proposes deployed config A out of {A, B}: a
    /// single-leaf decision tree, built through the serialized form.
    fn always_a_policy(a: usize, b: usize) -> SelectorPolicy {
        let tree =
            CompiledTree::deserialize(&format!("deployed {a},{b}\nleaf 0\n")).unwrap();
        SelectorPolicy::Tree(tree)
    }

    #[test]
    fn fallback_ordering_direct_then_config_then_xla_then_error() {
        let a = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let b = crate::dataset::config_by_name("r2a4c8_wg8x32").unwrap().index();
        // Shape coverage: 8^3 ships A; 64^3 ships only B (+XLA); 32^3 ships
        // only XLA; 16^3 ships nothing.
        let manifest = Manifest::from_parts(
            PathBuf::from("<test>"),
            vec!["r8a4c4_wg16x16".into(), "r2a4c8_wg8x32".into()],
            "r8a4c4_wg16x16".into(),
            vec![
                matmul_meta(Some(a), 8, 8, 8),
                matmul_meta(Some(b), 64, 64, 64),
                matmul_meta(None, 64, 64, 64),
                matmul_meta(None, 32, 32, 32),
            ],
        );
        let reg = KernelRegistry::new(manifest, always_a_policy(a, b));

        // 1. The proposed config is shipped at the shape: Direct.
        let (meta, res, _) = reg.resolve(&GemmShape::new(8, 8, 8, 1)).unwrap();
        assert_eq!(res, Resolution::Direct);
        assert_eq!(meta.config_index, Some(a));

        // 2. Proposed config missing, another deployed config shipped:
        //    FallbackConfig (preferred over the XLA artifact also present).
        let (meta, res, _) = reg.resolve(&GemmShape::new(64, 64, 64, 1)).unwrap();
        assert_eq!(res, Resolution::FallbackConfig);
        assert_eq!(meta.config_index, Some(b));

        // 3. No deployed config shipped, XLA artifact present: FallbackXla.
        let (meta, res, _) = reg.resolve(&GemmShape::new(32, 32, 32, 1)).unwrap();
        assert_eq!(res, Resolution::FallbackXla);
        assert_eq!(meta.config_index, None);

        // 4. Nothing shipped at the shape: error.
        let err = reg.resolve(&GemmShape::new(16, 16, 16, 1)).unwrap_err();
        assert!(err.contains("no artifact"), "{err}");
    }

    // --- quarantine interaction ------------------------------------------

    use crate::coordinator::quarantine::{QuarantineConfig, QuarantineSet};

    fn trip(q: &QuarantineSet, cfg: usize) {
        for _ in 0..QuarantineConfig::default().trip_failures {
            q.observe(Some(cfg), false);
        }
        assert!(q.blocks(cfg));
    }

    #[test]
    fn quarantined_choice_falls_through_ladder() {
        let a = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let b = crate::dataset::config_by_name("r2a4c8_wg8x32").unwrap().index();
        let q = Arc::new(QuarantineSet::new(QuarantineConfig::default()));
        let reg = registry(always_a_policy(a, b)).with_quarantine(q.clone());
        let shape = GemmShape::new(64, 64, 64, 1);
        // Healthy: A resolves directly (both A and B ship in synthetic).
        let (meta, res, _) = reg.resolve(&shape).unwrap();
        assert_eq!((meta.config_index, res), (Some(a), Resolution::Direct));
        // Tripped A: resolution falls to the next deployed config.
        trip(&q, a);
        let (meta, res, _) = reg.resolve(&shape).unwrap();
        assert_eq!(res, Resolution::FallbackConfig);
        assert_ne!(meta.config_index, Some(a));
        // Tripped B too: the whole deployed set of this policy ({A, B})
        // is blocked; the XLA comparator is the untracked healthy floor.
        trip(&q, b);
        assert_eq!(q.trips(), 2);
        let (meta, res, _) = reg.resolve(&shape).unwrap();
        assert_eq!((meta.config_index, res), (None, Resolution::FallbackXla));
    }

    #[test]
    fn probation_probe_resolves_direct() {
        let a = crate::dataset::config_by_name("r8a4c4_wg16x16").unwrap().index();
        let b = crate::dataset::config_by_name("r2a4c8_wg8x32").unwrap().index();
        let cfg = QuarantineConfig::default();
        let q = Arc::new(QuarantineSet::new(cfg));
        let reg = registry(always_a_policy(a, b)).with_quarantine(q.clone());
        let shape = GemmShape::new(64, 64, 64, 1);
        trip(&q, a);
        // Each resolve screens A once, ticking the cooloff; after the
        // cooloff drains the next resolve is the fired probe: Direct.
        for _ in 0..cfg.cooloff {
            let (_, res, _) = reg.resolve(&shape).unwrap();
            assert_ne!(res, Resolution::Direct);
        }
        let (meta, res, _) = reg.resolve(&shape).unwrap();
        assert_eq!((meta.config_index, res), (Some(a), Resolution::Direct));
        // The probe is a sampled trickle, not a floodgate: the next
        // probe_every - 1 resolves fall back again.
        for _ in 1..cfg.probe_every {
            let (_, res, _) = reg.resolve(&shape).unwrap();
            assert_ne!(res, Resolution::Direct);
        }
        // Promote on sustained probe success; resolution heals to Direct.
        for _ in 0..cfg.promote_successes {
            q.observe(Some(a), true);
        }
        assert!(!q.blocks(a));
        let (_, res, _) = reg.resolve(&shape).unwrap();
        assert_eq!(res, Resolution::Direct);
        assert_eq!(q.restores(), 1);
    }

    #[test]
    fn healthy_shipped_configs_masks_blocked() {
        let reg = registry(SelectorPolicy::Xla);
        let all = reg.manifest.shipped_configs();
        assert_eq!(reg.healthy_shipped_configs(), all);
        let q = Arc::new(QuarantineSet::new(QuarantineConfig::default()));
        let reg = registry(SelectorPolicy::Xla).with_quarantine(q.clone());
        trip(&q, all[0]);
        let healthy = reg.healthy_shipped_configs();
        assert_eq!(healthy.len(), all.len() - 1);
        assert!(!healthy.contains(&all[0]));
        // All blocked: degrade to the full pool rather than an empty one.
        for &c in &all {
            if !q.blocks(c) {
                trip(&q, c);
            }
        }
        assert_eq!(reg.healthy_shipped_configs(), all);
    }
}
