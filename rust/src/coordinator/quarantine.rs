//! Per-variant circuit breaker: windowed failure tracking trips a kernel
//! configuration into quarantine, a cooloff leads to half-open probation,
//! and sustained probe success promotes it back to healthy.
//!
//! The paper's premise — a *small* shipped kernel set serving every input
//! — means one misbehaving variant takes out a disproportionate slice of
//! capacity if the selector keeps choosing it. This module is the pure
//! decision layer: [`VariantHealth`] is a sequential state machine over
//! one variant's observed outcomes (ported verbatim to
//! `tools/devsim_check.py` for cross-validation), and [`QuarantineSet`]
//! wraps one `VariantHealth` per shipped configuration behind a bitmask
//! fast path so a healthy pool pays a single relaxed atomic load per
//! observation.
//!
//! State machine (all thresholds from [`QuarantineConfig`]):
//!
//! ```text
//! Healthy --[>= trip_failures failures in last window outcomes]--> Quarantined
//! Quarantined --[cooloff screen calls elapse]--> Probation
//! Probation --[1 probe per probe_every screens; promote_successes
//!              consecutive probe successes]--> Healthy
//! Probation --[any probe failure]--> Quarantined (cooloff restarts)
//! ```
//!
//! While a variant is not `Healthy`, the registry's fallback ladder skips
//! it (except for sampled probes), the resolution cache treats hits on it
//! as misses — invalidation equivalent to a generation bump without a
//! walk — and the retuner masks it out of the shipped pool.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::dataset::NUM_CONFIGS;

/// Thresholds for the trip/probation/promotion state machine.
///
/// The defaults are deliberately aggressive: a variant failing half of a
/// 16-outcome window trips, sits out 32 resolution attempts, then earns
/// its way back with 3 consecutive probe successes sampled one-in-8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuarantineConfig {
    /// Sliding outcome window size in observations (clamped to 1..=64 —
    /// the window is a u64 bitmask).
    pub window: u32,
    /// Failures within the window that trip the variant.
    pub trip_failures: u32,
    /// Resolution attempts a quarantined variant sits out before
    /// half-open probation begins.
    pub cooloff: u32,
    /// During probation, one resolution in `probe_every` is allowed
    /// through as a probe; the rest keep falling back.
    pub probe_every: u32,
    /// Consecutive probe successes that promote the variant back to
    /// healthy.
    pub promote_successes: u32,
}

impl Default for QuarantineConfig {
    fn default() -> QuarantineConfig {
        QuarantineConfig {
            window: 16,
            trip_failures: 8,
            cooloff: 32,
            probe_every: 8,
            promote_successes: 3,
        }
    }
}

impl QuarantineConfig {
    fn window_mask(&self) -> u64 {
        let w = self.window.clamp(1, 64);
        if w >= 64 {
            u64::MAX
        } else {
            (1u64 << w) - 1
        }
    }
}

/// Health of one kernel configuration.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Health {
    /// Normal operation: selectable, outcomes tracked in the window.
    #[default]
    Healthy,
    /// Tripped: never selectable; screening ticks the cooloff down.
    Quarantined,
    /// Half-open: selectable only on a sampled probe trickle.
    Probation,
}

/// A state-machine transition worth reporting (trace events, counters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transition {
    /// Healthy or Probation → Quarantined.
    Tripped,
    /// A probation probe succeeded but did not yet promote.
    Probed,
    /// Probation → Healthy on sustained probe success.
    Restored,
}

/// The pure per-variant trip/probation/promotion state machine.
///
/// Two entry points: [`VariantHealth::observe`] folds one execution
/// outcome in (called from the serving shard after every execute of the
/// variant), and [`VariantHealth::screen`] asks "may the resolver pick
/// this variant right now?" (called from the registry's resolve path) —
/// screening is what ticks the cooloff and samples the probe trickle, so
/// a quarantined variant nobody wants stays quarantined for free.
#[derive(Clone, Copy, Debug, Default)]
pub struct VariantHealth {
    /// Current state.
    pub state: Health,
    /// Bitmask of the last `window` outcomes; bit set = failure.
    recent: u64,
    /// Outcomes observed since the window was last reset (saturates at
    /// the window size).
    seen: u32,
    /// Screens remaining before a quarantined variant enters probation.
    cooloff_left: u32,
    /// Probation screen counter (samples the probe trickle).
    probe_tick: u32,
    /// Consecutive probe successes in the current probation.
    probe_successes: u32,
}

impl VariantHealth {
    /// Fold one execution outcome in; returns the transition it caused,
    /// if any.
    pub fn observe(&mut self, ok: bool, cfg: &QuarantineConfig) -> Option<Transition> {
        match self.state {
            Health::Healthy => {
                self.recent = ((self.recent << 1) | u64::from(!ok)) & cfg.window_mask();
                self.seen = (self.seen + 1).min(cfg.window.clamp(1, 64));
                if self.recent.count_ones() >= cfg.trip_failures.max(1) {
                    self.trip(cfg);
                    return Some(Transition::Tripped);
                }
                None
            }
            // Stragglers from batches dispatched before the trip: already
            // quarantined, nothing to learn.
            Health::Quarantined => None,
            Health::Probation => {
                if ok {
                    self.probe_successes += 1;
                    if self.probe_successes >= cfg.promote_successes.max(1) {
                        *self = VariantHealth::default();
                        Some(Transition::Restored)
                    } else {
                        Some(Transition::Probed)
                    }
                } else {
                    self.trip(cfg);
                    Some(Transition::Tripped)
                }
            }
        }
    }

    /// May the resolver select this variant right now? Returns
    /// `(selectable, is_probe)`; quarantine cooloff and the probation
    /// probe cadence advance as side effects.
    pub fn screen(&mut self, cfg: &QuarantineConfig) -> (bool, bool) {
        match self.state {
            Health::Healthy => (true, false),
            Health::Quarantined => {
                self.cooloff_left = self.cooloff_left.saturating_sub(1);
                if self.cooloff_left == 0 {
                    self.state = Health::Probation;
                    self.probe_tick = 0;
                    self.probe_successes = 0;
                }
                (false, false)
            }
            Health::Probation => {
                let fire = self.probe_tick % cfg.probe_every.max(1) == 0;
                self.probe_tick = self.probe_tick.wrapping_add(1);
                (fire, fire)
            }
        }
    }

    /// True while the variant must be skipped by non-probing resolution
    /// (fallback ladder, retuner pool, cache hits).
    pub fn blocked(&self) -> bool {
        self.state != Health::Healthy
    }

    fn trip(&mut self, cfg: &QuarantineConfig) {
        self.state = Health::Quarantined;
        self.recent = 0;
        self.seen = 0;
        self.cooloff_left = cfg.cooloff.max(1);
        self.probe_tick = 0;
        self.probe_successes = 0;
    }
}

/// Pool-wide concurrent quarantine state: one [`VariantHealth`] per
/// shipped configuration behind a blocked-bit fast path.
///
/// The hot paths are engineered around "nothing is quarantined", which is
/// the steady state: observing a success costs one relaxed load of the
/// active count, and screening a config costs that load plus one relaxed
/// bitmask load. Only failures and quarantined configs take the mutex.
#[derive(Debug)]
pub struct QuarantineSet {
    cfg: QuarantineConfig,
    /// One bit per config; set while the config is blocked (Quarantined
    /// or Probation). Mirrors `inner` for lock-free screening.
    blocked_bits: Vec<AtomicU64>,
    /// Number of currently blocked configs (fast-path gate).
    active: AtomicUsize,
    inner: Mutex<Vec<VariantHealth>>,
    trips: AtomicU64,
    probes: AtomicU64,
    restores: AtomicU64,
}

impl QuarantineSet {
    /// An empty set (everything healthy) under `cfg` thresholds.
    pub fn new(cfg: QuarantineConfig) -> QuarantineSet {
        let words = NUM_CONFIGS.div_ceil(64);
        QuarantineSet {
            cfg,
            blocked_bits: (0..words).map(|_| AtomicU64::new(0)).collect(),
            active: AtomicUsize::new(0),
            inner: Mutex::new(vec![VariantHealth::default(); NUM_CONFIGS]),
            trips: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            restores: AtomicU64::new(0),
        }
    }

    /// True while any config is blocked — the one-load fast-path gate.
    #[inline]
    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Relaxed) > 0
    }

    /// Lock-free: is `config` currently blocked (quarantined or on
    /// probation)? Pure read; never advances cooloff or probe state.
    #[inline]
    pub fn blocks(&self, config: usize) -> bool {
        if !self.is_active() || config >= NUM_CONFIGS {
            return false;
        }
        let bit = 1u64 << (config % 64);
        self.blocked_bits[config / 64].load(Ordering::Relaxed) & bit != 0
    }

    /// Fold one execution outcome for `config` in. `None` configs (the
    /// XLA fallback artifact) are never tracked — XLA is the healthy
    /// floor the ladder lands on. Returns the transition, if any.
    pub fn observe(&self, config: Option<usize>, ok: bool) -> Option<Transition> {
        let config = config?;
        if config >= NUM_CONFIGS || (ok && !self.is_active()) {
            // Success with nothing quarantined: the steady state. One
            // relaxed load, no lock — keeps the warm path allocation-free
            // and bit-identical to the pre-quarantine pool.
            return None;
        }
        let mut inner = self.inner.lock().unwrap();
        let was_blocked = inner[config].blocked();
        let transition = inner[config].observe(ok, &self.cfg);
        match transition {
            Some(Transition::Tripped) => {
                self.trips.fetch_add(1, Ordering::Relaxed);
                if was_blocked {
                    // A failed probe: the bit is already set.
                    self.probes.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.set_blocked(config, true);
                    self.active.fetch_add(1, Ordering::Relaxed);
                }
            }
            Some(Transition::Probed) => {
                self.probes.fetch_add(1, Ordering::Relaxed);
            }
            Some(Transition::Restored) => {
                self.probes.fetch_add(1, Ordering::Relaxed);
                self.restores.fetch_add(1, Ordering::Relaxed);
                self.set_blocked(config, false);
                self.active.fetch_sub(1, Ordering::Relaxed);
            }
            None => {}
        }
        transition
    }

    /// May the resolver select `config` right now? Advances cooloff and
    /// the probation probe cadence for blocked configs; free (one load)
    /// for healthy ones.
    pub fn screen(&self, config: usize) -> bool {
        if !self.blocks(config) {
            return true;
        }
        let mut inner = self.inner.lock().unwrap();
        let (selectable, _probe) = inner[config].screen(&self.cfg);
        selectable
    }

    /// Total trips (Healthy/Probation → Quarantined).
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Total probe outcomes observed during probation (successful or
    /// tripping).
    pub fn probes(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }

    /// Total promotions back to healthy.
    pub fn restores(&self) -> u64 {
        self.restores.load(Ordering::Relaxed)
    }

    /// Number of currently blocked configs.
    pub fn active_count(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    fn set_blocked(&self, config: usize, blocked: bool) {
        let bit = 1u64 << (config % 64);
        let word = &self.blocked_bits[config / 64];
        if blocked {
            word.fetch_or(bit, Ordering::Relaxed);
        } else {
            word.fetch_and(!bit, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> QuarantineConfig {
        QuarantineConfig::default()
    }

    #[test]
    fn trips_at_windowed_threshold_exactly() {
        // Pinned worked example (ported to tools/devsim_check.py): with
        // the default window=16 / trip_failures=8, seven straight
        // failures leave the variant healthy and the eighth trips it.
        let c = cfg();
        let mut v = VariantHealth::default();
        for _ in 0..7 {
            assert_eq!(v.observe(false, &c), None);
        }
        assert_eq!(v.observe(false, &c), Some(Transition::Tripped));
        assert_eq!(v.state, Health::Quarantined);
    }

    #[test]
    fn window_slides_old_failures_out() {
        let c = cfg();
        let mut v = VariantHealth::default();
        // 7 failures, then enough successes to push them out of the
        // 16-outcome window, then 7 more: never trips.
        for _ in 0..7 {
            assert_eq!(v.observe(false, &c), None);
        }
        for _ in 0..16 {
            assert_eq!(v.observe(true, &c), None);
        }
        for _ in 0..7 {
            assert_eq!(v.observe(false, &c), None);
        }
        assert_eq!(v.state, Health::Healthy);
    }

    #[test]
    fn cooloff_then_probation_then_promotion() {
        let c = cfg();
        let mut v = VariantHealth::default();
        for _ in 0..8 {
            v.observe(false, &c);
        }
        assert_eq!(v.state, Health::Quarantined);
        // Cooloff: 32 screens all refuse; the 32nd flips to probation.
        for i in 0..c.cooloff {
            let (sel, probe) = v.screen(&c);
            assert!(!sel && !probe, "cooloff screen {i} must refuse");
        }
        assert_eq!(v.state, Health::Probation);
        // Probe cadence: screen 0 of each probe_every-block fires.
        let (sel, probe) = v.screen(&c);
        assert!(sel && probe);
        for _ in 1..c.probe_every {
            let (sel, probe) = v.screen(&c);
            assert!(!sel && !probe);
        }
        let (sel, probe) = v.screen(&c);
        assert!(sel && probe);
        // Two probe successes report Probed; the third promotes.
        assert_eq!(v.observe(true, &c), Some(Transition::Probed));
        assert_eq!(v.observe(true, &c), Some(Transition::Probed));
        assert_eq!(v.observe(true, &c), Some(Transition::Restored));
        assert_eq!(v.state, Health::Healthy);
        assert!(!v.blocked());
    }

    #[test]
    fn failed_probe_re_trips_and_restarts_cooloff() {
        let c = cfg();
        let mut v = VariantHealth::default();
        for _ in 0..8 {
            v.observe(false, &c);
        }
        for _ in 0..c.cooloff {
            v.screen(&c);
        }
        assert_eq!(v.state, Health::Probation);
        assert_eq!(v.observe(true, &c), Some(Transition::Probed));
        assert_eq!(v.observe(false, &c), Some(Transition::Tripped));
        assert_eq!(v.state, Health::Quarantined);
        // The cooloff restarted in full.
        let (sel, _) = v.screen(&c);
        assert!(!sel);
        assert_eq!(v.state, Health::Quarantined);
    }

    #[test]
    fn quarantined_stragglers_are_ignored() {
        let c = cfg();
        let mut v = VariantHealth::default();
        for _ in 0..8 {
            v.observe(false, &c);
        }
        // Outcomes from batches dispatched pre-trip change nothing.
        assert_eq!(v.observe(false, &c), None);
        assert_eq!(v.observe(true, &c), None);
        assert_eq!(v.state, Health::Quarantined);
    }

    #[test]
    fn window_one_trips_on_single_failure() {
        let c = QuarantineConfig { window: 1, trip_failures: 1, ..cfg() };
        let mut v = VariantHealth::default();
        assert_eq!(v.observe(true, &c), None);
        assert_eq!(v.observe(false, &c), Some(Transition::Tripped));
    }

    #[test]
    fn set_fast_path_tracks_nothing_while_healthy() {
        let q = QuarantineSet::new(cfg());
        assert!(!q.is_active());
        for _ in 0..1000 {
            assert_eq!(q.observe(Some(3), true), None);
        }
        assert!(q.screen(3));
        assert!(!q.blocks(3));
        assert_eq!(q.trips(), 0);
    }

    #[test]
    fn set_trip_probe_restore_accounting() {
        let q = QuarantineSet::new(cfg());
        for i in 0..8 {
            let t = q.observe(Some(5), false);
            if i < 7 {
                assert_eq!(t, None);
            } else {
                assert_eq!(t, Some(Transition::Tripped));
            }
        }
        assert!(q.is_active());
        assert!(q.blocks(5));
        assert!(!q.blocks(4));
        assert_eq!(q.active_count(), 1);
        assert_eq!(q.trips(), 1);
        // Drain the cooloff via screening, then probe to promotion.
        for _ in 0..cfg().cooloff {
            assert!(!q.screen(5));
        }
        assert!(q.screen(5)); // first probation screen fires the probe
        for _ in 0..3 {
            q.observe(Some(5), true);
        }
        assert!(!q.blocks(5));
        assert!(!q.is_active());
        assert_eq!(q.restores(), 1);
        assert_eq!(q.probes(), 3);
    }

    #[test]
    fn set_ignores_untracked_configs() {
        let q = QuarantineSet::new(cfg());
        assert_eq!(q.observe(None, false), None);
        assert_eq!(q.observe(Some(NUM_CONFIGS + 7), false), None);
        assert!(!q.is_active());
        assert!(q.screen(NUM_CONFIGS + 7));
    }
}
