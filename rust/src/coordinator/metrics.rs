//! Serving metrics: request counts, latency distribution, batch sizes,
//! per-configuration dispatch counts and the pool's scheduling counters
//! (spilled routes, stolen batches, per-shard occupancy histogram) —
//! plus [`StripedCounter`], the lock-free per-thread-striped cell the
//! coordinator frontend counts with on the submit path, and
//! [`LatencyHistogram`], the atomic log2-bucketed histogram behind the
//! live exposition's approximate per-tenant latency quantiles.

use std::cell::Cell;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::coordinator::admission::REJECT_REASONS;

/// Cells per [`StripedCounter`]; also the lane count reused by the
/// completion pool's free lists.
const COUNTER_STRIPES: usize = 8;

static NEXT_THREAD_STRIPE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_STRIPE: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Stable per-thread stripe index in `[0, modulus)`, assigned round-robin
/// on a thread's first use. Shared by the striped frontend counters and
/// the completion pool's free-list lanes so steady-state traffic from one
/// thread stays on (mostly) thread-private cache lines.
pub(crate) fn thread_stripe(modulus: usize) -> usize {
    THREAD_STRIPE.with(|cell| {
        let mut v = cell.get();
        if v == usize::MAX {
            v = NEXT_THREAD_STRIPE.fetch_add(1, Ordering::Relaxed);
            cell.set(v);
        }
        v % modulus
    })
}

/// One cache line per cell so concurrent writers never false-share.
#[repr(align(64))]
#[derive(Debug, Default)]
struct CounterCell(AtomicUsize);

/// A per-thread-striped counter: increments land on the calling thread's
/// home cell and `sum()` folds the stripes at read time — the same
/// write-local/fold-at-report structure `TelemetrySink` uses for its
/// stripes, shrunk to a single integer. The coordinator frontend counts
/// resolution failures with it instead of taking a `Mutex<Metrics>` on
/// the submit path.
#[derive(Debug)]
pub struct StripedCounter {
    cells: Vec<CounterCell>,
}

impl StripedCounter {
    /// An all-zero counter with one cache-line-padded cell per stripe.
    pub fn new() -> StripedCounter {
        StripedCounter { cells: (0..COUNTER_STRIPES).map(|_| CounterCell::default()).collect() }
    }

    /// Add 1 to the calling thread's home cell.
    pub fn incr(&self) {
        self.add(1);
    }

    /// Add `n` to the calling thread's home cell.
    pub fn add(&self, n: usize) {
        self.cells[thread_stripe(COUNTER_STRIPES)].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Fold every stripe; exact once concurrent writers have quiesced.
    pub fn sum(&self) -> usize {
        self.cells.iter().map(|cell| cell.0.load(Ordering::Relaxed)).sum()
    }
}

impl Default for StripedCounter {
    fn default() -> StripedCounter {
        StripedCounter::new()
    }
}

/// Buckets in a [`LatencyHistogram`]: bucket `i` counts samples in
/// `[2^i, 2^(i+1))` nanoseconds, the last bucket absorbing everything
/// larger (2^39 ns ≈ 9 minutes — far past any serving latency).
pub const LATENCY_BUCKETS: usize = 40;

/// A lock-free log2-bucketed latency histogram for the *live* metrics
/// exposition: shards record completions with one relaxed `fetch_add`,
/// and `metrics_text()` reads approximate quantiles without stopping
/// the pool. The shutdown report keeps its exact sample vectors
/// ([`TenantLane::latencies`]); this type exists so a scrape never has
/// to copy or sort them.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl LatencyHistogram {
    /// Record one end-to-end latency sample, in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        let bucket = (63 - ns.max(1).leading_zeros() as usize).min(LATENCY_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded so far.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate quantile `q` in `[0, 1]`, in nanoseconds: the
    /// geometric midpoint of the bucket holding the q-th sample
    /// (`0.0` before the first sample). Accurate to the bucket's 2x
    /// width — good enough for a live p50/p99 gauge, not for a report.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Geometric midpoint of [2^i, 2^(i+1)): 2^i * sqrt(2).
                return (1u64 << i) as f64 * std::f64::consts::SQRT_2;
            }
        }
        (1u64 << (LATENCY_BUCKETS - 1)) as f64 * std::f64::consts::SQRT_2
    }
}

/// Upper edges of the occupancy-histogram buckets: queue depths
/// `0, 1, 2-3, 4-7, 8-15, 16-31, 32-63, 64+` observed at batch-drain time.
pub const OCCUPANCY_BUCKETS: usize = 8;

/// Serving counters and distributions for one executor shard (or, after
/// [`Metrics::merge`], the whole pool). `requests` counts work actually
/// served by a shard; submit-time refusals live in `failures` (resolution
/// errors, dead pool) and `rejected` (admission), and `shed` counts work
/// admitted but dropped at drain time for blowing its queue budget.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Requests served to completion (success or execution failure).
    pub requests: usize,
    /// Batches drained (each batch serves one artifact group).
    pub batches: usize,
    /// Requests that failed: execution errors on a shard, plus submit-path
    /// failures (resolution errors, dead pool) counted by the frontend.
    pub failures: usize,
    /// Resolutions that fell back to another deployed configuration.
    pub fallback_config: usize,
    /// Resolutions that fell back to the XLA comparator artifact.
    pub fallback_xla: usize,
    /// Requests refused by the admission policy at submit time (they never
    /// took a completion slot or touched a shard).
    pub rejected: usize,
    /// Admitted requests dropped at drain time because they had already
    /// waited past the admission queue budget.
    pub shed: usize,
    /// Peak pool-wide in-flight count observed at admit time. Only
    /// tracked while an inflight-capping admission policy (`BoundedQueue`)
    /// is active — `Unbounded` and `DeadlineShed` never touch the
    /// counter, so it stays 0 for them; merged by `max`.
    pub inflight_peak: usize,
    /// Requests routed off their shape-affinity shard because the preferred
    /// shard's load gauge exceeded the imbalance threshold.
    pub spilled: usize,
    /// Ready batches this shard stole from an overloaded peer's injector.
    pub steals: usize,
    /// Individual requests that arrived via those stolen batches.
    pub stolen_requests: usize,
    /// Selector hot-swaps published (pool-level: background retuner plus
    /// explicit `swap_selector` calls; shards never count these).
    pub selector_swaps: usize,
    /// Full selection+classification reruns on measured data (pool-level).
    pub retunes: usize,
    /// Retune ticks where the drift detector tripped (pool-level).
    pub drift_trips: usize,
    /// Variants tripped into quarantine by windowed failure tracking
    /// (pool-level: folded from the shared quarantine set at shutdown).
    pub quarantine_trips: usize,
    /// Half-open probation probes of quarantined variants (pool-level).
    pub quarantine_probes: usize,
    /// Variants promoted back to healthy after sustained probe success
    /// (pool-level).
    pub quarantine_restores: usize,
    /// Dead shard workers respawned by the supervisor (pool-level).
    pub worker_respawns: usize,
    /// Retries spent from the retry budget by `call_with_retry`
    /// (pool-level).
    pub retries: usize,
    /// Retries refused because the budget was below its shed threshold
    /// (pool-level; retries shed first under load).
    pub retries_denied: usize,
    /// Shard queue depth sampled at every batch drain, bucketed
    /// logarithmically (see [`OCCUPANCY_BUCKETS`]).
    pub occupancy: [usize; OCCUPANCY_BUCKETS],
    /// End-to-end latency samples (seconds).
    latencies: Vec<f64>,
    batch_sizes: Vec<usize>,
    /// Dispatches per configuration index (usize::MAX = XLA backend).
    pub per_config: HashMap<usize, usize>,
    /// Per-tenant serving lanes, keyed by raw tenant id. Only populated
    /// for registered (non-anonymous) tenants — anonymous traffic is
    /// never tracked here, keeping the pre-tenant path untouched.
    /// `BTreeMap` so reports iterate tenants in stable id order.
    pub per_tenant: BTreeMap<u32, TenantLane>,
}

/// Serving counters for one tenant: the per-tenant slice of the pool's
/// request/reject/shed story, plus the tenant's own latency samples so
/// fairness is observable per tenant (p99, in-SLO goodput) instead of
/// blended into the pool distribution.
#[derive(Clone, Debug, Default)]
pub struct TenantLane {
    /// Requests served to completion for this tenant.
    pub requests: usize,
    /// Served requests that finished within the tenant's SLO wall
    /// (every served request when the tenant has no wall configured).
    pub in_slo: usize,
    /// Requests refused at submit time (quota or pool admission).
    pub rejected: usize,
    /// Admitted requests dropped at drain time past the queue budget.
    pub shed: usize,
    /// `shed`, split by the [`RejectReason`] the drain-side shed maps to
    /// (indexed by [`RejectReason::code`]) — `queue-full` under
    /// `BoundedQueue`, `deadline-unmeetable` under `DeadlineShed`.
    ///
    /// [`RejectReason`]: crate::coordinator::admission::RejectReason
    /// [`RejectReason::code`]: crate::coordinator::admission::RejectReason::code
    pub shed_by_reason: [usize; REJECT_REASONS],
    /// End-to-end latency samples (seconds) for this tenant's requests.
    pub latencies: Vec<f64>,
}

impl TenantLane {
    /// Fold another lane (same tenant, different shard) into this one.
    pub fn merge(&mut self, other: TenantLane) {
        self.requests += other.requests;
        self.in_slo += other.in_slo;
        self.rejected += other.rejected;
        self.shed += other.shed;
        for (mine, theirs) in self.shed_by_reason.iter_mut().zip(other.shed_by_reason) {
            *mine += theirs;
        }
        self.latencies.extend(other.latencies);
    }

    /// Distribution stats over this tenant's latency samples, or `None`
    /// before its first served request.
    pub fn latency_stats(&self) -> Option<crate::util::Stats> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(crate::util::Stats::from_secs(&self.latencies))
        }
    }
}

/// Key under which XLA-comparator dispatches are counted in
/// [`Metrics::per_config`] (no Pallas configuration index applies).
pub const XLA_BACKEND_KEY: usize = usize::MAX;

impl Metrics {
    /// Record one drained batch of `size` requests.
    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batch_sizes.push(size);
    }

    /// Sample the shard's queue depth (queued + in-flight requests) into the
    /// occupancy histogram. Called once per drained batch.
    pub fn record_occupancy(&mut self, depth: usize) {
        let bucket = match depth {
            0 => 0,
            1 => 1,
            2..=3 => 2,
            4..=7 => 3,
            8..=15 => 4,
            16..=31 => 5,
            32..=63 => 6,
            _ => 7,
        };
        self.occupancy[bucket] += 1;
    }

    /// Count how the registry resolved a request (direct hit vs fallback).
    /// Called once per served request by the executor shard.
    pub fn record_resolution(&mut self, resolution: &crate::coordinator::registry::Resolution) {
        use crate::coordinator::registry::Resolution;
        match resolution {
            Resolution::Direct => {}
            Resolution::FallbackConfig => self.fallback_config += 1,
            Resolution::FallbackXla => self.fallback_xla += 1,
        }
    }

    /// Fold another shard's metrics into this one (per-shard aggregation at
    /// pool shutdown). Latency and batch-size samples are concatenated, so
    /// distribution stats remain exact across the pool.
    pub fn merge(&mut self, other: Metrics) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.failures += other.failures;
        self.fallback_config += other.fallback_config;
        self.fallback_xla += other.fallback_xla;
        self.rejected += other.rejected;
        self.shed += other.shed;
        self.inflight_peak = self.inflight_peak.max(other.inflight_peak);
        self.spilled += other.spilled;
        self.steals += other.steals;
        self.stolen_requests += other.stolen_requests;
        self.selector_swaps += other.selector_swaps;
        self.retunes += other.retunes;
        self.drift_trips += other.drift_trips;
        self.quarantine_trips += other.quarantine_trips;
        self.quarantine_probes += other.quarantine_probes;
        self.quarantine_restores += other.quarantine_restores;
        self.worker_respawns += other.worker_respawns;
        self.retries += other.retries;
        self.retries_denied += other.retries_denied;
        for (mine, theirs) in self.occupancy.iter_mut().zip(other.occupancy) {
            *mine += theirs;
        }
        self.latencies.extend(other.latencies);
        self.batch_sizes.extend(other.batch_sizes);
        for (config, count) in other.per_config {
            *self.per_config.entry(config).or_default() += count;
        }
        for (tenant, lane) in other.per_tenant {
            self.per_tenant.entry(tenant).or_default().merge(lane);
        }
    }

    /// Record one served request into a tenant's lane: its end-to-end
    /// latency and whether it landed within the tenant's SLO wall.
    /// Called by the serving shard for registered tenants only — the
    /// pool-wide [`Metrics::record_request`] still counts the request.
    pub fn record_tenant(&mut self, tenant: u32, latency_secs: f64, in_slo: bool) {
        let lane = self.per_tenant.entry(tenant).or_default();
        lane.requests += 1;
        if in_slo {
            lane.in_slo += 1;
        }
        lane.latencies.push(latency_secs);
    }

    /// Record one served request's end-to-end latency and the
    /// configuration that served it (`None` = XLA backend).
    pub fn record_request(&mut self, latency_secs: f64, config: Option<usize>) {
        self.requests += 1;
        self.latencies.push(latency_secs);
        *self
            .per_config
            .entry(config.unwrap_or(XLA_BACKEND_KEY))
            .or_default() += 1;
    }

    /// Distribution stats over every recorded end-to-end latency sample,
    /// or `None` before the first served request.
    pub fn latency_stats(&self) -> Option<crate::util::Stats> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(crate::util::Stats::from_secs(&self.latencies))
        }
    }

    /// Mean requests per drained batch (0.0 before the first batch).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    /// Number of distinct kernel configurations actually dispatched.
    pub fn distinct_configs(&self) -> usize {
        self.per_config
            .keys()
            .filter(|&&k| k != XLA_BACKEND_KEY)
            .count()
    }

    /// One-line human-readable rendering of every counter.
    pub fn summary(&self) -> String {
        let lat = self
            .latency_stats()
            .map(|s| {
                format!(
                    "p50={:.1}us p95={:.1}us p99={:.1}us mean={:.1}us",
                    s.p50 * 1e6,
                    s.p95 * 1e6,
                    s.p99 * 1e6,
                    s.mean * 1e6
                )
            })
            .unwrap_or_else(|| "n/a".into());
        format!(
            "requests={} batches={} mean_batch={:.2} failures={} \
             rejected={} shed={} inflight_peak={} \
             fallbacks(config/xla)={}/{} spilled={} steals={}/{} \
             selector_swaps={} retunes={} drift_trips={} \
             quarantine(trips/probes/restores)={}/{}/{} respawns={} \
             retries(spent/denied)={}/{} \
             distinct_configs={} occupancy={:?} latency[{}]",
            self.requests,
            self.batches,
            self.mean_batch_size(),
            self.failures,
            self.rejected,
            self.shed,
            self.inflight_peak,
            self.fallback_config,
            self.fallback_xla,
            self.spilled,
            self.steals,
            self.stolen_requests,
            self.selector_swaps,
            self.retunes,
            self.drift_trips,
            self.quarantine_trips,
            self.quarantine_probes,
            self.quarantine_restores,
            self.worker_respawns,
            self.retries,
            self.retries_denied,
            self.distinct_configs(),
            self.occupancy,
            lat
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::default();
        m.record_batch(3);
        m.record_request(0.001, Some(5));
        m.record_request(0.002, Some(5));
        m.record_request(0.003, None);
        assert_eq!(m.requests, 3);
        assert_eq!(m.per_config[&5], 2);
        assert_eq!(m.per_config[&XLA_BACKEND_KEY], 1);
        assert_eq!(m.distinct_configs(), 1);
        let s = m.latency_stats().unwrap();
        assert_eq!(s.n, 3);
        assert!(m.summary().contains("requests=3"));
    }

    #[test]
    fn empty_latency_none() {
        let m = Metrics::default();
        assert!(m.latency_stats().is_none());
        assert_eq!(m.mean_batch_size(), 0.0);
    }

    #[test]
    fn merge_aggregates_everything() {
        use crate::coordinator::registry::Resolution;
        let mut a = Metrics::default();
        a.record_batch(2);
        a.record_request(0.001, Some(3));
        a.record_request(0.002, None);
        a.record_resolution(&Resolution::FallbackXla);
        a.failures = 1;
        a.rejected = 2;
        a.inflight_peak = 9;

        let mut b = Metrics::default();
        b.record_batch(4);
        b.record_request(0.004, Some(3));
        b.record_resolution(&Resolution::FallbackConfig);
        b.record_resolution(&Resolution::Direct); // no-op
        b.rejected = 3;
        b.shed = 5;
        b.inflight_peak = 4;
        b.spilled = 2;
        b.steals = 1;
        b.stolen_requests = 4;
        b.selector_swaps = 2;
        b.retunes = 3;
        b.drift_trips = 1;
        b.record_occupancy(0);
        b.record_occupancy(5);

        a.merge(b);
        assert_eq!(a.requests, 3);
        assert_eq!(a.batches, 2);
        assert_eq!(a.failures, 1);
        assert_eq!(a.fallback_xla, 1);
        assert_eq!(a.fallback_config, 1);
        assert_eq!(a.rejected, 5);
        assert_eq!(a.shed, 5);
        assert_eq!(a.inflight_peak, 9, "peaks merge by max, not sum");
        assert_eq!(a.spilled, 2);
        assert_eq!(a.steals, 1);
        assert_eq!(a.stolen_requests, 4);
        assert_eq!(a.selector_swaps, 2);
        assert_eq!(a.retunes, 3);
        assert_eq!(a.drift_trips, 1);
        assert!(a.summary().contains("selector_swaps=2"));
        assert!(a.summary().contains("rejected=5 shed=5 inflight_peak=9"));
        assert_eq!(a.occupancy[0], 1);
        assert_eq!(a.occupancy[3], 1);
        assert_eq!(a.per_config[&3], 2);
        assert_eq!(a.per_config[&XLA_BACKEND_KEY], 1);
        assert_eq!(a.latency_stats().unwrap().n, 3);
        assert_eq!(a.mean_batch_size(), 3.0);
    }

    #[test]
    fn tenant_lanes_record_and_merge_per_tenant() {
        let mut a = Metrics::default();
        a.record_tenant(1, 0.001, true);
        a.record_tenant(1, 0.009, false);
        a.record_tenant(2, 0.002, true);

        let mut b = Metrics::default();
        b.record_tenant(1, 0.003, true);
        b.per_tenant.entry(3).or_default().rejected = 4;
        b.per_tenant.entry(3).or_default().shed = 2;

        a.merge(b);
        let t1 = &a.per_tenant[&1];
        assert_eq!((t1.requests, t1.in_slo), (3, 2));
        assert_eq!(t1.latency_stats().unwrap().n, 3);
        assert_eq!(a.per_tenant[&2].requests, 1);
        let t3 = &a.per_tenant[&3];
        assert_eq!((t3.rejected, t3.shed), (4, 2));
        assert!(t3.latency_stats().is_none());
        // Stable id order for reports.
        let ids: Vec<u32> = a.per_tenant.keys().copied().collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn striped_counter_folds_exactly_across_threads() {
        let counter = std::sync::Arc::new(StripedCounter::new());
        let mut joins = Vec::new();
        for _ in 0..4 {
            let counter = counter.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    counter.incr();
                }
            }));
        }
        for join in joins {
            join.join().unwrap();
        }
        counter.add(5);
        assert_eq!(counter.sum(), 40_005);
    }

    #[test]
    fn tenant_lane_shed_reasons_merge_elementwise() {
        let mut a = TenantLane::default();
        a.shed = 3;
        a.shed_by_reason = [3, 0, 0];
        let mut b = TenantLane::default();
        b.shed = 2;
        b.shed_by_reason = [1, 1, 0];
        a.merge(b);
        assert_eq!(a.shed, 5);
        assert_eq!(a.shed_by_reason, [4, 1, 0]);
    }

    #[test]
    fn latency_histogram_quantiles_track_log_buckets() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_ns(0.5), 0.0, "empty histogram reads 0");
        // 90 samples near 1us, 10 near 1ms: p50 sits in the 1us decade,
        // p99 in the 1ms decade (each within its bucket's 2x width).
        for _ in 0..90 {
            h.record_ns(1_000);
        }
        for _ in 0..10 {
            h.record_ns(1_000_000);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        assert!((512.0..2048.0).contains(&p50), "p50 = {p50}");
        assert!((524_288.0..2_097_152.0).contains(&p99), "p99 = {p99}");
        // Degenerate inputs clamp instead of panicking.
        h.record_ns(0);
        h.record_ns(u64::MAX);
        assert_eq!(h.count(), 102);
    }

    #[test]
    fn occupancy_buckets_are_logarithmic() {
        let mut m = Metrics::default();
        for depth in [0, 1, 2, 3, 4, 7, 8, 16, 32, 64, 1000] {
            m.record_occupancy(depth);
        }
        assert_eq!(m.occupancy, [1, 1, 2, 2, 1, 1, 1, 2]);
    }
}
