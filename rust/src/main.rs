//! `kernelsel` — the command-line front end of the tuned-kernel library.
//!
//! Subcommands cover the whole paper pipeline:
//!   simulate    generate benchmark datasets (devsim) to CSV
//!   select      run a kernel-subset selection and print/emit a deployment
//!   train       train the runtime classifier, emit the selector tree
//!   codegen     emit the nested-if Rust source of a trained selector
//!   eval        evaluate selection + classifier on a train/test split
//!   experiment  regenerate a paper figure/table (or `all`)
//!   serve       run the GEMM serving coordinator demo
//!   infer       run VGG16 inference through the runtime
//!   tpu-est     print TPU-viability estimates

use std::path::PathBuf;

use kernelsel::classify::codegen::{to_rust_source, CompiledTree};
use kernelsel::classify::{ClassifierKind, KernelClassifier, ALL_CLASSIFIERS};
#[cfg(feature = "pjrt")]
use kernelsel::coordinator::VggEngine;
use kernelsel::coordinator::{Coordinator, PoolConfig, SelectorPolicy};
use kernelsel::dataset::{
    benchmark_shapes, config_by_index, config_by_name, GemmShape, Normalization,
};
use kernelsel::devsim::{all_profiles, generate_dataset, profile_by_name};
use kernelsel::engine::EngineKind;
use kernelsel::experiments;
use kernelsel::runtime::Manifest;
#[cfg(feature = "pjrt")]
use kernelsel::runtime::Runtime;
use kernelsel::selection::{achievable_percent, select, Method};
use kernelsel::util::fill_buffer;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let value = if it.peek().map_or(false, |v| !v.starts_with("--")) {
                    it.next().unwrap().clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), value);
            } else {
                positional.push(a.clone());
            }
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

fn device_dataset(args: &Args) -> kernelsel::dataset::PerfDataset {
    let device = args.get("device", "r9-nano");
    if let Some(csv) = args.flags.get("data") {
        kernelsel::dataset::PerfDataset::load(&device, std::path::Path::new(csv))
            .unwrap_or_else(|e| fail(&format!("loading {csv}: {e}")))
    } else {
        let profile = profile_by_name(&device)
            .unwrap_or_else(|| fail(&format!("unknown device {device}")));
        generate_dataset(profile, &benchmark_shapes())
    }
}

fn fail(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(1);
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        print_usage();
        std::process::exit(2);
    };
    match cmd {
        "collect" => cmd_collect(&args),
        "simulate" => cmd_simulate(&args),
        "select" => cmd_select(&args),
        "train" => cmd_train(&args),
        "codegen" => cmd_codegen(&args),
        "eval" => cmd_eval(&args),
        "experiment" => cmd_experiment(&args),
        "serve" => cmd_serve(&args),
        "infer" => cmd_infer(&args),
        "tpu-est" => cmd_tpu_est(),
        "help" | "--help" | "-h" => print_usage(),
        other => {
            eprintln!("unknown command {other:?}");
            print_usage();
            std::process::exit(2);
        }
    }
}

fn print_usage() {
    println!(
        "kernelsel — ML-guided kernel selection (Lawson 2020 reproduction)

USAGE: kernelsel <command> [flags]

  simulate   --device <name|all> [--out results/]          dataset CSVs
  collect    [--out results/measured_cpu.csv]              measure shipped
             artifacts on the local CPU PJRT (real data for tuning)
  select     --device D [--method M --norm N --k K --emit-deploy]
  train      --device D [--k K --classifier C --out tree.txt]
  codegen    --device D [--k K]                            nested-if Rust
  eval       --device D [--k K]                            full pipeline eval
  experiment <fig1..fig7|tab1|tab2|tpu-est|all> [--out results/]
  serve      [--requests N --shards S --policy tuned|single|xla
              --backend sim|pjrt]                          executor-pool demo
  infer      [--network vgg16-tiny --policy tuned|single|xla --iters N]
  tpu-est                                                   TPU estimates

Common flags: --device {}, --artifacts DIR, --seed S, --data CSV",
        all_profiles()
            .iter()
            .map(|p| p.name)
            .collect::<Vec<_>>()
            .join("|")
    );
}

/// Without native PJRT there is no hardware to measure.
#[cfg(not(feature = "pjrt"))]
fn cmd_collect(_args: &Args) {
    fail("`collect` measures real artifacts and requires the `pjrt` feature");
}

/// Measure every shipped (config, shape) GEMM artifact on the local CPU
/// PJRT backend — the paper's data-collection protocol (§3.1: warmup, then
/// batched timed iterations) on real hardware. Unmeasured configs stay 0,
/// which downstream training over the deployed set never reads.
#[cfg(feature = "pjrt")]
fn cmd_collect(args: &Args) {
    use kernelsel::dataset::{PerfDataset, NUM_CONFIGS};
    use kernelsel::linalg::Matrix;
    use std::time::Duration;

    let dir = artifacts_dir(args);
    let runtime = Runtime::new(&dir).unwrap_or_else(|e| fail(&e.to_string()));
    let manifest = Manifest::load(&dir).unwrap_or_else(|e| fail(&e));
    let out = PathBuf::from(args.get("out", "results/measured_cpu.csv"));
    let budget = Duration::from_millis(args.get_usize("budget-ms", 100) as u64);
    // Skip shapes whose single-execution cost would dominate the run: the
    // selector only needs relative data on the serving-bucket shapes.
    let max_gflop = args.get_usize("max-gflop", 2) as f64;

    let shapes: Vec<GemmShape> = manifest
        .matmul_shapes()
        .into_iter()
        .map(|(m, k, n, b)| GemmShape::new(m, k, n, b))
        .filter(|s| s.flops() <= max_gflop * 1e9)
        .collect();
    let mut gflops = Matrix::zeros(shapes.len(), NUM_CONFIGS);
    let mut measured = 0usize;
    for (si, s) in shapes.iter().enumerate() {
        let lhs = fill_buffer(si as u32, s.batch * s.m * s.k);
        let rhs = fill_buffer((si + 77) as u32, s.batch * s.k * s.n);
        for meta in manifest.matmuls_for_shape(s.m, s.k, s.n, s.batch) {
            let Some(cfg) = meta.config_index else {
                continue; // the xla backend has no config column
            };
            let exe = runtime.load(&meta.path).unwrap_or_else(|e| fail(&e.to_string()));
            let stats = kernelsel::util::timing::measure(
                || {
                    runtime
                        .execute_f32(
                            &exe,
                            &[
                                (&lhs, &[s.batch, s.m, s.k]),
                                (&rhs, &[s.batch, s.k, s.n]),
                            ],
                        )
                        .expect("execute");
                },
                1,
                budget,
            );
            gflops[(si, cfg)] = s.flops() / stats.mean / 1e9;
            measured += 1;
        }
        eprintln!(
            "[{}/{}] {}: measured {} configs",
            si + 1,
            shapes.len(),
            s.label(),
            manifest.matmuls_for_shape(s.m, s.k, s.n, s.batch).len()
        );
    }
    let ds = PerfDataset::new("local-cpu", shapes, gflops);
    std::fs::create_dir_all(out.parent().unwrap_or(std::path::Path::new("."))).ok();
    ds.save(&out).unwrap_or_else(|e| fail(&e.to_string()));
    println!(
        "measured {} (config, shape) points over {} shapes -> {}",
        measured,
        ds.n_shapes(),
        out.display()
    );
}

fn cmd_simulate(args: &Args) {
    let device = args.get("device", "all");
    let out = PathBuf::from(args.get("out", "results"));
    std::fs::create_dir_all(&out).unwrap();
    let devices: Vec<String> = if device == "all" {
        all_profiles().iter().map(|p| p.name.to_string()).collect()
    } else {
        vec![device]
    };
    for dev in devices {
        let profile = profile_by_name(&dev).unwrap_or_else(|| fail("unknown device"));
        let ds = generate_dataset(profile, &benchmark_shapes());
        let path = out.join(format!("dataset_{dev}.csv"));
        ds.save(&path).unwrap();
        println!(
            "{dev}: {} shapes x 640 configs -> {}",
            ds.n_shapes(),
            path.display()
        );
    }
}

fn cmd_select(args: &Args) {
    let ds = device_dataset(args);
    let method = Method::by_name(&args.get("method", "PCA+KMeans"))
        .unwrap_or_else(|| fail("unknown method"));
    let norm = Normalization::by_name(&args.get("norm", "standard"))
        .unwrap_or_else(|| fail("unknown normalization"));
    let k = args.get_usize("k", 8);
    let seed = args.get_usize("seed", 7) as u64;
    let split = ds.split(0.8, seed);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);
    let picks = select(method, &train, norm, k, seed);
    let pct = achievable_percent(&test, &picks);
    if args.flags.contains_key("emit-deploy") {
        // JSON consumable by `python -m compile.aot --deploy`.
        let names: Vec<String> = picks
            .iter()
            .map(|&c| format!("\"{}\"", config_by_index(c).name()))
            .collect();
        let single = kernelsel::selection::single_best(&train);
        println!(
            "{{\n  \"deployed\": [{}],\n  \"single_best\": \"{}\"\n}}",
            names.join(", "),
            config_by_index(single).name()
        );
    } else {
        println!(
            "{} selection of {k} kernels on {} ({} norm): {:.2}% of optimal",
            method.name(),
            ds.device,
            norm.name(),
            pct
        );
        for &c in &picks {
            println!("  {}", config_by_index(c).name());
        }
    }
}

fn cmd_train(args: &Args) {
    let ds = device_dataset(args);
    let k = args.get_usize("k", 8);
    let seed = args.get_usize("seed", 7) as u64;
    let kind = ALL_CLASSIFIERS
        .iter()
        .copied()
        .find(|c| c.name().eq_ignore_ascii_case(&args.get("classifier", "DecisionTreeB")))
        .unwrap_or(ClassifierKind::DecisionTreeB);
    let split = ds.split(0.8, seed);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);
    let deployed = select(Method::PcaKMeans, &train, Normalization::Standard, k, seed);
    let clf = KernelClassifier::fit(kind, &train, &deployed, seed);
    let pct = kernelsel::selection::achieved_percent(&test, &clf.choices(&test));
    println!(
        "{} over {k} PCA+KMeans kernels on {}: {:.2}% of optimal \
         (oracle {:.2}%)",
        kind.name(),
        ds.device,
        pct,
        achievable_percent(&test, &deployed)
    );
    if let Some(tree) = CompiledTree::compile(&clf) {
        let out = args.get("out", "");
        if !out.is_empty() {
            std::fs::write(&out, tree.serialize()).unwrap();
            println!("selector tree -> {out}");
        }
    }
}

fn cmd_codegen(args: &Args) {
    let ds = device_dataset(args);
    let k = args.get_usize("k", 8);
    let seed = args.get_usize("seed", 7) as u64;
    let (_, tree) = kernelsel::coordinator::tune_selector(
        &ds,
        k,
        Normalization::Standard,
        seed,
    );
    println!("{}", to_rust_source(&tree, "select_kernel"));
}

fn cmd_eval(args: &Args) {
    let ds = device_dataset(args);
    let k = args.get_usize("k", 8);
    let seed = args.get_usize("seed", 7) as u64;
    let split = ds.split(0.8, seed);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);
    println!("device={} shapes={} k={k}", ds.device, ds.n_shapes());
    for method in kernelsel::selection::ALL_METHODS {
        let picks = select(method, &train, Normalization::Standard, k, seed);
        println!(
            "  {:12} oracle {:.2}%",
            method.name(),
            achievable_percent(&test, &picks)
        );
    }
    let deployed = select(Method::PcaKMeans, &train, Normalization::Standard, k, seed);
    for kind in ALL_CLASSIFIERS {
        let pct =
            kernelsel::classify::classifier_percent(kind, &train, &test, &deployed, seed);
        println!("  {:16} {:.2}%", kind.name(), pct);
    }
}

fn cmd_experiment(args: &Args) {
    let id = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let seed = args.get_usize("seed", 7) as u64;
    let ctx = experiments::Context::new(seed);
    let out = args.flags.get("out").map(PathBuf::from);
    if let Err(e) =
        experiments::run_and_save(&id, &ctx, &artifacts_dir(args), out.as_deref())
    {
        fail(&e);
    }
}

fn cmd_serve(args: &Args) {
    let n = args.get_usize("requests", 64);
    let shards = args.get_usize("shards", 2);
    let dir = artifacts_dir(args);
    let policy = policy_from_flag(args, &dir);
    let engine = EngineKind::by_name(&args.get("backend", "sim"))
        .unwrap_or_else(|| fail("unknown backend (sim, or pjrt with the feature)"));
    println!(
        "starting coordinator ({} shard(s), policy={}, backend={}) ...",
        shards,
        policy.name(),
        engine.name()
    );
    let coord = Coordinator::start_pool(
        dir,
        policy,
        PoolConfig { shards, engine, ..PoolConfig::default() },
    )
    .unwrap_or_else(|e| fail(&e));
    let shapes = [
        GemmShape::new(128, 128, 128, 1),
        GemmShape::new(512, 784, 512, 1),
        GemmShape::new(64, 2304, 128, 1),
    ];
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for i in 0..n {
        let s = shapes[i % shapes.len()];
        let lhs = fill_buffer(i as u32, s.batch * s.m * s.k);
        let rhs = fill_buffer((i + 1000) as u32, s.batch * s.k * s.n);
        pending.push(coord.submit(s, lhs, rhs));
    }
    let mut ok = 0;
    for rx in pending {
        if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let report = coord.stop_detailed();
    println!(
        "{ok}/{n} ok in {secs:.3}s ({:.1} req/s)\n{}",
        n as f64 / secs,
        report.summary()
    );
}

fn policy_from_flag(args: &Args, dir: &std::path::Path) -> SelectorPolicy {
    // Missing artifacts fall back to the synthetic deployment, which is
    // what the SimBackend serves.
    let manifest = Manifest::load_or_synthetic(dir);
    match args.get("policy", "tuned").as_str() {
        "xla" => SelectorPolicy::Xla,
        "single" => SelectorPolicy::Single(
            config_by_name(&manifest.single_best).unwrap().index(),
        ),
        _ => {
            // Tune a tree over the shipped deployment. Prefer *measured*
            // local-CPU data (`kernelsel collect`) when available; fall
            // back to the simulated CPU dataset.
            let measured = PathBuf::from(
                args.get("measured-data", "results/measured_cpu.csv"),
            );
            let ds = if measured.exists() {
                eprintln!("tuning on measured data: {}", measured.display());
                kernelsel::dataset::PerfDataset::load("local-cpu", &measured)
                    .unwrap_or_else(|e| fail(&e))
            } else {
                generate_dataset(
                    profile_by_name("i7-6700k").unwrap(),
                    &benchmark_shapes(),
                )
            };
            let deployed: Vec<usize> = manifest
                .deployed
                .iter()
                .map(|n| config_by_name(n).unwrap().index())
                .collect();
            let clf = KernelClassifier::fit(
                ClassifierKind::DecisionTreeB,
                &ds,
                &deployed,
                args.get_usize("seed", 7) as u64,
            );
            SelectorPolicy::Tree(CompiledTree::compile(&clf).unwrap())
        }
    }
}

/// VGG inference chains device-resident PJRT buffers; no sim equivalent.
#[cfg(not(feature = "pjrt"))]
fn cmd_infer(_args: &Args) {
    fail("`infer` runs network layers on PJRT and requires the `pjrt` feature");
}

#[cfg(feature = "pjrt")]
fn cmd_infer(args: &Args) {
    let dir = artifacts_dir(args);
    let network = args.get("network", "vgg16-tiny");
    let iters = args.get_usize("iters", 5);
    let policy = policy_from_flag(args, &dir);
    let runtime = Runtime::new(&dir).unwrap_or_else(|e| fail(&e.to_string()));
    let manifest = Manifest::load(&dir).unwrap_or_else(|e| fail(&e));
    let engine = VggEngine::load(&runtime, &manifest, &network, &policy)
        .unwrap_or_else(|e| fail(&e.to_string()));
    let in_shape = engine.input_shape().to_vec();
    let image = fill_buffer(99, in_shape.iter().product());
    println!(
        "{network} via {} ({} layers, {} distinct kernel configs)",
        engine.backend(),
        engine.n_layers(),
        engine.distinct_configs()
    );
    let (logits, _) = engine.infer(&image).unwrap_or_else(|e| fail(&e.to_string()));
    let mut times = Vec::new();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        engine.infer(&image).unwrap_or_else(|e| fail(&e.to_string()));
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    println!(
        "inference mean {mean:.2} ms over {iters} iters; class={argmax} \
         logit={:.4}",
        logits[argmax]
    );
}

fn cmd_tpu_est() {
    for t in kernelsel::experiments::tpu_est::tpu_estimates() {
        println!("{}", t.render());
    }
}
