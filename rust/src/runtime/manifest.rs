//! The artifact manifest: metadata for every AOT-lowered HLO executable
//! emitted by `python/compile/aot.py` (shapes, kernel configs, flops).

use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};

/// What one AOT artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// A standalone GEMM (the benchmark/serving unit of the paper).
    Matmul,
    /// One convolution layer of a lowered network (im2col + GEMM, with
    /// optional fused pooling/ReLU).
    ConvLayer,
    /// One fully-connected layer of a lowered network.
    FcLayer,
}

/// Metadata for one AOT-lowered executable in the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// HLO-text file path relative to the manifest directory.
    pub path: String,
    /// What the artifact computes.
    pub kind: ArtifactKind,
    /// `Some(config_index)` for Pallas-kernel artifacts; `None` for the
    /// XLA-dot comparator backend.
    pub config_index: Option<usize>,
    /// Kernel configuration name matching `config_index` (`None` for the
    /// XLA comparator).
    pub config_name: Option<String>,
    /// GEMM rows of the (possibly im2col-lowered) multiply.
    pub m: usize,
    /// GEMM reduction depth.
    pub k: usize,
    /// GEMM columns.
    pub n: usize,
    /// Batch dimension (1 for unbatched).
    pub b: usize,
    /// Floating-point operations per execution (`2*b*m*k*n` for GEMM).
    pub flops: f64,
    /// Owning network name for layer artifacts (`None` for standalone).
    pub network: Option<String>,
    /// Layer label within the network (e.g. `conv1_1`).
    pub layer: Option<String>,
    /// Position within the network's layer sequence.
    pub layer_index: Option<usize>,
    /// Layer fuses a trailing 2x2 max-pool.
    pub pool: bool,
    /// Layer fuses a trailing ReLU.
    pub relu: bool,
    /// Input tensor shapes in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output tensor shape.
    pub output: Vec<usize>,
}

/// The AOT deployment: every shipped artifact plus the tuning pipeline's
/// chosen kernel subset, as emitted by `python/compile/aot.py`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the artifact paths are relative to.
    pub dir: PathBuf,
    /// Names of the deployed kernel-configuration subset (paper §4).
    pub deployed: Vec<String>,
    /// The single globally-best configuration (the paper's one-kernel
    /// baseline deployment).
    pub single_best: String,
    /// Every shipped artifact.
    pub artifacts: Vec<ArtifactMeta>,
    /// Hot-path index: (config, m, k, n, b) -> artifact position. Built at
    /// load so per-request resolution is O(1) instead of a linear scan.
    matmul_index:
        std::collections::HashMap<(Option<usize>, usize, usize, usize, usize), usize>,
}

fn dims(j: &Json) -> Vec<usize> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
        .unwrap_or_default()
}

impl Manifest {
    /// Parse `manifest.json` under `dir` and build the hot-path matmul
    /// index. Errors carry enough context to diagnose a malformed file.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading manifest: {e}"))?;
        let root = parse(&text)?;
        let meta = root.get("meta").ok_or("manifest missing meta")?;
        let deployed = meta
            .get("deployed")
            .and_then(|d| d.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        let single_best = meta
            .get("single_best")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string();
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or("manifest missing artifacts")?
        {
            let kind = match a.get("kind").and_then(|v| v.as_str()) {
                Some("matmul") => ArtifactKind::Matmul,
                Some("conv_layer") => ArtifactKind::ConvLayer,
                Some("fc_layer") => ArtifactKind::FcLayer,
                other => return Err(format!("unknown artifact kind {other:?}")),
            };
            artifacts.push(ArtifactMeta {
                path: a
                    .get("path")
                    .and_then(|v| v.as_str())
                    .ok_or("artifact missing path")?
                    .to_string(),
                kind,
                config_index: a.get("config_index").and_then(|v| v.as_usize()),
                config_name: a
                    .get("config")
                    .and_then(|v| v.as_str())
                    .map(String::from),
                m: a.get("m").and_then(|v| v.as_usize()).unwrap_or(0),
                k: a.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                n: a.get("n").and_then(|v| v.as_usize()).unwrap_or(0),
                b: a.get("b").and_then(|v| v.as_usize()).unwrap_or(1),
                flops: a.get("flops").and_then(|v| v.as_f64()).unwrap_or(0.0),
                network: a.get("network").and_then(|v| v.as_str()).map(String::from),
                layer: a.get("layer").and_then(|v| v.as_str()).map(String::from),
                layer_index: a.get("layer_index").and_then(|v| v.as_usize()),
                pool: a.get("pool").and_then(|v| v.as_bool()).unwrap_or(false),
                relu: a.get("relu").and_then(|v| v.as_bool()).unwrap_or(true),
                inputs: a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .map(|arr| arr.iter().map(dims).collect())
                    .unwrap_or_default(),
                output: a.get("output").map(dims).unwrap_or_default(),
            });
        }
        Ok(Manifest::from_parts(
            dir.to_path_buf(),
            deployed,
            single_best,
            artifacts,
        ))
    }

    /// Assemble a manifest from in-memory parts, building the hot-path
    /// matmul index. This is how `load` finishes, and how test fixtures and
    /// [`Manifest::synthetic`] construct manifests without a disk file.
    pub fn from_parts(
        dir: PathBuf,
        deployed: Vec<String>,
        single_best: String,
        artifacts: Vec<ArtifactMeta>,
    ) -> Manifest {
        let mut matmul_index = std::collections::HashMap::new();
        for (i, a) in artifacts.iter().enumerate() {
            if a.kind == ArtifactKind::Matmul {
                matmul_index.insert((a.config_index, a.m, a.k, a.n, a.b), i);
            }
        }
        Manifest { dir, deployed, single_best, artifacts, matmul_index }
    }

    /// The deployed configuration set of the synthetic manifest (all legal
    /// points of the paper's 640-config space, spread across tile shapes).
    pub const SYNTHETIC_DEPLOYED: [&str; 8] = [
        "r8a4c4_wg16x16",
        "r4a4c4_wg8x16",
        "r4a8c4_wg16x16",
        "r2a4c8_wg8x32",
        "r8a2c2_wg8x8",
        "r1a4c2_wg1x128",
        "r2a8c2_wg32x8",
        "r4a2c8_wg16x8",
    ];

    /// The serving shape buckets of the synthetic manifest.
    pub fn synthetic_shapes() -> Vec<(usize, usize, usize, usize)> {
        vec![
            (32, 32, 32, 1),
            (32, 32, 32, 4),
            (64, 64, 64, 1),
            (64, 64, 64, 4),
            (128, 128, 128, 1),
            (256, 256, 256, 1),
            (512, 784, 512, 1),
            (512, 784, 512, 16),
            (64, 2304, 128, 1),
            (1024, 27, 64, 1),
            (256, 576, 128, 1),
            (196, 4608, 512, 1),
            (32, 12321, 27, 1),
            (1, 4096, 1000, 1),
        ]
    }

    /// An in-memory manifest for backends that execute no on-disk binaries
    /// (the devsim-driven `engine::SimBackend`): every serving bucket is
    /// "shipped" for the 8-kernel synthetic deployment plus the XLA-dot
    /// comparator, with artifact paths that are never opened.
    pub fn synthetic() -> Manifest {
        let deployed: Vec<String> =
            Self::SYNTHETIC_DEPLOYED.iter().map(|s| s.to_string()).collect();
        let configs: Vec<(Option<usize>, String)> = std::iter::once((None, "xla".to_string()))
            .chain(deployed.iter().map(|name| {
                let idx = crate::dataset::config_by_name(name)
                    .expect("synthetic deployed config is legal")
                    .index();
                (Some(idx), name.clone())
            }))
            .collect();
        let mut artifacts = Vec::new();
        for (m, k, n, b) in Self::synthetic_shapes() {
            for (config_index, name) in &configs {
                artifacts.push(ArtifactMeta {
                    path: format!("sim/{name}/m{m}k{k}n{n}b{b}.hlo.txt"),
                    kind: ArtifactKind::Matmul,
                    config_index: *config_index,
                    config_name: config_index.map(|_| name.clone()),
                    m,
                    k,
                    n,
                    b,
                    flops: 2.0 * (b * m * k * n) as f64,
                    network: None,
                    layer: None,
                    layer_index: None,
                    pool: false,
                    relu: false,
                    inputs: vec![vec![b, m, k], vec![b, k, n]],
                    output: vec![b, m, n],
                });
            }
        }
        Manifest::from_parts(
            PathBuf::from("<synthetic>"),
            deployed,
            "r8a4c4_wg16x16".to_string(),
            artifacts,
        )
    }

    /// The serving shape buckets of the CPU synthetic manifest: bounded,
    /// CPU-scale GEMMs spanning the small/skinny/large regimes the native
    /// backend's tilings target (no devsim-scale 512x784x512 monsters —
    /// these actually execute on the host per request).
    pub fn synthetic_cpu_shapes() -> Vec<(usize, usize, usize, usize)> {
        vec![
            (16, 16, 16, 1),
            (32, 32, 32, 1),
            (32, 32, 32, 4),
            (48, 48, 48, 1),
            (64, 64, 64, 1),
            (16, 2048, 16, 1),
            (32, 1024, 24, 1),
            (8, 4096, 32, 1),
            (96, 96, 96, 1),
            (128, 128, 128, 1),
            (192, 192, 192, 1),
        ]
    }

    /// An in-memory manifest for the native CPU backend: every CPU bucket
    /// ships all `engine::cpu` GEMM variants (their variant indices are
    /// the `config_index` values) plus the reference-GEMM comparator
    /// (`config_index = None`), with artifact paths that are never opened.
    pub fn synthetic_cpu() -> Manifest {
        let variants = crate::engine::cpu::cpu_variants();
        let deployed: Vec<String> = variants.iter().map(|v| v.name()).collect();
        let configs: Vec<(Option<usize>, String)> = std::iter::once((None, "ref".to_string()))
            .chain(variants.iter().map(|v| (Some(v.index), v.name())))
            .collect();
        let mut artifacts = Vec::new();
        for (m, k, n, b) in Self::synthetic_cpu_shapes() {
            for (config_index, name) in &configs {
                artifacts.push(ArtifactMeta {
                    path: format!("cpu/{name}/m{m}k{k}n{n}b{b}.kernel"),
                    kind: ArtifactKind::Matmul,
                    config_index: *config_index,
                    config_name: config_index.map(|_| name.clone()),
                    m,
                    k,
                    n,
                    b,
                    flops: 2.0 * (b * m * k * n) as f64,
                    network: None,
                    layer: None,
                    layer_index: None,
                    pool: false,
                    relu: false,
                    inputs: vec![vec![b, m, k], vec![b, k, n]],
                    output: vec![b, m, n],
                });
            }
        }
        let single_best = "cpu_large_pb_vec_tp".to_string();
        debug_assert!(deployed.contains(&single_best));
        Manifest::from_parts(PathBuf::from("<synthetic-cpu>"), deployed, single_best, artifacts)
    }

    /// Load the on-disk manifest when one exists, otherwise fall back to
    /// the synthetic deployment (the no-artifacts serving path).
    pub fn load_or_synthetic(dir: &Path) -> Manifest {
        match Manifest::load(dir) {
            Ok(m) => m,
            Err(_) => Manifest::synthetic(),
        }
    }

    /// Find a standalone GEMM artifact for (config, shape). `config=None`
    /// looks for the XLA comparator backend. O(1) via the load-time index.
    pub fn find_matmul(
        &self,
        config_index: Option<usize>,
        m: usize,
        k: usize,
        n: usize,
        b: usize,
    ) -> Option<&ArtifactMeta> {
        self.matmul_index
            .get(&(config_index, m, k, n, b))
            .map(|&i| &self.artifacts[i])
    }

    /// All GEMM artifacts for a shape, any backend.
    pub fn matmuls_for_shape(&self, m: usize, k: usize, n: usize, b: usize) -> Vec<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Matmul && a.m == m && a.k == k && a.n == n && a.b == b
            })
            .collect()
    }

    /// The layer artifacts of a network for one backend choice, ordered by
    /// layer index. `config_for_layer(layer_index, meta) -> Option<usize>`
    /// decides the per-layer kernel (None = XLA backend).
    pub fn network_layers(
        &self,
        network: &str,
        mut config_for_layer: impl FnMut(usize, &ArtifactMeta) -> Option<usize>,
    ) -> Result<Vec<&ArtifactMeta>, String> {
        let n_layers = self
            .artifacts
            .iter()
            .filter(|a| a.network.as_deref() == Some(network))
            .filter_map(|a| a.layer_index)
            .max()
            .map(|m| m + 1)
            .ok_or_else(|| format!("no layers for network {network}"))?;
        let mut out = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            // Use any artifact of the layer to query its metadata.
            let probe = self
                .artifacts
                .iter()
                .find(|a| {
                    a.network.as_deref() == Some(network) && a.layer_index == Some(li)
                })
                .ok_or_else(|| format!("{network}: missing layer {li}"))?;
            let want = config_for_layer(li, probe);
            let found = self
                .artifacts
                .iter()
                .find(|a| {
                    a.network.as_deref() == Some(network)
                        && a.layer_index == Some(li)
                        && a.config_index == want
                })
                .ok_or_else(|| {
                    format!("{network} layer {li}: no artifact for config {want:?}")
                })?;
            out.push(found);
        }
        Ok(out)
    }

    /// Distinct kernel configurations with at least one shipped GEMM
    /// artifact, sorted — the candidate pool online retuning may select
    /// from (a selector cannot deploy a kernel the binary does not carry).
    pub fn shipped_configs(&self) -> Vec<usize> {
        let mut configs: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Matmul)
            .filter_map(|a| a.config_index)
            .collect();
        configs.sort_unstable();
        configs.dedup();
        configs
    }

    /// Distinct GEMM shapes available as standalone artifacts.
    pub fn matmul_shapes(&self) -> Vec<(usize, usize, usize, usize)> {
        let mut shapes: Vec<(usize, usize, usize, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Matmul)
            .map(|a| (a.m, a.k, a.n, a.b))
            .collect();
        shapes.sort_unstable();
        shapes.dedup();
        shapes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// On-disk artifacts come from `make artifacts` (a JAX AOT run) and are
    /// not checked in; disk-backed tests skip when they are absent.
    fn load() -> Option<Manifest> {
        Manifest::load(&manifest_dir()).ok()
    }

    #[test]
    fn synthetic_manifest_serves_every_bucket() {
        let m = Manifest::synthetic();
        assert_eq!(m.deployed.len(), 8);
        assert!(m.artifacts.len() > 100);
        let best = crate::dataset::config_by_name(&m.single_best).unwrap().index();
        for (mm, k, n, b) in Manifest::synthetic_shapes() {
            assert!(m.find_matmul(None, mm, k, n, b).is_some(), "xla {mm}x{k}x{n}");
            assert!(m.find_matmul(Some(best), mm, k, n, b).is_some());
        }
        // Every deployed name is a legal config and has artifacts.
        for name in &m.deployed {
            let idx = crate::dataset::config_by_name(name)
                .unwrap_or_else(|| panic!("illegal synthetic config {name}"))
                .index();
            assert!(m.find_matmul(Some(idx), 128, 128, 128, 1).is_some());
        }
        // Unknown shapes stay unknown.
        assert!(m.find_matmul(None, 17, 19, 23, 1).is_none());
    }

    #[test]
    fn shipped_configs_match_deployment() {
        let m = Manifest::synthetic();
        let pool = m.shipped_configs();
        assert_eq!(pool.len(), 8, "synthetic deployment ships 8 configs");
        let mut expected: Vec<usize> = m
            .deployed
            .iter()
            .map(|n| crate::dataset::config_by_name(n).unwrap().index())
            .collect();
        expected.sort_unstable();
        assert_eq!(pool, expected);
        // Sorted and deduplicated.
        assert!(pool.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn synthetic_cpu_manifest_ships_every_variant_everywhere() {
        let m = Manifest::synthetic_cpu();
        let variants = crate::engine::cpu::cpu_variants();
        assert_eq!(m.deployed.len(), variants.len());
        assert_eq!(m.shipped_configs(), (0..variants.len()).collect::<Vec<_>>());
        assert!(m.deployed.contains(&m.single_best));
        for (mm, k, n, b) in Manifest::synthetic_cpu_shapes() {
            assert!(m.find_matmul(None, mm, k, n, b).is_some(), "ref {mm}x{k}x{n}");
            for v in &variants {
                assert!(
                    m.find_matmul(Some(v.index), mm, k, n, b).is_some(),
                    "{} missing for {mm}x{k}x{n}b{b}",
                    v.name()
                );
            }
        }
    }

    #[test]
    fn load_or_synthetic_falls_back() {
        let m = Manifest::load_or_synthetic(Path::new("/nonexistent/artifacts"));
        assert_eq!(m.deployed.len(), 8);
    }

    #[test]
    fn loads_and_has_deployment() {
        let Some(m) = load() else { return };
        assert_eq!(m.deployed.len(), 8);
        assert!(!m.single_best.is_empty());
        assert!(m.artifacts.len() > 100);
    }

    #[test]
    fn fig1_matmuls_present_for_deployed_configs() {
        let Some(m) = load() else { return };
        let best =
            crate::dataset::config_by_name(&m.single_best).unwrap().index();
        assert!(m.find_matmul(Some(best), 512, 784, 512, 16).is_some());
        assert!(m.find_matmul(None, 512, 784, 512, 16).is_some());
        assert!(m.find_matmul(Some(best), 1, 1, 1, 1).is_none());
    }

    #[test]
    fn vgg16_tiny_layers_complete() {
        let Some(m) = load() else { return };
        let layers = m.network_layers("vgg16-tiny", |_, _| None).unwrap();
        assert_eq!(layers.len(), 16);
        assert_eq!(layers[0].kind, ArtifactKind::ConvLayer);
        assert_eq!(layers[15].kind, ArtifactKind::FcLayer);
        // Files actually exist.
        for l in &layers {
            assert!(m.dir.join(&l.path).exists(), "{}", l.path);
        }
        // Pallas-backed variant also complete for every deployed config.
        for name in m.deployed.clone() {
            let idx = crate::dataset::config_by_name(&name).unwrap().index();
            let layers = m.network_layers("vgg16-tiny", |_, _| Some(idx)).unwrap();
            assert_eq!(layers.len(), 16, "config {name}");
        }
    }

    #[test]
    fn missing_network_errors() {
        let m = Manifest::synthetic();
        assert!(m.network_layers("resnet9000", |_, _| None).is_err());
    }
}
