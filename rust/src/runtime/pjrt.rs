//! PJRT runtime: load AOT HLO-text artifacts, compile them once, execute
//! them from the request path.
//!
//! PJRT handles are `Rc`-based and must stay on one thread; the coordinator
//! gives each executor shard its own backend instance (and therefore its
//! own `Runtime`) and talks to it over channels.
//!
//! Only compiled with the `pjrt` cargo feature. In the default offline
//! build the `xla` dependency is the in-tree stub crate, so everything here
//! type-checks but fails at client creation; swap the path dependency for
//! real bindings to execute natively.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use crate::runtime::manifest::{ArtifactKind, ArtifactMeta};

/// Runtime statistics (compiles, cache hits, executions, wall time).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// Artifacts parsed and compiled for the first time.
    pub compiles: usize,
    /// `load` calls satisfied by the executable cache.
    pub cache_hits: usize,
    /// Executions performed (any entry point).
    pub executions: usize,
    /// Wall-clock seconds spent compiling.
    pub compile_secs: f64,
    /// Wall-clock seconds spent executing.
    pub execute_secs: f64,
}

/// A PJRT client plus a compile-once executable cache, rooted at one
/// artifacts directory. Wrapped by `engine::PjrtBackend`; `Rc`-based, so
/// it stays on the thread that created it.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime rooted at the artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime, String> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| format!("creating PJRT CPU client: {e}"))?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// The PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Lifetime counters of this runtime instance.
    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    /// Load (and cache) the executable for an artifact-relative path.
    pub fn load(&self, rel_path: &str) -> Result<Rc<xla::PjRtLoadedExecutable>, String> {
        if let Some(exe) = self.cache.borrow().get(rel_path) {
            self.stats.borrow_mut().cache_hits += 1;
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let full = self.dir.join(rel_path);
        let full_str = full.to_str().ok_or_else(|| "non-utf8 path".to_string())?;
        let proto = xla::HloModuleProto::from_text_file(full_str)
            .map_err(|e| format!("parsing HLO text {rel_path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| format!("compiling {rel_path}: {e}"))?,
        );
        {
            let mut stats = self.stats.borrow_mut();
            stats.compiles += 1;
            stats.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.cache
            .borrow_mut()
            .insert(rel_path.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload an f32 host buffer to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer, String> {
        let count: usize = dims.iter().product();
        if count != data.len() {
            return Err(format!(
                "upload: {} elements for dims {dims:?}",
                data.len()
            ));
        }
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| format!("uploading host buffer: {e}"))
    }

    /// Execute with device buffers, returning the single (tuple-unwrapped)
    /// output buffer — the zero-copy path used for chained layers.
    pub fn execute_buffers(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer, String> {
        let t0 = Instant::now();
        let mut outs = exe
            .execute_b(args)
            .map_err(|e| format!("executing (buffers): {e}"))?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.execute_secs += t0.elapsed().as_secs_f64();
        }
        outs.pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| "empty execution result".to_string())
    }

    /// Read an output buffer back to the host. Artifacts are lowered with
    /// `return_tuple=False`, so outputs are plain arrays.
    pub fn download(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>, String> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| format!("downloading result: {e}"))?;
        lit.to_vec::<f32>()
            .map_err(|e| format!("converting result to f32: {e}"))
    }

    /// Convenience: upload f32 inputs, execute, download the f32 output.
    pub fn execute_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<f32>, String> {
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|(data, dims)| self.upload(data, dims))
            .collect::<Result<_, String>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out = self.execute_buffers(exe, &refs)?;
        self.download(&out)
    }

    /// Load a GEMM artifact and run it on (lhs, rhs).
    pub fn run_matmul(
        &self,
        meta: &ArtifactMeta,
        lhs: &[f32],
        rhs: &[f32],
    ) -> Result<Vec<f32>, String> {
        if meta.kind != ArtifactKind::Matmul {
            return Err("not a matmul artifact".to_string());
        }
        let exe = self.load(&meta.path)?;
        let (b, m, k, n) = (meta.b, meta.m, meta.k, meta.n);
        self.execute_f32(&exe, &[(lhs, &[b, m, k]), (rhs, &[b, k, n])])
    }
}

/// Tests below require real PJRT bindings plus a `make artifacts` run; they
/// are compiled with `--features pjrt` but skip (like the disk-backed
/// manifest tests) when only the in-tree stub or no artifacts are present,
/// so the CI matrix can run `cargo test --features pjrt` everywhere.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use crate::util::fill_buffer;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn setup() -> Option<(Runtime, Manifest)> {
        let dir = artifacts_dir();
        let rt = Runtime::new(&dir).ok()?;
        let mf = Manifest::load(&dir).ok()?;
        Some((rt, mf))
    }

    #[test]
    fn pallas_artifact_matches_host_reference() {
        let Some((rt, mf)) = setup() else { return };
        let meta = mf
            .find_matmul(None, 128, 128, 128, 1)
            .expect("xla 128^3 artifact")
            .clone();
        let lhs = fill_buffer(11, 128 * 128);
        let rhs = fill_buffer(12, 128 * 128);
        let got = rt.run_matmul(&meta, &lhs, &rhs).unwrap();
        // Shared reference GEMM: the same oracle the SimBackend tests use.
        let want = crate::engine::sim::host_gemm(
            &crate::dataset::GemmShape::new(128, 128, 128, 1),
            &lhs,
            &rhs,
        )
        .unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }

        // And the Pallas single-best config artifact gives the same result.
        let best = crate::dataset::config_by_name(&mf.single_best).unwrap().index();
        let meta_p = mf.find_matmul(Some(best), 128, 128, 128, 1).unwrap().clone();
        let got_p = rt.run_matmul(&meta_p, &lhs, &rhs).unwrap();
        for (g, w) in got_p.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "pallas {g} vs {w}");
        }
    }

    #[test]
    fn executable_cache_hits() {
        let Some((rt, mf)) = setup() else { return };
        let meta = mf.find_matmul(None, 128, 128, 128, 1).unwrap().clone();
        let _ = rt.load(&meta.path).unwrap();
        let _ = rt.load(&meta.path).unwrap();
        let stats = rt.stats();
        assert_eq!(stats.compiles, 1);
        assert!(stats.cache_hits >= 1);
    }

    #[test]
    fn buffer_chaining_executes() {
        // Run one fc layer with device-resident buffers.
        let Some((rt, mf)) = setup() else { return };
        let layers = mf.network_layers("vgg16-tiny", |_, _| None).unwrap();
        let fc = layers[13].clone(); // fc6 of vgg16-tiny
        assert_eq!(fc.kind, ArtifactKind::FcLayer);
        let exe = rt.load(&fc.path).unwrap();
        let x = rt
            .upload(&fill_buffer(1, fc.inputs[0].iter().product()), &fc.inputs[0])
            .unwrap();
        let w = rt
            .upload(&fill_buffer(2, fc.inputs[1].iter().product()), &fc.inputs[1])
            .unwrap();
        let bias = rt
            .upload(&fill_buffer(3, fc.inputs[2].iter().product()), &fc.inputs[2])
            .unwrap();
        let out = rt.execute_buffers(&exe, &[&x, &w, &bias]).unwrap();
        let host = rt.download(&out).unwrap();
        assert_eq!(host.len(), fc.output.iter().product::<usize>());
        assert!(host.iter().all(|v| v.is_finite()));
        // ReLU applied.
        assert!(host.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn upload_validates_shape() {
        let Some((rt, _)) = setup() else { return };
        assert!(rt.upload(&[1.0, 2.0], &[3]).is_err());
    }
}
