//! Artifact runtime layer: the AOT manifest (always available) and the
//! native PJRT executor (behind the `pjrt` cargo feature).
//!
//! Mirrors the architecture constraint of the paper's SYCL libraries: the
//! kernels were compiled ahead of time (Python/JAX never runs here); this
//! layer only *loads* binaries and launches them. HLO text is the
//! interchange format — see `/opt/xla-example/README.md` for why serialized
//! protos from jax >= 0.5 are rejected by xla_extension 0.5.1.
//!
//! Execution itself moved behind the [`crate::engine::Backend`] trait: the
//! coordinator no longer talks to [`Runtime`] directly, it instantiates an
//! engine backend per shard. `Runtime` remains the PJRT implementation
//! detail wrapped by `engine::PjrtBackend`.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};
#[cfg(feature = "pjrt")]
pub use pjrt::{Runtime, RuntimeStats};
