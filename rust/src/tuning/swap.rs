//! Hot-swappable selector handle: generation-counted, torn-read-free
//! deployment of a new [`SelectorPolicy`].
//!
//! The registry resolves every request through one immutable snapshot
//! ([`DeployedSelector`]) taken at the start of the resolution, so a
//! request can never observe half of an old deployed set and half of a new
//! one. Swapping installs a fresh snapshot and bumps a generation counter;
//! the selector cache tags its entries with the generation they were
//! resolved under and treats entries from older generations as misses, so
//! no stale resolution is ever served after a swap — and no traffic pauses,
//! because readers only ever take a brief read lock and an `Arc` clone
//! (the no-external-crates stand-in for `ArcSwap`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::coordinator::cache::ResolutionCache;
use crate::coordinator::registry::KernelRegistry;
use crate::coordinator::selector::SelectorPolicy;

/// One immutable deployment of a selector policy. Everything a resolution
/// needs (the policy and its generation) travels together, so concurrent
/// swaps can never be observed torn.
#[derive(Clone, Debug)]
pub struct DeployedSelector {
    /// The selector policy of this deployment.
    pub policy: SelectorPolicy,
    /// Monotonic deployment counter; 0 is the policy the pool booted with.
    pub generation: u64,
}

/// The swappable slot the registry reads its policy through.
#[derive(Debug)]
pub struct SelectorHandle {
    current: RwLock<Arc<DeployedSelector>>,
    /// Mirror of the current snapshot's generation, readable without the
    /// lock — the selector cache checks this on every hit.
    generation: AtomicU64,
}

impl SelectorHandle {
    /// A handle booted with `policy` at generation 0.
    pub fn new(policy: SelectorPolicy) -> SelectorHandle {
        SelectorHandle {
            current: RwLock::new(Arc::new(DeployedSelector { policy, generation: 0 })),
            generation: AtomicU64::new(0),
        }
    }

    /// The current deployment snapshot (brief read lock + `Arc` clone).
    pub fn load(&self) -> Arc<DeployedSelector> {
        self.current.read().unwrap().clone()
    }

    /// The current deployment generation, lock-free.
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Install a new policy; returns its generation. The atomic mirror is
    /// updated while the write lock is held, so `generation()` never runs
    /// ahead of what `load()` can observe.
    pub fn swap(&self, policy: SelectorPolicy) -> u64 {
        let mut slot = self.current.write().unwrap();
        let generation = slot.generation + 1;
        *slot = Arc::new(DeployedSelector { policy, generation });
        self.generation.store(generation, Ordering::Release);
        generation
    }
}

/// Deploy a new policy pool-wide: swap the registry's selector handle and
/// invalidate every selector-cache entry resolved under an older
/// generation. This is the single swap path shared by the background
/// retuner and explicit [`crate::coordinator::Coordinator::swap_selector`]
/// calls.
pub fn deploy_policy(
    registry: &KernelRegistry,
    cache: &ResolutionCache,
    policy: SelectorPolicy,
) -> u64 {
    let generation = registry.swap_policy(policy);
    cache.invalidate_stale(generation);
    generation
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generations_are_monotonic() {
        let handle = SelectorHandle::new(SelectorPolicy::Xla);
        assert_eq!(handle.generation(), 0);
        assert_eq!(handle.load().generation, 0);
        assert_eq!(handle.swap(SelectorPolicy::Single(3)), 1);
        assert_eq!(handle.swap(SelectorPolicy::Single(4)), 2);
        assert_eq!(handle.generation(), 2);
        let snap = handle.load();
        assert_eq!(snap.generation, 2);
        assert_eq!(snap.policy.deployed(), vec![4]);
    }

    #[test]
    fn snapshot_outlives_swap() {
        let handle = SelectorHandle::new(SelectorPolicy::Single(1));
        let old = handle.load();
        handle.swap(SelectorPolicy::Single(2));
        // The pre-swap snapshot stays internally consistent.
        assert_eq!(old.generation, 0);
        assert_eq!(old.policy.deployed(), vec![1]);
        assert_eq!(handle.load().policy.deployed(), vec![2]);
    }

    #[test]
    fn concurrent_readers_see_whole_snapshots() {
        let handle = std::sync::Arc::new(SelectorHandle::new(SelectorPolicy::Single(7)));
        let mut joins = Vec::new();
        for _ in 0..4 {
            let h = handle.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..2000 {
                    let snap = h.load();
                    let deployed = snap.policy.deployed();
                    // Either deployment, never a mix, and the generation
                    // always matches the policy it travels with.
                    assert!(deployed == vec![7] || deployed == vec![9]);
                    if deployed == vec![7] {
                        assert_eq!(snap.generation % 2, 0);
                    } else {
                        assert_eq!(snap.generation % 2, 1);
                    }
                }
            }));
        }
        for _ in 0..50 {
            handle.swap(SelectorPolicy::Single(9));
            handle.swap(SelectorPolicy::Single(7));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
