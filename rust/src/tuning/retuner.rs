//! The background retuner: re-runs the paper's §4 selection and §5
//! classification on *measured* serving data and hot-swaps the result.
//!
//! The loop is the adaptive-library closing of the paper's "fully
//! automated, relying only on benchmark data" claim: telemetry accumulates
//! a live benchmark dataset on the serving path, the drift detector
//! decides when the deployed selector's assumptions went stale, and a
//! retune re-selects + retrains against the measured data, publishing the
//! new decision tree through the generation-counted selector handle.
//!
//! Measured cells are truth; unmeasured cells of the shipped pool are
//! filled with the devsim prior *calibrated by the drift ratios* (a
//! config's own measured/predicted geomean where it was observed, the
//! global geomean otherwise). Selection is implicitly restricted to the
//! shipped artifact pool — cells outside it stay zero, so no pick can
//! name a kernel the library cannot actually serve (the paper's
//! binary-size constraint survives online retuning).
//!
//! One retune step ([`retune_once`]) is a plain synchronous function so
//! benches and tests can drive deterministic retune cycles; [`Retuner`]
//! wraps it in a timer/drift-triggered background thread for serving.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::classify::ClassifierKind;
use crate::coordinator::cache::{CostModel, ResolutionCache};
use crate::coordinator::registry::KernelRegistry;
use crate::coordinator::selector::{tune_selector_with, SelectorPolicy};
use crate::dataset::{Normalization, PerfDataset, NUM_CONFIGS};
use crate::linalg::Matrix;
use crate::selection::Method;
use crate::tuning::drift::{evaluate_drift, DriftReport};
use crate::tuning::swap::deploy_policy;
use crate::tuning::telemetry::{TelemetrySink, TelemetrySnapshot};

/// Background-retuning policy knobs.
#[derive(Clone, Debug)]
pub struct RetuneConfig {
    /// Timer cadence: retune at least this often once data exists (drift
    /// can trigger a retune earlier).
    pub interval: Duration,
    /// Drift trigger: retune when any config's measured/predicted ratio
    /// deviates beyond this factor (> 1), e.g. 1.25 = 25%.
    pub drift_threshold: f64,
    /// Distinct measured shapes required before the first retune.
    pub min_shapes: usize,
    /// Samples a telemetry cell needs to count as measured.
    pub min_cell_samples: u64,
    /// Deployed-set size to re-select; `None` = the whole shipped pool.
    pub k: Option<usize>,
    /// Feature normalization applied before PCA+K-means re-selection.
    pub norm: Normalization,
    /// Classifier retrained on the live dataset. Must be one of the
    /// decision-tree kinds (only trees compile to a deployable
    /// [`crate::classify::codegen::CompiledTree`]); anything else makes
    /// every retune return [`RetuneOutcome::UnsupportedClassifier`]. The
    /// default is the unbounded tree (paper's DecisionTreeA): the live
    /// dataset is the serving distribution itself, so exact fit is what
    /// we want.
    pub classifier: ClassifierKind,
    /// RNG seed for the re-selection pipeline (deterministic retunes).
    pub seed: u64,
}

impl Default for RetuneConfig {
    fn default() -> RetuneConfig {
        RetuneConfig {
            interval: Duration::from_secs(2),
            drift_threshold: 1.25,
            min_shapes: 2,
            min_cell_samples: 3,
            k: None,
            norm: Normalization::Standard,
            classifier: ClassifierKind::DecisionTreeA,
            seed: 17,
        }
    }
}

/// Counters the retuner accumulates (folded into the pool metrics at
/// shutdown).
#[derive(Clone, Debug, Default)]
pub struct RetunerStats {
    /// Retune attempts (timer ticks plus explicit `retune_now` calls).
    pub ticks: usize,
    /// Ticks where the drift detector tripped.
    pub drift_trips: usize,
    /// Full selection+classification reruns that produced a tree.
    pub retunes: usize,
    /// Reruns whose tree differed and was hot-swapped in.
    pub swaps: usize,
    /// The worst per-config drift deviation seen on the last tick.
    pub last_drift_deviation: f64,
    /// Deviation the most recent retune already incorporated (0 = none
    /// yet). Drift only *re*-triggers when it moves relative to this: a
    /// permanently mispredicting device (cross-device serving) must not
    /// re-trip on every tick after a retune absorbed the measurements.
    pub baseline_deviation: f64,
    /// Generation of the most recent swap (0 = never swapped).
    pub generation: u64,
}

/// What one retune attempt did.
#[derive(Clone, Debug, PartialEq)]
pub enum RetuneOutcome {
    /// `RetuneConfig::classifier` cannot compile to a deployable tree —
    /// a misconfiguration, not a data problem; retuning will never land
    /// until the config changes.
    UnsupportedClassifier,
    /// Not enough measured data yet.
    Insufficient,
    /// Data exists but neither drift nor the timer asked for a retune.
    NotDue,
    /// Re-ran the pipeline; the tree was identical, nothing swapped.
    NoChange,
    /// Published a new selector.
    Swapped {
        /// Generation of the newly deployed selector.
        generation: u64,
        /// Configuration indices the new selector picks from.
        deployed: Vec<usize>,
    },
}

/// Fold a telemetry snapshot into a live [`PerfDataset`]: rows are the
/// measured shapes, measured cells carry measured GFLOP/s, unmeasured
/// cells of the shipped `pool` carry the drift-calibrated prior from the
/// pool's pricing [`CostModel`], and everything outside the pool stays
/// zero (unselectable).
pub fn live_dataset(
    snapshot: &TelemetrySnapshot,
    model: &CostModel,
    drift: &DriftReport,
    pool: &[usize],
    min_cell_samples: u64,
) -> Option<PerfDataset> {
    let shapes = snapshot.measured_shapes(min_cell_samples);
    if shapes.is_empty() || pool.is_empty() {
        return None;
    }
    // Index the snapshot once: the cell lookups below would otherwise
    // linear-scan the whole snapshot per (shape, config) pair.
    let by_key: std::collections::HashMap<(crate::dataset::GemmShape, usize), f64> = snapshot
        .cells
        .iter()
        .filter(|c| c.count >= min_cell_samples)
        .filter_map(|c| c.config.map(|config| ((c.shape, config), c.gflops())))
        .collect();
    let mut gflops = Matrix::zeros(shapes.len(), NUM_CONFIGS);
    for (row, shape) in shapes.iter().enumerate() {
        for &config in pool {
            let value = match by_key.get(&(*shape, config)) {
                Some(&measured_gflops) => measured_gflops,
                None => {
                    let secs =
                        model.predict_secs(shape, Some(config)) * drift.ratio_for(config);
                    shape.flops() / (secs.max(1e-12) * 1e9)
                }
            };
            gflops[(row, config)] = value;
        }
    }
    let device = match model {
        CostModel::Devsim(profile) => format!("live-{}", profile.name),
        CostModel::CpuAnalytic => "live-cpu-native".to_string(),
    };
    Some(PerfDataset::new(&device, shapes, gflops))
}

/// Run one synchronous retune attempt against the pool's live state.
///
/// `timer_due` says whether the caller's retune timer elapsed; drift can
/// force a retune regardless. Explicit callers (benches,
/// `Coordinator::retune_now`) pass `true` to always retune when data
/// exists.
pub fn retune_once(
    cfg: &RetuneConfig,
    timer_due: bool,
    registry: &KernelRegistry,
    cache: &ResolutionCache,
    telemetry: &TelemetrySink,
    stats: &mut RetunerStats,
) -> RetuneOutcome {
    stats.ticks += 1;
    if !matches!(
        cfg.classifier,
        ClassifierKind::DecisionTreeA
            | ClassifierKind::DecisionTreeB
            | ClassifierKind::DecisionTreeC
    ) {
        return RetuneOutcome::UnsupportedClassifier;
    }
    let snapshot = telemetry.snapshot();
    let shapes = snapshot.measured_shapes(cfg.min_cell_samples);
    if shapes.len() < cfg.min_shapes.max(1) {
        return RetuneOutcome::Insufficient;
    }
    let model = cache.cost_model();
    let drift = evaluate_drift(&snapshot, &model, cfg.min_cell_samples);
    stats.last_drift_deviation = drift.max_deviation;
    // Drift triggers *relative to the last retune's* deviation: absolute
    // drift stays high forever on a mispredicted device even after the
    // retune incorporated every measurement — only a change in drift is
    // actionable before the timer; slow creep is the timer's job.
    let tripped = drift.triggered_relative(stats.baseline_deviation, cfg.drift_threshold);
    if tripped {
        stats.drift_trips += 1;
    }
    if !tripped && !timer_due {
        return RetuneOutcome::NotDue;
    }
    // Quarantined variants are masked out of the candidate pool: a
    // tripped kernel cannot be re-deployed until probation restores it.
    let pool = registry.healthy_shipped_configs();
    let Some(dataset) = live_dataset(&snapshot, &model, &drift, &pool, cfg.min_cell_samples)
    else {
        return RetuneOutcome::Insufficient;
    };
    let k = cfg.k.unwrap_or(pool.len()).clamp(1, pool.len());
    let Some((deployed, tree)) =
        tune_selector_with(Method::PcaKMeans, cfg.classifier, &dataset, k, cfg.norm, cfg.seed)
    else {
        // Unreachable with the kinds admitted above, but keep the
        // misconfiguration signal if the compile path ever grows gaps.
        return RetuneOutcome::UnsupportedClassifier;
    };
    stats.retunes += 1;
    stats.baseline_deviation = drift.max_deviation;
    if let SelectorPolicy::Tree(current) = &registry.policy().policy {
        if current.deployed == tree.deployed && current.serialize() == tree.serialize() {
            return RetuneOutcome::NoChange;
        }
    }
    let generation = deploy_policy(registry, cache, SelectorPolicy::Tree(tree));
    stats.swaps += 1;
    stats.generation = generation;
    RetuneOutcome::Swapped { generation, deployed }
}

struct RetunerShared {
    stop: AtomicBool,
    wake: Mutex<()>,
    cv: Condvar,
}

/// Background retune thread: wakes every `interval / 4` to check drift,
/// retunes on drift *change* or when the full interval elapsed since the
/// last retune. The counters live in a caller-provided shared store so
/// explicit `retune_now` calls and the thread accumulate into one place.
pub struct Retuner {
    shared: Arc<RetunerShared>,
    stats: Arc<Mutex<RetunerStats>>,
    handle: Option<JoinHandle<()>>,
}

impl Retuner {
    /// Spawn the background thread; it watches `telemetry` for drift and
    /// deploys re-tuned selectors through `registry`/`cache`, counting
    /// into the shared `stats` store. Stop it with [`Retuner::finish`].
    pub fn start(
        cfg: RetuneConfig,
        registry: Arc<KernelRegistry>,
        cache: Arc<ResolutionCache>,
        telemetry: Arc<TelemetrySink>,
        stats: Arc<Mutex<RetunerStats>>,
    ) -> Retuner {
        let shared = Arc::new(RetunerShared {
            stop: AtomicBool::new(false),
            wake: Mutex::new(()),
            cv: Condvar::new(),
        });
        let thread_shared = shared.clone();
        let thread_stats = stats.clone();
        let handle = std::thread::Builder::new()
            .name("kernelsel-retuner".to_string())
            .spawn(move || {
                let tick = (cfg.interval / 4).max(Duration::from_millis(10));
                let mut last_retune = Instant::now();
                loop {
                    // Check stop *before* waiting, with the wake lock
                    // held: shutdown stores the flag and then takes this
                    // lock to notify, so either we see the flag here or
                    // we are already waiting when the notify lands — the
                    // wakeup can't fall between the check and the wait.
                    // The lock is released before the retune work below.
                    {
                        let guard = thread_shared.wake.lock().unwrap();
                        if thread_shared.stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let _unused =
                            thread_shared.cv.wait_timeout(guard, tick).unwrap();
                    }
                    if thread_shared.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let timer_due = last_retune.elapsed() >= cfg.interval;
                    // The lock is held across the attempt, but the drift
                    // gate makes the common tick cheap (snapshot + ratio
                    // math); the expensive selection+training stage only
                    // runs on a drift change or the timer, so readers of
                    // the shared stats stall at most once per retune.
                    let mut stats = thread_stats.lock().unwrap();
                    let outcome = retune_once(
                        &cfg,
                        timer_due,
                        &registry,
                        &cache,
                        &telemetry,
                        &mut stats,
                    );
                    drop(stats);
                    match outcome {
                        RetuneOutcome::Swapped { .. } | RetuneOutcome::NoChange => {
                            last_retune = Instant::now();
                        }
                        RetuneOutcome::UnsupportedClassifier
                        | RetuneOutcome::Insufficient
                        | RetuneOutcome::NotDue => {}
                    }
                }
            })
            .expect("spawn retuner thread");
        Retuner { shared, stats, handle: Some(handle) }
    }

    /// Point-in-time copy of the retuner's counters.
    pub fn stats(&self) -> RetunerStats {
        self.stats.lock().unwrap().clone()
    }

    fn shutdown(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.shared.stop.store(true, Ordering::Relaxed);
            let _guard = self.shared.wake.lock().unwrap();
            self.shared.cv.notify_all();
            drop(_guard);
            let _ = handle.join();
        }
    }

    /// Stop the thread and return the final counters.
    pub fn finish(mut self) -> RetunerStats {
        self.shutdown();
        self.stats()
    }
}

impl Drop for Retuner {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cache::predict_dispatch_secs;
    use crate::coordinator::registry::Resolution;
    use crate::dataset::GemmShape;
    use crate::devsim::profile_by_name;
    use crate::runtime::Manifest;

    fn fixture() -> (KernelRegistry, ResolutionCache, TelemetrySink) {
        let manifest = Manifest::synthetic();
        let best = crate::dataset::config_by_name(&manifest.single_best).unwrap().index();
        let registry = KernelRegistry::new(manifest, SelectorPolicy::Single(best));
        let cache = ResolutionCache::with_profile(64, "i7-6700k");
        let telemetry = TelemetrySink::new(1, 1.0);
        (registry, cache, telemetry)
    }

    /// Feed nano-measured times for every pool config at a few buckets.
    fn feed_nano(telemetry: &TelemetrySink, registry: &KernelRegistry) {
        let gpu = profile_by_name("r9-nano").unwrap();
        let buckets = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(128, 128, 128, 1),
            GemmShape::new(256, 256, 256, 1),
        ];
        for shape in buckets {
            for config in registry.manifest.shipped_configs() {
                let secs = predict_dispatch_secs(gpu, &shape, Some(config));
                telemetry.record(shape, Some(config), secs);
            }
        }
    }

    #[test]
    fn live_dataset_mixes_measured_and_calibrated_prior() {
        let (registry, _cache, telemetry) = fixture();
        let profile = profile_by_name("i7-6700k").unwrap();
        let model = CostModel::Devsim(profile);
        let pool = registry.manifest.shipped_configs();
        assert_eq!(pool.len(), 8);
        let shape = GemmShape::new(64, 64, 64, 1);
        // Measure exactly one pool config, 2x slower than predicted.
        let predicted = predict_dispatch_secs(profile, &shape, Some(pool[0]));
        telemetry.record(shape, Some(pool[0]), predicted * 2.0);
        let snapshot = telemetry.snapshot();
        let drift = evaluate_drift(&snapshot, &model, 1);
        assert!((drift.global_ratio - 2.0).abs() < 1e-9);
        let ds = live_dataset(&snapshot, &model, &drift, &pool, 1).unwrap();
        assert_eq!(ds.n_shapes(), 1);
        // Measured cell: measured gflops (half the predicted rate).
        let measured_gflops = shape.flops() / (predicted * 2.0 * 1e9);
        assert!((ds.gflops[(0, pool[0])] - measured_gflops).abs() < 1e-9);
        // Unmeasured pool cell: prior corrected by the global 2x ratio.
        let prior = predict_dispatch_secs(profile, &shape, Some(pool[1]));
        let corrected = shape.flops() / (prior * 2.0 * 1e9);
        assert!((ds.gflops[(0, pool[1])] - corrected).abs() < 1e-9);
        // Outside the pool: zero, unselectable.
        let outside = (0..NUM_CONFIGS).find(|c| !pool.contains(c)).unwrap();
        assert_eq!(ds.gflops[(0, outside)], 0.0);
    }

    #[test]
    fn retune_skips_without_data_and_swaps_on_drift() {
        let (registry, cache, telemetry) = fixture();
        let cfg = RetuneConfig { min_cell_samples: 1, ..RetuneConfig::default() };
        let mut stats = RetunerStats::default();
        assert_eq!(
            retune_once(&cfg, true, &registry, &cache, &telemetry, &mut stats),
            RetuneOutcome::Insufficient
        );
        feed_nano(&telemetry, &registry);
        let outcome = retune_once(&cfg, true, &registry, &cache, &telemetry, &mut stats);
        let RetuneOutcome::Swapped { generation, deployed } = outcome else {
            panic!("expected swap, got {outcome:?}");
        };
        assert_eq!(generation, 1);
        assert_eq!(registry.generation(), 1);
        assert_eq!(stats.swaps, 1);
        assert_eq!(stats.drift_trips, 1, "cross-device serving must trip drift");
        // Every pick is a shipped config.
        let pool = registry.manifest.shipped_configs();
        assert!(deployed.iter().all(|c| pool.contains(c)));
        // The swapped policy resolves directly (artifacts exist for it).
        let (_, resolution, generation) =
            registry.resolve(&GemmShape::new(64, 64, 64, 1)).unwrap();
        assert_eq!(resolution, Resolution::Direct);
        assert_eq!(generation, 1);
    }

    #[test]
    fn identical_retraining_is_nochange_not_a_swap() {
        let (registry, cache, telemetry) = fixture();
        let cfg = RetuneConfig { min_cell_samples: 1, ..RetuneConfig::default() };
        let mut stats = RetunerStats::default();
        feed_nano(&telemetry, &registry);
        let first = retune_once(&cfg, true, &registry, &cache, &telemetry, &mut stats);
        assert!(matches!(first, RetuneOutcome::Swapped { .. }));
        // Same telemetry, same config: the rerun reproduces the same tree.
        let second = retune_once(&cfg, true, &registry, &cache, &telemetry, &mut stats);
        assert_eq!(second, RetuneOutcome::NoChange);
        assert_eq!(stats.swaps, 1);
        assert_eq!(stats.retunes, 2);
        assert_eq!(registry.generation(), 1, "no churn on identical trees");
    }

    #[test]
    fn non_tree_classifier_reports_misconfiguration() {
        let (registry, cache, telemetry) = fixture();
        feed_nano(&telemetry, &registry);
        let cfg = RetuneConfig {
            min_cell_samples: 1,
            classifier: ClassifierKind::NearestNeighbor1,
            ..RetuneConfig::default()
        };
        let mut stats = RetunerStats::default();
        let outcome = retune_once(&cfg, true, &registry, &cache, &telemetry, &mut stats);
        assert_eq!(outcome, RetuneOutcome::UnsupportedClassifier);
        assert_eq!(stats.retunes, 0);
        assert_eq!(stats.drift_trips, 0, "misconfig must not masquerade as drift");
        assert_eq!(registry.generation(), 0);
    }

    #[test]
    fn not_due_without_timer_or_drift() {
        let (registry, cache, telemetry) = fixture();
        // Measured == predicted on the pricing profile: zero drift.
        let profile = profile_by_name("i7-6700k").unwrap();
        for shape in [GemmShape::new(32, 32, 32, 1), GemmShape::new(64, 64, 64, 1)] {
            for config in registry.manifest.shipped_configs() {
                telemetry.record(
                    shape,
                    Some(config),
                    predict_dispatch_secs(profile, &shape, Some(config)),
                );
            }
        }
        let cfg = RetuneConfig { min_cell_samples: 1, ..RetuneConfig::default() };
        let mut stats = RetunerStats::default();
        let outcome = retune_once(&cfg, false, &registry, &cache, &telemetry, &mut stats);
        assert_eq!(outcome, RetuneOutcome::NotDue);
        assert_eq!(stats.drift_trips, 0);
        assert_eq!(registry.generation(), 0);
    }

    #[test]
    fn background_thread_swaps_and_stops_cleanly() {
        let (registry, cache, telemetry) = fixture();
        let registry = Arc::new(registry);
        let cache = Arc::new(cache);
        let telemetry = Arc::new(telemetry);
        feed_nano(&telemetry, &registry);
        let cfg = RetuneConfig {
            interval: Duration::from_millis(40),
            min_cell_samples: 1,
            ..RetuneConfig::default()
        };
        let stats_store = Arc::new(Mutex::new(RetunerStats::default()));
        let retuner = Retuner::start(
            cfg,
            registry.clone(),
            cache.clone(),
            telemetry.clone(),
            stats_store,
        );
        let t0 = Instant::now();
        while registry.generation() == 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = retuner.finish();
        assert!(stats.swaps >= 1, "thread never swapped: {stats:?}");
        assert!(registry.generation() >= 1);
    }
}
