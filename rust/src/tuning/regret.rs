//! Online selection-quality estimation: counterfactual regret of the
//! deployed selector against the best *measured* variant per shape.
//!
//! The paper's claim is that the trained classifier picks near-optimal
//! kernels; offline, the benches check it against an oracle. This module
//! makes the same quantity an online, operator-visible signal: for every
//! shape bucket where telemetry has measured at least two variants, the
//! regret ratio is
//!
//! ```text
//! ratio(shape) = ewma_secs(shape, chosen) / min over measured configs c
//!                of ewma_secs(shape, c)
//! ```
//!
//! where `chosen` is what the registry's *current* selector deployment
//! resolves the shape to. The per-domain figure is the geometric mean of
//! the per-shape ratios (1.0 = every selection is the measured best;
//! 1.30 = selections cost 30% over the best measured variant on
//! average), smoothed over successive evaluations by a
//! [`RegretEstimator`] EWMA so the exposition gauge doesn't jitter with
//! every telemetry refresh. Both feed the
//! `kernelsel_selection_regret{domain=..}` family in
//! `Coordinator::metrics_text()`.
//!
//! This is *counterfactual* only over variants traffic has actually
//! measured — a selector stuck on the sole measured variant of a shape
//! scores no regret there (the cell is excluded until a second variant
//! is measured), which is exactly the exploration gap the ROADMAP's
//! autotune item is about.

use crate::coordinator::registry::KernelRegistry;
use crate::dataset::GemmShape;
use crate::tuning::telemetry::TelemetrySnapshot;

/// Per-shape counterfactual regret (see the module docs).
#[derive(Clone, Debug)]
pub struct ShapeRegret {
    /// The shape bucket.
    pub shape: GemmShape,
    /// The config the current deployment resolves the shape to
    /// (`None` = the XLA comparator artifact).
    pub chosen: Option<usize>,
    /// Measured EWMA seconds of the chosen variant.
    pub chosen_secs: f64,
    /// The best measured variant at this shape.
    pub best: Option<usize>,
    /// Measured EWMA seconds of the best variant.
    pub best_secs: f64,
    /// `chosen_secs / best_secs` — 1.0 when the selection is the
    /// measured best (the chosen cell participates in the minimum, so
    /// the ratio is never below 1).
    pub ratio: f64,
}

/// One evaluation of the deployed selector against measured telemetry.
#[derive(Clone, Debug, Default)]
pub struct RegretReport {
    /// Per-shape ratios, in the snapshot's deterministic shape order.
    pub per_shape: Vec<ShapeRegret>,
    /// Geometric mean of the per-shape ratios (1.0 when no shape
    /// qualifies).
    pub geomean: f64,
    /// Shapes with >= 2 sufficiently-sampled measured variants (the
    /// counterfactual's denominator pool).
    pub comparable_shapes: usize,
    /// Comparable shapes skipped because the *chosen* variant has no
    /// measured cell yet (nothing to score the selection against).
    pub unscored_shapes: usize,
}

impl RegretReport {
    /// The single worst-scored shape, if any shape was scored.
    pub fn worst(&self) -> Option<&ShapeRegret> {
        self.per_shape.iter().max_by(|x, y| x.ratio.total_cmp(&y.ratio))
    }
}

/// Score the registry's current selector deployment against a telemetry
/// snapshot. Only cells with at least `min_cell_samples` samples count
/// as measured; shapes with fewer than two such variants are skipped
/// (no counterfactual exists).
pub fn evaluate_regret(
    snapshot: &TelemetrySnapshot,
    registry: &KernelRegistry,
    min_cell_samples: u64,
) -> RegretReport {
    let mut report = RegretReport::default();
    let mut shapes: Vec<GemmShape> = snapshot
        .cells
        .iter()
        .filter(|c| c.count >= min_cell_samples)
        .map(|c| c.shape)
        .collect();
    shapes.sort_by_key(|s| (s.m, s.k, s.n, s.batch));
    shapes.dedup();
    let mut log_sum = 0.0f64;
    for shape in shapes {
        let measured: Vec<(Option<usize>, f64)> = snapshot
            .cells
            .iter()
            .filter(|c| c.shape == shape && c.count >= min_cell_samples)
            .map(|c| (c.config, c.ewma_secs))
            .collect();
        if measured.len() < 2 {
            continue; // one variant measured: no counterfactual
        }
        report.comparable_shapes += 1;
        let chosen = match registry.resolve(&shape) {
            Ok((meta, _, _)) => meta.config_index,
            Err(_) => {
                report.unscored_shapes += 1;
                continue;
            }
        };
        let Some(&(_, chosen_secs)) = measured.iter().find(|(c, _)| *c == chosen) else {
            report.unscored_shapes += 1;
            continue;
        };
        let &(best, best_secs) = measured
            .iter()
            .min_by(|x, y| x.1.total_cmp(&y.1))
            .expect("measured has >= 2 entries");
        let ratio = (chosen_secs / best_secs).max(1.0);
        log_sum += ratio.ln();
        report.per_shape.push(ShapeRegret {
            shape,
            chosen,
            chosen_secs,
            best,
            best_secs,
            ratio,
        });
    }
    report.geomean = if report.per_shape.is_empty() {
        1.0
    } else {
        (log_sum / report.per_shape.len() as f64).exp()
    };
    report
}

/// Smooths successive [`RegretReport`] geomeans into a stable gauge
/// (exponentially weighted, like the telemetry cells themselves).
#[derive(Clone, Debug)]
pub struct RegretEstimator {
    alpha: f64,
    ewma: Option<f64>,
    evaluations: u64,
}

impl Default for RegretEstimator {
    fn default() -> RegretEstimator {
        RegretEstimator::new(0.25)
    }
}

impl RegretEstimator {
    /// An estimator with EWMA smoothing factor `alpha` in (0, 1]
    /// (1.0 = last evaluation wins).
    pub fn new(alpha: f64) -> RegretEstimator {
        RegretEstimator { alpha: alpha.clamp(0.01, 1.0), ewma: None, evaluations: 0 }
    }

    /// Fold one evaluation in and return the smoothed gauge. Reports
    /// that scored no shape leave the gauge unchanged (an empty
    /// telemetry window says nothing about selection quality).
    pub fn observe(&mut self, report: &RegretReport) -> f64 {
        if !report.per_shape.is_empty() {
            self.evaluations += 1;
            self.ewma = Some(match self.ewma {
                None => report.geomean,
                Some(prev) => self.alpha * report.geomean + (1.0 - self.alpha) * prev,
            });
        }
        self.value()
    }

    /// The smoothed regret gauge; 1.0 until the first scored report.
    pub fn value(&self) -> f64 {
        self.ewma.unwrap_or(1.0)
    }

    /// Reports folded in so far (the exposition's confidence hint).
    pub fn evaluations(&self) -> u64 {
        self.evaluations
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selector::SelectorPolicy;
    use crate::runtime::Manifest;
    use crate::tuning::telemetry::TelemetrySink;

    fn sink() -> TelemetrySink {
        TelemetrySink::new(1, 1.0)
    }

    #[test]
    fn empty_snapshot_scores_no_regret() {
        let reg = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let report = evaluate_regret(&TelemetrySnapshot::default(), &reg, 1);
        assert_eq!(report.geomean, 1.0);
        assert_eq!(report.comparable_shapes, 0);
        assert!(report.per_shape.is_empty());
        assert!(report.worst().is_none());
    }

    #[test]
    fn regret_is_the_chosen_over_best_ratio() {
        // The Xla policy resolves every synthetic bucket to the None
        // config. Measure None at 2ms and a concrete config at 1ms: the
        // chosen variant costs 2x the best measured one.
        let reg = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let shape = GemmShape::new(128, 128, 128, 1);
        let telemetry = sink();
        telemetry.record(shape, None, 2e-3);
        telemetry.record(shape, Some(3), 1e-3);
        let report = evaluate_regret(&telemetry.snapshot(), &reg, 1);
        assert_eq!(report.comparable_shapes, 1);
        assert_eq!(report.per_shape.len(), 1);
        let sr = &report.per_shape[0];
        assert_eq!(sr.chosen, None);
        assert_eq!(sr.best, Some(3));
        assert!((sr.ratio - 2.0).abs() < 1e-9, "ratio {}", sr.ratio);
        assert!((report.geomean - 2.0).abs() < 1e-9);
        assert_eq!(report.worst().unwrap().shape, shape);
    }

    #[test]
    fn optimal_selection_scores_one() {
        let reg = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let shape = GemmShape::new(64, 64, 64, 1);
        let telemetry = sink();
        telemetry.record(shape, None, 1e-3); // chosen == measured best
        telemetry.record(shape, Some(5), 4e-3);
        let report = evaluate_regret(&telemetry.snapshot(), &reg, 1);
        assert_eq!(report.per_shape.len(), 1);
        assert_eq!(report.per_shape[0].ratio, 1.0);
        assert_eq!(report.geomean, 1.0);
    }

    #[test]
    fn single_variant_and_undersampled_cells_are_skipped() {
        let reg = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let shape = GemmShape::new(32, 32, 32, 1);
        let telemetry = TelemetrySink::new(1, 1.0);
        telemetry.record(shape, None, 1e-3); // only one variant measured
        let report = evaluate_regret(&telemetry.snapshot(), &reg, 1);
        assert_eq!(report.comparable_shapes, 0);
        assert!(report.per_shape.is_empty());
        // A second variant below the sample floor still doesn't count.
        telemetry.record(shape, Some(2), 5e-4);
        let report = evaluate_regret(&telemetry.snapshot(), &reg, 2);
        assert_eq!(report.comparable_shapes, 0);
    }

    #[test]
    fn unmeasured_chosen_variant_is_reported_unscored() {
        // Two concrete configs measured, but the Xla policy's choice
        // (None) has no cell: comparable, yet unscorable.
        let reg = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let shape = GemmShape::new(64, 64, 64, 1);
        let telemetry = sink();
        telemetry.record(shape, Some(1), 1e-3);
        telemetry.record(shape, Some(2), 2e-3);
        let report = evaluate_regret(&telemetry.snapshot(), &reg, 1);
        assert_eq!(report.comparable_shapes, 1);
        assert_eq!(report.unscored_shapes, 1);
        assert!(report.per_shape.is_empty());
        assert_eq!(report.geomean, 1.0);
    }

    #[test]
    fn geomean_folds_across_shapes() {
        let reg = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let a = GemmShape::new(32, 32, 32, 1);
        let b = GemmShape::new(64, 64, 64, 1);
        let telemetry = sink();
        telemetry.record(a, None, 4e-3); // ratio 4
        telemetry.record(a, Some(1), 1e-3);
        telemetry.record(b, None, 1e-3); // ratio 1
        telemetry.record(b, Some(1), 1e-3);
        let report = evaluate_regret(&telemetry.snapshot(), &reg, 1);
        assert_eq!(report.per_shape.len(), 2);
        assert!((report.geomean - 2.0).abs() < 1e-9, "sqrt(4 * 1) = 2");
    }

    #[test]
    fn estimator_smooths_and_ignores_empty_reports() {
        let mut est = RegretEstimator::new(0.5);
        assert_eq!(est.value(), 1.0);
        let scored = RegretReport {
            per_shape: vec![ShapeRegret {
                shape: GemmShape::new(8, 8, 8, 1),
                chosen: None,
                chosen_secs: 2.0,
                best: Some(0),
                best_secs: 1.0,
                ratio: 2.0,
            }],
            geomean: 2.0,
            comparable_shapes: 1,
            unscored_shapes: 0,
        };
        assert_eq!(est.observe(&scored), 2.0, "first observation seeds the EWMA");
        let empty = RegretReport::default();
        assert_eq!(est.observe(&empty), 2.0, "empty reports leave the gauge alone");
        assert_eq!(est.evaluations(), 1);
        let better = RegretReport { geomean: 1.0, ..scored.clone() };
        assert_eq!(est.observe(&better), 1.5, "0.5 * 1 + 0.5 * 2");
        assert_eq!(est.evaluations(), 2);
    }
}
