//! Drift detection: measured serving cost vs the devsim predictions the
//! deployment was tuned against.
//!
//! For every telemetry cell with enough samples the detector computes the
//! ratio of measured to predicted dispatch time, folds the ratios into a
//! per-configuration geometric mean (and one global geometric mean), and
//! trips when any configuration's ratio deviates from 1.0 by more than a
//! configurable threshold in either direction. A perfectly-predicting
//! model (serving the same device profile the hints are priced against)
//! yields ratios of exactly 1.0 and never trips.
//!
//! The ratios double as calibration: the retuner uses them to correct the
//! devsim prior for cells it has no measurements on, so the live dataset
//! mixes measured truth with drift-corrected estimates instead of raw
//! stale predictions.

use crate::coordinator::cache::CostModel;
use crate::tuning::telemetry::TelemetrySnapshot;

/// Measured/predicted time ratio of one configuration (geometric mean over
/// its measured cells). `ratio > 1` = the device runs it slower than the
/// pricing model predicts.
#[derive(Clone, Debug)]
pub struct ConfigDrift {
    /// The kernel configuration index the ratio describes.
    pub config: usize,
    /// Cells (distinct shapes) the ratio is estimated from.
    pub cells: usize,
    /// Telemetry samples behind those cells.
    pub samples: u64,
    /// Geometric-mean measured/predicted dispatch-time ratio.
    pub ratio: f64,
}

/// Pool-wide drift verdict.
#[derive(Clone, Debug)]
pub struct DriftReport {
    /// Per-configuration drift ratios over the measured cells.
    pub per_config: Vec<ConfigDrift>,
    /// Geometric-mean ratio over every measured cell (any config).
    pub global_ratio: f64,
    /// The largest per-config deviation, as `max(ratio, 1/ratio) >= 1`.
    pub max_deviation: f64,
    /// Measured cells that contributed.
    pub cells: usize,
}

impl Default for DriftReport {
    fn default() -> DriftReport {
        DriftReport { per_config: Vec::new(), global_ratio: 1.0, max_deviation: 1.0, cells: 0 }
    }
}

impl DriftReport {
    /// Whether any configuration drifted beyond `threshold` (> 1), e.g.
    /// 1.25 trips on a >25% gap between measured and predicted cost.
    /// This is the absolute check — equivalent to
    /// [`DriftReport::triggered_relative`] against a pristine baseline.
    pub fn triggered(&self, threshold: f64) -> bool {
        self.triggered_relative(1.0, threshold)
    }

    /// Whether the worst deviation moved by more than `threshold` (> 1)
    /// *relative to* `baseline` — the deviation a previous retune already
    /// incorporated (pass 1.0, or 0.0 meaning "none yet", before the
    /// first retune). This is the retuner's trip predicate: a permanently
    /// mispredicting device trips once, not on every tick after the
    /// retune absorbed the measurements.
    pub fn triggered_relative(&self, baseline: f64, threshold: f64) -> bool {
        if self.cells == 0 {
            return false;
        }
        // Deviations are >= 1 by construction; 0/negative = no baseline.
        let baseline = baseline.max(1.0);
        let current = self.max_deviation.max(1.0);
        (current / baseline).max(baseline / current) > threshold.max(1.0)
    }

    /// Calibration ratio for a configuration: its own geomean ratio when
    /// measured anywhere, the global ratio otherwise.
    pub fn ratio_for(&self, config: usize) -> f64 {
        self.per_config
            .iter()
            .find(|c| c.config == config)
            .map(|c| c.ratio)
            .unwrap_or(self.global_ratio)
    }
}

/// Compare a telemetry snapshot against the predictions of the pool's
/// pricing [`CostModel`] (devsim profile or the CPU analytic prior). Only
/// cells with a concrete configuration and at least `min_cell_samples`
/// samples participate (the comparator backend has no model point, so it
/// is excluded).
pub fn evaluate_drift(
    snapshot: &TelemetrySnapshot,
    model: &CostModel,
    min_cell_samples: u64,
) -> DriftReport {
    struct Acc {
        log_sum: f64,
        cells: usize,
        samples: u64,
    }
    let mut by_config: Vec<(usize, Acc)> = Vec::new();
    let mut global_log_sum = 0.0;
    let mut global_cells = 0usize;
    for cell in &snapshot.cells {
        let Some(config) = cell.config else { continue };
        if cell.count < min_cell_samples {
            continue;
        }
        let predicted = model.predict_secs(&cell.shape, Some(config));
        if predicted <= 0.0 {
            continue;
        }
        let log_ratio = (cell.ewma_secs / predicted).ln();
        global_log_sum += log_ratio;
        global_cells += 1;
        match by_config.iter().position(|(c, _)| *c == config) {
            Some(i) => {
                let acc = &mut by_config[i].1;
                acc.log_sum += log_ratio;
                acc.cells += 1;
                acc.samples += cell.count;
            }
            None => by_config.push((
                config,
                Acc { log_sum: log_ratio, cells: 1, samples: cell.count },
            )),
        }
    }
    if global_cells == 0 {
        return DriftReport::default();
    }
    let mut per_config: Vec<ConfigDrift> = by_config
        .into_iter()
        .map(|(config, acc)| ConfigDrift {
            config,
            cells: acc.cells,
            samples: acc.samples,
            ratio: (acc.log_sum / acc.cells as f64).exp(),
        })
        .collect();
    per_config.sort_by_key(|c| c.config);
    let max_deviation = per_config
        .iter()
        .map(|c| c.ratio.max(1.0 / c.ratio))
        .fold(1.0f64, f64::max);
    DriftReport {
        per_config,
        global_ratio: (global_log_sum / global_cells as f64).exp(),
        max_deviation,
        cells: global_cells,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cache::predict_dispatch_secs;
    use crate::dataset::GemmShape;
    use crate::devsim::profile_by_name;
    use crate::tuning::telemetry::TelemetrySink;

    fn shapes() -> Vec<GemmShape> {
        vec![
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(128, 128, 128, 1),
        ]
    }

    #[test]
    fn no_drift_when_predictions_are_exact() {
        // Feed the detector the pricing model's own predictions: every
        // ratio must be exactly 1 and nothing may trip.
        let profile = profile_by_name("i7-6700k").unwrap();
        let sink = TelemetrySink::new(1, 1.0);
        for s in shapes() {
            for cfg in [100usize, 200, 300] {
                let t = predict_dispatch_secs(profile, &s, Some(cfg));
                sink.record(s, Some(cfg), t);
            }
        }
        let report = evaluate_drift(&sink.snapshot(), &CostModel::Devsim(profile), 1);
        assert_eq!(report.cells, 9);
        assert!((report.global_ratio - 1.0).abs() < 1e-9, "{}", report.global_ratio);
        assert!((report.max_deviation - 1.0).abs() < 1e-9);
        assert!(!report.triggered(1.05));
    }

    #[test]
    fn cpu_analytic_model_is_self_consistent() {
        // The native backend's drift loop prices against the CPU analytic
        // prior; feeding it its own predictions must never trip.
        let model = CostModel::CpuAnalytic;
        let sink = TelemetrySink::new(1, 1.0);
        for s in shapes() {
            for cfg in [0usize, 7, 23] {
                sink.record(s, Some(cfg), model.predict_secs(&s, Some(cfg)));
            }
        }
        let report = evaluate_drift(&sink.snapshot(), &model, 1);
        assert_eq!(report.cells, 9);
        assert!(!report.triggered(1.05), "max deviation {}", report.max_deviation);
    }

    #[test]
    fn cross_device_serving_trips_the_detector() {
        // Priced on the CPU, measured on the GPU simulator: ratios diverge
        // far beyond any reasonable threshold.
        let cpu = profile_by_name("i7-6700k").unwrap();
        let gpu = profile_by_name("r9-nano").unwrap();
        let sink = TelemetrySink::new(1, 1.0);
        for s in shapes() {
            for cfg in [100usize, 300] {
                sink.record(s, Some(cfg), predict_dispatch_secs(gpu, &s, Some(cfg)));
            }
        }
        let report = evaluate_drift(&sink.snapshot(), &CostModel::Devsim(cpu), 1);
        assert!(report.triggered(1.25), "max deviation {}", report.max_deviation);
        assert_eq!(report.per_config.len(), 2);
        // Calibration: measured configs use their own ratio, unmeasured
        // configs fall back to the global geomean.
        let own = report.ratio_for(100);
        assert!((own - report.per_config[0].ratio).abs() < 1e-12);
        assert!((report.ratio_for(555) - report.global_ratio).abs() < 1e-12);
    }

    #[test]
    fn undersampled_and_xla_cells_excluded() {
        let profile = profile_by_name("i7-6700k").unwrap();
        let sink = TelemetrySink::new(1, 1.0);
        let s = GemmShape::new(64, 64, 64, 1);
        sink.record(s, Some(5), 1.0); // one sample < min of 2
        sink.record(s, None, 1.0); // XLA comparator: no devsim point
        sink.record(s, None, 1.0);
        let report = evaluate_drift(&sink.snapshot(), &CostModel::Devsim(profile), 2);
        assert_eq!(report.cells, 0);
        assert!(!report.triggered(1.0001));
        assert_eq!(report.global_ratio, 1.0);
    }

    #[test]
    fn relative_trigger_is_quiet_once_baseline_absorbed() {
        let cpu = profile_by_name("i7-6700k").unwrap();
        let gpu = profile_by_name("r9-nano").unwrap();
        let sink = TelemetrySink::new(1, 1.0);
        for s in shapes() {
            sink.record(s, Some(100), predict_dispatch_secs(gpu, &s, Some(100)));
        }
        let report = evaluate_drift(&sink.snapshot(), &CostModel::Devsim(cpu), 1);
        // Fresh deployment (no baseline): the big deviation trips.
        assert!(report.triggered_relative(0.0, 1.25));
        assert!(report.triggered_relative(1.0, 1.25));
        // A retune that already incorporated this deviation: quiet.
        assert!(!report.triggered_relative(report.max_deviation, 1.25));
        // Deviation moving well past the absorbed baseline trips again.
        assert!(report.triggered_relative(report.max_deviation * 2.0, 1.25));
    }
}
