//! Online retuning: measured-cost telemetry, drift detection, background
//! re-selection and hot-swappable selector deployment.
//!
//! The offline pipeline (paper §4 + §5) tunes once against devsim
//! benchmark data and freezes the selector at startup. This subsystem
//! closes the loop on the serving path:
//!
//! ```text
//!   shards measure ──▶ [telemetry]  ──▶ [drift detector] ──trip/timer──▶
//!   [retuner: live PerfDataset ▶ PCA+K-means ▶ decision tree] ──▶
//!   [generation-counted hot swap] ──▶ selector cache invalidation
//! ```
//!
//! * [`telemetry`] — lock-light striped (shape, config) → measured-time
//!   accumulator; also powers the measured cost-hint handoff for the
//!   router's load gauges.
//! * [`drift`] — per-config geometric-mean measured/predicted ratios with
//!   a configurable trip threshold, doubling as prior calibration.
//! * [`explore`] — the exploration half of the loop: seeded,
//!   budget-capped epsilon probes of unmeasured shipped configs and the
//!   first-sight micro-benchmark planner, feeding the same telemetry
//!   sink (and, via its extended snapshot, warm-starting the next
//!   deployment).
//! * [`retuner`] — the background thread plus the synchronous
//!   [`retuner::retune_once`] step it (and benches) drive.
//! * [`swap`] — the generation-counted selector handle and the shared
//!   swap-then-invalidate deployment path.
//! * [`regret`] — the online selection-quality estimator: counterfactual
//!   chosen-vs-best-measured regret per shape, geomean'd per domain and
//!   EWMA-smoothed into the metrics exposition's gauge.

pub mod drift;
pub mod explore;
pub mod regret;
pub mod retuner;
pub mod swap;
pub mod telemetry;

pub use drift::{evaluate_drift, ConfigDrift, DriftReport};
pub use explore::{
    measured_coverage, probe_draw, probe_pick, probe_would_admit, rank_by_prior,
    unmeasured_candidates, ExploreConfig, ExplorePlanner, ExploreStats,
};
pub use regret::{evaluate_regret, RegretEstimator, RegretReport, ShapeRegret};
pub use retuner::{
    live_dataset, retune_once, RetuneConfig, RetuneOutcome, Retuner, RetunerStats,
};
pub use swap::{deploy_policy, DeployedSelector, SelectorHandle};
pub use telemetry::{TelemetryCell, TelemetrySink, TelemetrySnapshot};
