//! Measured-cost telemetry: the serving-path feedback the offline tuning
//! pipeline never had.
//!
//! Every executor shard reports the measured execution time of each request
//! into a lock-light striped accumulator keyed by (shape bucket, kernel
//! configuration). Two consumers read it back:
//!
//! * the submit path, which prefers an EWMA of measured dispatch times over
//!   the devsim estimate once a cell has enough samples (the measured
//!   cost-hint handoff, falling back to devsim while cold), and
//! * the background retuner, which folds a snapshot into a live
//!   [`PerfDataset`] compatible with `selection::select` and
//!   `KernelClassifier::fit` (paper §4 + §5 re-run on measured data).
//!
//! Stripes are independent mutexes selected by shape hash, so concurrent
//! shards rarely contend; a shard touches exactly one stripe per request.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::dataset::GemmShape;
use crate::util::json::Json;

/// Telemetry key: the shape bucket plus the configuration that served it
/// (`None` = the XLA comparator artifact).
pub type TelemetryKey = (GemmShape, Option<usize>);

const STRIPES: usize = 16;

/// Safety valve against unbounded growth: cells per stripe. Real serving
/// traffic is bounded by the manifest (shape buckets x shipped configs,
/// ~100 cells), so the cap only binds on pathological/adversarial shape
/// streams — new keys beyond it are dropped, existing cells keep
/// updating.
const MAX_CELLS_PER_STRIPE: usize = 512;

#[derive(Clone, Copy, Debug, Default)]
struct Cell {
    count: u64,
    sum_secs: f64,
    ewma_secs: f64,
    /// How many of `count` came from exploration probes (epsilon-probe
    /// redirects or first-sight micro-benchmarks) rather than organic
    /// selector traffic. Provenance only — the EWMA treats both equally.
    probed: u64,
}

/// Lock-light accumulator of measured per-(shape, config) execution times.
#[derive(Debug)]
pub struct TelemetrySink {
    stripes: Vec<Mutex<HashMap<TelemetryKey, Cell>>>,
    total: AtomicU64,
    /// Samples a cell needs before its EWMA overrides the devsim hint.
    min_samples: u64,
    /// EWMA smoothing factor in (0, 1]; 1.0 = last sample wins.
    alpha: f64,
}

impl Default for TelemetrySink {
    fn default() -> TelemetrySink {
        TelemetrySink::new(3, 0.25)
    }
}

impl TelemetrySink {
    /// A sink whose cells need `min_samples` samples before their EWMA
    /// (smoothing factor `alpha`) overrides the devsim cost hint.
    pub fn new(min_samples: u64, alpha: f64) -> TelemetrySink {
        TelemetrySink {
            stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect(),
            total: AtomicU64::new(0),
            min_samples: min_samples.max(1),
            alpha: alpha.clamp(0.01, 1.0),
        }
    }

    fn stripe(&self, shape: &GemmShape) -> usize {
        let mut h = DefaultHasher::new();
        shape.hash(&mut h);
        (h.finish() as usize) % self.stripes.len()
    }

    /// Record one measured execution (seconds) for a served request.
    pub fn record(&self, shape: GemmShape, config: Option<usize>, secs: f64) {
        self.record_inner(shape, config, secs, false);
    }

    /// Record one measured execution that came from an exploration probe
    /// (epsilon-probe redirect or first-sight micro-benchmark). Identical
    /// to [`TelemetrySink::record`] except the cell's `probed` provenance
    /// counter is bumped alongside `count`.
    pub fn record_probe(&self, shape: GemmShape, config: Option<usize>, secs: f64) {
        self.record_inner(shape, config, secs, true);
    }

    fn record_inner(&self, shape: GemmShape, config: Option<usize>, secs: f64, probed: bool) {
        if !secs.is_finite() || secs <= 0.0 {
            return;
        }
        let mut stripe = self.stripes[self.stripe(&shape)].lock().unwrap();
        if stripe.len() >= MAX_CELLS_PER_STRIPE && !stripe.contains_key(&(shape, config)) {
            return; // safety cap: drop new keys, keep updating known cells
        }
        let cell = stripe.entry((shape, config)).or_default();
        cell.count += 1;
        cell.sum_secs += secs;
        if probed {
            cell.probed += 1;
        }
        cell.ewma_secs = if cell.count == 1 {
            secs
        } else {
            self.alpha * secs + (1.0 - self.alpha) * cell.ewma_secs
        };
        drop(stripe);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    /// Total samples recorded since construction.
    pub fn total_samples(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// The measured dispatch cost (EWMA seconds) for a cell, once it has
    /// at least `min_samples` samples; `None` while cold.
    pub fn measured_cost_secs(&self, shape: &GemmShape, config: Option<usize>) -> Option<f64> {
        let stripe = self.stripes[self.stripe(shape)].lock().unwrap();
        stripe
            .get(&(*shape, config))
            .filter(|c| c.count >= self.min_samples)
            .map(|c| c.ewma_secs)
    }

    /// Seed the sink from a persisted snapshot — telemetry persistence
    /// across restarts. Each absorbed cell is installed with its saved
    /// count / mean / EWMA, so cost-hint handoff and retune state resume
    /// where the previous process left off. Cells already measured in
    /// *this* process win over the snapshot (live data is fresher), and
    /// the per-stripe safety cap applies as usual.
    pub fn absorb(&self, snapshot: &TelemetrySnapshot) {
        for cell in &snapshot.cells {
            if cell.count == 0
                || !cell.ewma_secs.is_finite()
                || cell.ewma_secs <= 0.0
                || !cell.mean_secs.is_finite()
                || cell.mean_secs <= 0.0
            {
                continue;
            }
            let key = (cell.shape, cell.config);
            let mut stripe = self.stripes[self.stripe(&cell.shape)].lock().unwrap();
            if stripe.contains_key(&key) {
                continue; // live measurements win over persisted state
            }
            if stripe.len() >= MAX_CELLS_PER_STRIPE {
                continue; // safety cap, as in record()
            }
            stripe.insert(
                key,
                Cell {
                    count: cell.count,
                    sum_secs: cell.mean_secs * cell.count as f64,
                    ewma_secs: cell.ewma_secs,
                    probed: cell.probed.min(cell.count),
                },
            );
            drop(stripe);
            self.total.fetch_add(cell.count, Ordering::Relaxed);
        }
    }

    /// Consistent point-in-time copy of every cell, deterministically
    /// ordered (by shape dims, then config). Stripes are locked one at a
    /// time, so a snapshot never blocks the serving path for long.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let mut cells = Vec::new();
        for stripe in &self.stripes {
            let guard = stripe.lock().unwrap();
            for (&(shape, config), cell) in guard.iter() {
                cells.push(TelemetryCell {
                    shape,
                    config,
                    count: cell.count,
                    mean_secs: cell.sum_secs / cell.count as f64,
                    ewma_secs: cell.ewma_secs,
                    probed: cell.probed,
                });
            }
        }
        cells.sort_by_key(|c| {
            (c.shape.m, c.shape.k, c.shape.n, c.shape.batch, c.config.map_or(0, |i| i + 1))
        });
        TelemetrySnapshot { cells }
    }
}

/// One (shape, config) telemetry cell at snapshot time.
#[derive(Clone, Debug)]
pub struct TelemetryCell {
    /// The GEMM shape of the cell.
    pub shape: GemmShape,
    /// The configuration that served it (None = XLA backend).
    pub config: Option<usize>,
    /// Samples recorded for the cell.
    pub count: u64,
    /// Arithmetic-mean measured execution seconds.
    pub mean_secs: f64,
    /// Exponentially-weighted moving average of the measured seconds.
    pub ewma_secs: f64,
    /// Of `count`, how many samples came from exploration probes (PR 10
    /// provenance extension; `0` for snapshots written before it).
    pub probed: u64,
}

impl TelemetryCell {
    /// Measured GFLOP/s of this cell (from the EWMA time).
    pub fn gflops(&self) -> f64 {
        self.shape.flops() / (self.ewma_secs.max(1e-12) * 1e9)
    }
}

/// Point-in-time view of the telemetry sink.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySnapshot {
    /// Every cell, deterministically ordered (shape dims, then config).
    pub cells: Vec<TelemetryCell>,
}

impl TelemetrySnapshot {
    /// Distinct shapes with at least one cell of `min_samples` samples on
    /// a concrete (non-XLA) configuration, in deterministic order.
    pub fn measured_shapes(&self, min_samples: u64) -> Vec<GemmShape> {
        let mut shapes: Vec<GemmShape> = self
            .cells
            .iter()
            .filter(|c| c.config.is_some() && c.count >= min_samples)
            .map(|c| c.shape)
            .collect();
        shapes.sort_by_key(|s| (s.m, s.k, s.n, s.batch));
        shapes.dedup();
        shapes
    }

    /// Look one cell up.
    pub fn cell(&self, shape: &GemmShape, config: Option<usize>) -> Option<&TelemetryCell> {
        self.cells.iter().find(|c| c.shape == *shape && c.config == config)
    }

    /// Parse a `kernelsel-telemetry-v1` document (the inverse of
    /// [`TelemetrySnapshot::to_json`]); the derived `gflops` field is
    /// ignored on input. Feed the result to [`TelemetrySink::absorb`] to
    /// restore retune state across restarts.
    ///
    /// The optional per-cell `probed` field (exploration provenance, added
    /// in PR 10) defaults to `0`, so pre-extension snapshots still load.
    pub fn from_json(doc: &Json) -> Result<TelemetrySnapshot, String> {
        if doc.get("schema").and_then(|s| s.as_str()) != Some("kernelsel-telemetry-v1") {
            return Err("not a kernelsel-telemetry-v1 document".to_string());
        }
        let raw_cells = doc
            .get("cells")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| "telemetry document has no cells array".to_string())?;
        let mut cells = Vec::with_capacity(raw_cells.len());
        for (i, cell) in raw_cells.iter().enumerate() {
            let dim = |key: &str| {
                cell.get(key)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| format!("cell {i}: missing/invalid {key}"))
            };
            let num = |key: &str| {
                cell.get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("cell {i}: missing/invalid {key}"))
            };
            let config = match cell.get("config") {
                Some(v) if v.is_null() => None,
                Some(v) => {
                    Some(v.as_usize().ok_or_else(|| format!("cell {i}: invalid config"))?)
                }
                None => return Err(format!("cell {i}: missing config")),
            };
            // Back-compat: `probed` was added after v1 shipped; absent (or
            // invalid, in a hand-edited file) means "no probe provenance".
            let probed =
                cell.get("probed").and_then(|v| v.as_usize()).map_or(0, |p| p as u64);
            cells.push(TelemetryCell {
                shape: GemmShape::new(dim("m")?, dim("k")?, dim("n")?, dim("batch")?),
                config,
                count: dim("count")? as u64,
                mean_secs: num("mean_secs")?,
                ewma_secs: num("ewma_secs")?,
                probed,
            });
        }
        Ok(TelemetrySnapshot { cells })
    }

    /// The snapshot as JSON (`kernelsel-telemetry-v1`; schema documented in
    /// ARCHITECTURE.md).
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("m", Json::Num(c.shape.m as f64)),
                    ("k", Json::Num(c.shape.k as f64)),
                    ("n", Json::Num(c.shape.n as f64)),
                    ("batch", Json::Num(c.shape.batch as f64)),
                    (
                        "config",
                        match c.config {
                            Some(i) => Json::Num(i as f64),
                            None => Json::Null,
                        },
                    ),
                    ("count", Json::Num(c.count as f64)),
                    ("probed", Json::Num(c.probed as f64)),
                    ("mean_secs", Json::Num(c.mean_secs)),
                    ("ewma_secs", Json::Num(c.ewma_secs)),
                    ("gflops", Json::Num(c.gflops())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Str("kernelsel-telemetry-v1".to_string())),
            ("cells", Json::Arr(cells)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> GemmShape {
        GemmShape::new(64, 64, 64, 1)
    }

    #[test]
    fn ewma_handoff_requires_min_samples() {
        let sink = TelemetrySink::new(3, 0.5);
        assert!(sink.measured_cost_secs(&shape(), Some(5)).is_none());
        sink.record(shape(), Some(5), 1e-3);
        sink.record(shape(), Some(5), 1e-3);
        assert!(sink.measured_cost_secs(&shape(), Some(5)).is_none(), "still cold");
        sink.record(shape(), Some(5), 1e-3);
        let ewma = sink.measured_cost_secs(&shape(), Some(5)).expect("warm");
        assert!((ewma - 1e-3).abs() < 1e-12);
        assert_eq!(sink.total_samples(), 3);
    }

    #[test]
    fn ewma_tracks_recent_samples() {
        let sink = TelemetrySink::new(1, 0.5);
        sink.record(shape(), None, 1.0);
        sink.record(shape(), None, 2.0);
        // 0.5 * 2 + 0.5 * 1 = 1.5
        let ewma = sink.measured_cost_secs(&shape(), None).unwrap();
        assert!((ewma - 1.5).abs() < 1e-12);
    }

    #[test]
    fn rejects_nonpositive_and_nonfinite() {
        let sink = TelemetrySink::default();
        sink.record(shape(), Some(1), 0.0);
        sink.record(shape(), Some(1), -1.0);
        sink.record(shape(), Some(1), f64::NAN);
        assert_eq!(sink.total_samples(), 0);
        assert!(sink.snapshot().cells.is_empty());
    }

    #[test]
    fn snapshot_deterministic_and_complete() {
        let sink = TelemetrySink::new(1, 0.25);
        let a = GemmShape::new(32, 32, 32, 1);
        let b = GemmShape::new(64, 64, 64, 1);
        sink.record(b, Some(2), 2e-3);
        sink.record(a, Some(1), 1e-3);
        sink.record(a, None, 3e-3);
        let snap = sink.snapshot();
        assert_eq!(snap.cells.len(), 3);
        // Sorted: (32..) before (64..); XLA (None) before configs.
        assert_eq!(snap.cells[0].shape, a);
        assert_eq!(snap.cells[0].config, None);
        assert_eq!(snap.cells[1].config, Some(1));
        assert_eq!(snap.cells[2].shape, b);
        assert_eq!(snap.measured_shapes(1), vec![a, b]);
        assert_eq!(snap.measured_shapes(2), Vec::<GemmShape>::new());
        assert!(snap.cell(&a, Some(1)).is_some());
        assert!(snap.cell(&b, None).is_none());
    }

    #[test]
    fn json_schema_fields() {
        let sink = TelemetrySink::new(1, 0.25);
        sink.record(shape(), Some(7), 1e-3);
        sink.record(shape(), None, 2e-3);
        let doc = sink.snapshot().to_json();
        assert_eq!(doc.get("schema").and_then(|s| s.as_str()), Some("kernelsel-telemetry-v1"));
        let cells = doc.get("cells").and_then(|c| c.as_arr()).unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].get("config").unwrap().is_null(), "XLA cell sorts first");
        assert_eq!(cells[1].get("config").and_then(|v| v.as_usize()), Some(7));
        for cell in cells {
            for key in
                ["m", "k", "n", "batch", "count", "probed", "mean_secs", "ewma_secs", "gflops"]
            {
                assert!(cell.get(key).is_some(), "missing {key}");
            }
        }
    }

    #[test]
    fn snapshot_json_roundtrip_restores_the_sink() {
        // Satellite acceptance: snapshot -> JSON text -> parse -> absorb
        // must reproduce every cell exactly (counts, means, EWMAs), so
        // retune state survives a restart.
        let sink = TelemetrySink::new(2, 0.25);
        let a = GemmShape::new(32, 32, 32, 1);
        let b = GemmShape::new(512, 784, 512, 1);
        sink.record(a, Some(3), 1.25e-4);
        sink.record(a, Some(3), 2.5e-4);
        sink.record(a, None, 7.5e-3);
        sink.record(b, Some(610), 3.3e-3);
        let before = sink.snapshot();
        let text = before.to_json().to_string();

        let parsed = crate::util::json::parse(&text).expect("well-formed JSON");
        let restored_snapshot = TelemetrySnapshot::from_json(&parsed).expect("valid schema");
        let fresh = TelemetrySink::new(2, 0.25);
        fresh.absorb(&restored_snapshot);
        assert_eq!(fresh.total_samples(), sink.total_samples());
        let after = fresh.snapshot();
        assert_eq!(after.cells.len(), before.cells.len());
        for (x, y) in before.cells.iter().zip(after.cells.iter()) {
            assert_eq!(x.shape, y.shape);
            assert_eq!(x.config, y.config);
            assert_eq!(x.count, y.count);
            assert!((x.mean_secs - y.mean_secs).abs() <= 1e-15 * x.mean_secs.abs());
            assert_eq!(x.ewma_secs, y.ewma_secs, "f64 JSON round-trip is exact");
        }
        // The restored EWMA drives cost hints exactly as before.
        assert_eq!(fresh.measured_cost_secs(&a, Some(3)), sink.measured_cost_secs(&a, Some(3)));
    }

    #[test]
    fn absorb_prefers_live_cells_and_skips_garbage() {
        let sink = TelemetrySink::new(1, 1.0);
        sink.record(shape(), Some(5), 2e-3); // live measurement
        let snapshot = TelemetrySnapshot {
            cells: vec![
                // Conflicts with the live cell: must lose.
                TelemetryCell {
                    shape: shape(),
                    config: Some(5),
                    count: 99,
                    mean_secs: 1e-3,
                    ewma_secs: 1e-3,
                    probed: 0,
                },
                // Fresh cell: must install (probed provenance carried, but
                // clamped to count).
                TelemetryCell {
                    shape: GemmShape::new(32, 32, 32, 1),
                    config: Some(7),
                    count: 4,
                    mean_secs: 5e-4,
                    ewma_secs: 6e-4,
                    probed: 9,
                },
                // Garbage: dropped silently.
                TelemetryCell {
                    shape: shape(),
                    config: Some(8),
                    count: 0,
                    mean_secs: 1e-3,
                    ewma_secs: 1e-3,
                    probed: 0,
                },
                TelemetryCell {
                    shape: shape(),
                    config: Some(9),
                    count: 2,
                    mean_secs: -1.0,
                    ewma_secs: 1e-3,
                    probed: 0,
                },
            ],
        };
        sink.absorb(&snapshot);
        assert_eq!(sink.measured_cost_secs(&shape(), Some(5)), Some(2e-3), "live wins");
        let restored = sink.measured_cost_secs(&GemmShape::new(32, 32, 32, 1), Some(7));
        assert_eq!(restored, Some(6e-4));
        assert!(sink.measured_cost_secs(&shape(), Some(8)).is_none());
        assert!(sink.measured_cost_secs(&shape(), Some(9)).is_none());
        assert_eq!(sink.total_samples(), 1 + 4);
        let snap = sink.snapshot();
        let fresh = snap.cell(&GemmShape::new(32, 32, 32, 1), Some(7)).unwrap();
        assert_eq!(fresh.probed, 4, "absorbed probed clamps to count");
    }

    #[test]
    fn probe_provenance_recorded_and_roundtripped() {
        // record_probe and record share one cell; only probes bump the
        // provenance counter, and it survives JSON -> absorb intact.
        let sink = TelemetrySink::new(1, 0.5);
        sink.record_probe(shape(), Some(4), 1e-3);
        sink.record(shape(), Some(4), 2e-3);
        sink.record_probe(shape(), Some(4), 3e-3);
        let snap = sink.snapshot();
        let cell = snap.cell(&shape(), Some(4)).expect("cell exists");
        assert_eq!(cell.count, 3);
        assert_eq!(cell.probed, 2);

        let text = snap.to_json().to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        let restored = TelemetrySnapshot::from_json(&parsed).unwrap();
        assert_eq!(restored.cell(&shape(), Some(4)).unwrap().probed, 2);
        let fresh = TelemetrySink::new(1, 0.5);
        fresh.absorb(&restored);
        assert_eq!(fresh.snapshot().cell(&shape(), Some(4)).unwrap().probed, 2);
    }

    #[test]
    fn from_json_defaults_missing_probed_to_zero() {
        // Pre-PR-10 kernelsel-telemetry-v1 documents carry no `probed`
        // field; they must keep loading with provenance defaulted.
        let doc = crate::util::json::parse(
            r#"{"schema":"kernelsel-telemetry-v1","cells":[
                {"m":64,"k":64,"n":64,"batch":1,"config":5,
                 "count":7,"mean_secs":0.001,"ewma_secs":0.001,"gflops":524.3}]}"#,
        )
        .unwrap();
        let snap = TelemetrySnapshot::from_json(&doc).expect("back-compat load");
        assert_eq!(snap.cells.len(), 1);
        assert_eq!(snap.cells[0].count, 7);
        assert_eq!(snap.cells[0].probed, 0);
    }

    #[test]
    fn from_json_rejects_bad_documents() {
        let bad_schema = crate::util::json::parse(r#"{"schema":"nope","cells":[]}"#).unwrap();
        assert!(TelemetrySnapshot::from_json(&bad_schema).is_err());
        let no_cells =
            crate::util::json::parse(r#"{"schema":"kernelsel-telemetry-v1"}"#).unwrap();
        assert!(TelemetrySnapshot::from_json(&no_cells).is_err());
        let bad_cell = crate::util::json::parse(
            r#"{"schema":"kernelsel-telemetry-v1","cells":[{"m":1,"k":1,"n":1}]}"#,
        )
        .unwrap();
        assert!(TelemetrySnapshot::from_json(&bad_cell).is_err());
    }

    #[test]
    fn cell_count_is_capped_but_known_cells_keep_updating() {
        let sink = TelemetrySink::new(1, 1.0);
        // Hammer one stripe's worth of distinct configs at one shape (all
        // land in the same stripe: the stripe key is the shape).
        let s = shape();
        for cfg in 0..(super::MAX_CELLS_PER_STRIPE + 50) {
            sink.record(s, Some(cfg), 1e-3);
        }
        let snap = sink.snapshot();
        assert!(snap.cells.len() <= super::MAX_CELLS_PER_STRIPE);
        // A pre-cap cell still updates after the cap is hit.
        sink.record(s, Some(0), 3e-3);
        assert_eq!(sink.measured_cost_secs(&s, Some(0)), Some(3e-3));
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let sink = std::sync::Arc::new(TelemetrySink::new(1, 0.25));
        let shapes = [
            GemmShape::new(32, 32, 32, 1),
            GemmShape::new(64, 64, 64, 1),
            GemmShape::new(128, 128, 128, 1),
        ];
        let mut joins = Vec::new();
        for t in 0..4 {
            let sink = sink.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..500 {
                    let s = shapes[(t + i) % shapes.len()];
                    sink.record(s, Some(t), 1e-4);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(sink.total_samples(), 2000);
        let snap = sink.snapshot();
        let total: u64 = snap.cells.iter().map(|c| c.count).sum();
        assert_eq!(total, 2000);
    }
}
