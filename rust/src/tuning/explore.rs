//! Exploration: epsilon-probes over unmeasured shipped configs plus a
//! first-sight micro-benchmark path, closing the retuner's exploration
//! gap.
//!
//! The background retuner (PR 3) only ever measures configurations the
//! deployed selector already picks, so the rest of the shipped pool stays
//! priced by the drift-calibrated prior forever. This module adds the
//! missing exploration half of the loop, after kubecl's runtime-autotune
//! design (micro-benchmarks cached per device, cache shipped with the
//! program) and the online-selection framing of arXiv 2003.06795:
//!
//! * **Epsilon probes** — a seeded, budget-capped fraction of live
//!   submits is redirected to an *unmeasured but shipped* configuration
//!   at the request's own shape. The draw is a pure function of
//!   `(seed, submit ordinal)` (same xoshiro-keyed determinism as the
//!   fault plan), so a probe schedule replays exactly across runs.
//! * **Admission awareness** — probes only ever take idle capacity.
//!   [`probe_would_admit`] is deliberately *stricter* than every
//!   admission policy: it demands a near-empty routed shard and at most
//!   half of any in-flight/backlog budget, so probes are shed to zero
//!   strictly before the policy itself starts rejecting in-SLO work.
//!   If admission still rejects a probe-redirected request, the pool
//!   retries the same request un-redirected — a probe can therefore
//!   never displace work that would have been admitted without it.
//! * **Quarantine screening** — probe candidates come from
//!   `healthy_shipped_configs()` and are re-checked against the breaker
//!   with the pure `blocks` read. Probes never call `screen`: the
//!   breaker's own probation trickle (the organic resolve path) stays
//!   the only way a tripped variant earns traffic.
//! * **First-sight micro-benchmarks** — the first submit of a
//!   never-seen shape bucket queues an off-hot-path micro-benchmark of
//!   the top-k prior-ranked healthy variants ([`rank_by_prior`]) on a
//!   dedicated backend instance, so the selector's answer for a new
//!   bucket is backed by measurements before it is trusted.
//!
//! Probe measurements flow into the ordinary [`TelemetrySink`] with a
//! per-cell `probed` provenance counter, persist through the extended
//! (back-compatible) `kernelsel-telemetry-v1` snapshot, and warm-start
//! the next deployment: restored coverage means the planner finds no
//! unmeasured candidates and issues zero live probes.
//!
//! [`TelemetrySink`]: crate::tuning::telemetry::TelemetrySink

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::cache::CostModel;
use crate::coordinator::registry::KernelRegistry;
use crate::dataset::GemmShape;
use crate::tuning::telemetry::{TelemetrySink, TelemetrySnapshot};
use crate::util::Rng;

/// Probes only fire while the routed shard's queue is at most this deep
/// — exploration rides idle capacity, it never joins a real queue.
pub const PROBE_MAX_QUEUE_DEPTH: usize = 2;

/// Probes only fire while the routed shard's backlog estimate is at most
/// this many nanoseconds (1 ms), regardless of the admission policy.
pub const PROBE_MAX_BACKLOG_NS: u64 = 1_000_000;

/// The exploration policy for one pool run (`--explore
/// eps,budget[,seed[,topk]]`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExploreConfig {
    /// Per-submit probability (permille) of redirecting the request to an
    /// unmeasured shipped config.
    pub eps_permille: u32,
    /// Lifetime cap on issued epsilon probes for this pool run.
    pub budget: u64,
    /// Seed of the probe schedule; the draw at submit ordinal `i` is a
    /// pure function of `(seed, i)`.
    pub seed: u64,
    /// Variants micro-benchmarked per never-seen shape bucket, ranked
    /// best-first by the cost-model prior.
    pub top_k: usize,
}

impl Default for ExploreConfig {
    /// Mild defaults: 5% probe rate, 256-probe budget, 3-variant
    /// first-sight sweep.
    fn default() -> ExploreConfig {
        ExploreConfig { eps_permille: 50, budget: 256, seed: 42, top_k: 3 }
    }
}

impl ExploreConfig {
    /// Parse an `--explore eps,budget[,seed[,topk]]` flag value. `eps` is
    /// permille (`<= 1000`), `budget` the lifetime probe cap; seed and
    /// top-k fall back to the defaults when omitted.
    pub fn parse(s: &str) -> Result<ExploreConfig, String> {
        let parts: Vec<&str> = s.split(',').collect();
        if parts.len() < 2 || parts.len() > 4 {
            return Err(format!("--explore {s}: expected eps,budget[,seed[,topk]]"));
        }
        let eps: u32 =
            parts[0].trim().parse().map_err(|_| format!("--explore eps: {}", parts[0]))?;
        if eps > 1000 {
            return Err(format!("--explore eps {eps}: permille must be <= 1000"));
        }
        let budget: u64 =
            parts[1].trim().parse().map_err(|_| format!("--explore budget: {}", parts[1]))?;
        let mut cfg = ExploreConfig { eps_permille: eps, budget, ..ExploreConfig::default() };
        if let Some(seed) = parts.get(2) {
            cfg.seed = seed.trim().parse().map_err(|_| format!("--explore seed: {seed}"))?;
        }
        if let Some(k) = parts.get(3) {
            cfg.top_k = k.trim().parse().map_err(|_| format!("--explore topk: {k}"))?;
        }
        Ok(cfg)
    }

    /// True when the policy can never fire a probe — an inert config is
    /// never armed, so the submit path stays bit-identical to a pool
    /// without exploration.
    pub fn is_inert(&self) -> bool {
        self.eps_permille == 0 || self.budget == 0
    }
}

/// The epsilon draw for submit ordinal `ordinal`: a pure function of
/// `(seed, ordinal)`, so the probe schedule is independent of thread
/// interleaving and replays exactly under the same seed.
pub fn probe_draw(seed: u64, ordinal: u64, eps_permille: u32) -> bool {
    if eps_permille == 0 {
        return false;
    }
    Rng::new(seed).fork(ordinal).below(1000) < eps_permille as usize
}

/// Which of `n_candidates` unmeasured configs the probe at `ordinal`
/// targets. Continues the same per-ordinal stream as [`probe_draw`] (the
/// gate draw is consumed first), so `(seed, ordinal, candidate list)`
/// fully determines the redirect.
pub fn probe_pick(seed: u64, ordinal: u64, n_candidates: usize) -> usize {
    let mut rng = Rng::new(seed).fork(ordinal);
    let _gate = rng.below(1000);
    rng.below(n_candidates.max(1))
}

/// Should a probe be allowed to occupy capacity right now? Pure predicate
/// over the routed shard's gauge (`backlog_ns`, `queued_depth`), the
/// pool-wide in-flight count, and the admission policy's budgets
/// (`max_inflight`/`max_queue_ns`, `0` = that budget is uncapped).
///
/// Deliberately stricter than every admission policy: a probe needs a
/// near-idle shard ([`PROBE_MAX_QUEUE_DEPTH`], [`PROBE_MAX_BACKLOG_NS`])
/// and must leave at least half of any bounded budget untouched —
/// `2 * (inflight + 1) <= max_inflight` and `2 * backlog <= max_queue_ns`
/// — so probes hit zero strictly before the policy starts rejecting
/// in-quota work. Ported to `tools/devsim_check.py`, which sweeps the
/// stricter-than-admission invariant without a Rust toolchain.
pub fn probe_would_admit(
    backlog_ns: u64,
    queued_depth: usize,
    inflight: usize,
    max_inflight: usize,
    max_queue_ns: u64,
) -> bool {
    if queued_depth > PROBE_MAX_QUEUE_DEPTH || backlog_ns > PROBE_MAX_BACKLOG_NS {
        return false;
    }
    if max_inflight > 0 && (inflight + 1).saturating_mul(2) > max_inflight {
        return false;
    }
    if max_queue_ns > 0 && backlog_ns.saturating_mul(2) > max_queue_ns {
        return false;
    }
    true
}

/// Healthy shipped configs at `shape` with no warm measured telemetry
/// cell yet — the probe candidate set. Quarantined variants are excluded
/// by `healthy_shipped_configs` (and re-checked with `blocks` at dispatch
/// time); "unmeasured" means the sink has fewer than its `min_samples`
/// samples for the `(shape, config)` cell.
pub fn unmeasured_candidates(
    registry: &KernelRegistry,
    telemetry: &TelemetrySink,
    shape: &GemmShape,
) -> Vec<usize> {
    registry
        .healthy_shipped_configs()
        .into_iter()
        .filter(|&cfg| {
            registry
                .manifest
                .find_matmul(Some(cfg), shape.m, shape.k, shape.n, shape.batch)
                .is_some()
                && telemetry.measured_cost_secs(shape, Some(cfg)).is_none()
        })
        .collect()
}

/// The top-`k` healthy shipped configs at `shape`, ranked best-first by
/// the cost-model prior — what the first-sight micro-benchmark sweeps for
/// a never-seen bucket.
pub fn rank_by_prior(
    registry: &KernelRegistry,
    model: &CostModel,
    shape: &GemmShape,
    k: usize,
) -> Vec<usize> {
    let mut ranked: Vec<(f64, usize)> = registry
        .healthy_shipped_configs()
        .into_iter()
        .filter(|&cfg| {
            registry
                .manifest
                .find_matmul(Some(cfg), shape.m, shape.k, shape.n, shape.batch)
                .is_some()
        })
        .map(|cfg| (model.predict_secs(shape, Some(cfg)), cfg))
        .collect();
    ranked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    ranked.into_iter().take(k.max(1)).map(|(_, cfg)| cfg).collect()
}

/// Measured coverage of the healthy shipped matrix: of every
/// `(shape bucket, healthy shipped config)` pair the manifest can serve,
/// how many have a telemetry cell with at least `min_samples` samples.
/// Returns `(measured, total)`; the exploration acceptance gate demands
/// `measured / total >= 0.9` within the probe budget.
pub fn measured_coverage(
    snapshot: &TelemetrySnapshot,
    registry: &KernelRegistry,
    min_samples: u64,
) -> (usize, usize) {
    let pool = registry.healthy_shipped_configs();
    let mut measured = 0usize;
    let mut total = 0usize;
    for bucket in registry.buckets() {
        for &cfg in &pool {
            if registry
                .manifest
                .find_matmul(Some(cfg), bucket.m, bucket.k, bucket.n, bucket.batch)
                .is_none()
            {
                continue;
            }
            total += 1;
            if snapshot.cell(&bucket, Some(cfg)).is_some_and(|c| c.count >= min_samples) {
                measured += 1;
            }
        }
    }
    (measured, total)
}

/// Point-in-time exploration counters (reports, metrics exposition).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExploreStats {
    /// Epsilon probes actually dispatched (counted against the budget).
    pub probes_issued: u64,
    /// Probe draws that fired but were refused capacity (load, budget
    /// exhaustion, admission retry) — the shed-first guarantee at work.
    pub probes_shed: u64,
    /// Probe executions whose measurement reached the telemetry sink.
    pub probes_completed: u64,
    /// Never-seen shape buckets handed to the first-sight path.
    pub first_sight_shapes: u64,
    /// Micro-benchmark executions run by the first-sight path.
    pub first_sight_runs: u64,
}

/// Shared exploration state for one pool run: the deterministic submit
/// ordinal, budget accounting, and the first-sight dedup set.
///
/// The planner is intentionally dumb about *where* its numbers come from
/// — the pool feeds it gauge readings and candidate sets; every decision
/// reduces to the pure functions above, which is what makes the schedule
/// replayable and the predicates portable to `tools/devsim_check.py`.
#[derive(Debug)]
pub struct ExplorePlanner {
    cfg: ExploreConfig,
    ordinal: AtomicU64,
    issued: AtomicU64,
    shed: AtomicU64,
    completed: AtomicU64,
    first_sight_shapes: AtomicU64,
    first_sight_runs: AtomicU64,
    seen: Mutex<HashSet<GemmShape>>,
}

impl ExplorePlanner {
    /// A planner for one pool run under `cfg`.
    pub fn new(cfg: ExploreConfig) -> ExplorePlanner {
        ExplorePlanner {
            cfg,
            ordinal: AtomicU64::new(0),
            issued: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            first_sight_shapes: AtomicU64::new(0),
            first_sight_runs: AtomicU64::new(0),
            seen: Mutex::new(HashSet::new()),
        }
    }

    /// The policy this planner runs.
    pub fn config(&self) -> ExploreConfig {
        self.cfg
    }

    /// Claim the next submit ordinal (one relaxed `fetch_add` on the
    /// explore-armed submit path).
    pub fn next_ordinal(&self) -> u64 {
        self.ordinal.fetch_add(1, Ordering::Relaxed)
    }

    /// Does the epsilon draw fire at `ordinal`, with budget remaining?
    /// The draw itself is pure ([`probe_draw`]); the budget guard reads
    /// the issued counter, so once `budget` probes have been dispatched
    /// every later draw is treated as shed.
    pub fn should_probe(&self, ordinal: u64) -> bool {
        if !probe_draw(self.cfg.seed, ordinal, self.cfg.eps_permille) {
            return false;
        }
        if self.issued.load(Ordering::Relaxed) >= self.cfg.budget {
            self.note_shed();
            return false;
        }
        true
    }

    /// The candidate index the probe at `ordinal` targets (see
    /// [`probe_pick`]).
    pub fn pick(&self, ordinal: u64, n_candidates: usize) -> usize {
        probe_pick(self.cfg.seed, ordinal, n_candidates)
    }

    /// Count one dispatched probe against the budget.
    pub fn note_issued(&self) {
        self.issued.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one fired-but-refused probe (load, budget, admission retry).
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one probe measurement that reached the telemetry sink.
    pub fn note_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// First submit of `shape` this run? True exactly once per bucket —
    /// the caller then queues the first-sight micro-benchmark for it.
    pub fn first_sight(&self, shape: GemmShape) -> bool {
        let fresh = self.seen.lock().unwrap().insert(shape);
        if fresh {
            self.first_sight_shapes.fetch_add(1, Ordering::Relaxed);
        }
        fresh
    }

    /// Count one first-sight micro-benchmark execution.
    pub fn note_first_sight_run(&self) {
        self.first_sight_runs.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time counter snapshot.
    pub fn stats(&self) -> ExploreStats {
        ExploreStats {
            probes_issued: self.issued.load(Ordering::Relaxed),
            probes_shed: self.shed.load(Ordering::Relaxed),
            probes_completed: self.completed.load(Ordering::Relaxed),
            first_sight_shapes: self.first_sight_shapes.load(Ordering::Relaxed),
            first_sight_runs: self.first_sight_runs.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::AdmissionPolicy;
    use crate::coordinator::selector::SelectorPolicy;
    use crate::runtime::Manifest;

    #[test]
    fn parse_accepts_all_arities() {
        let two = ExploreConfig::parse("50,256").unwrap();
        assert_eq!(two, ExploreConfig { eps_permille: 50, budget: 256, seed: 42, top_k: 3 });
        let three = ExploreConfig::parse("100,64,7").unwrap();
        assert_eq!(three.seed, 7);
        let four = ExploreConfig::parse("100, 64, 7, 5").unwrap();
        assert_eq!((four.eps_permille, four.budget, four.seed, four.top_k), (100, 64, 7, 5));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "50", "1001,10", "x,10", "50,y", "50,10,z", "50,10,1,k", "1,2,3,4,5"] {
            assert!(ExploreConfig::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn inert_configs_never_fire() {
        assert!(ExploreConfig { eps_permille: 0, ..Default::default() }.is_inert());
        assert!(ExploreConfig { budget: 0, ..Default::default() }.is_inert());
        assert!(!ExploreConfig::default().is_inert());
        for i in 0..1000 {
            assert!(!probe_draw(42, i, 0));
        }
    }

    #[test]
    fn draw_is_deterministic_and_seed_sensitive() {
        let a: Vec<bool> = (0..4096).map(|i| probe_draw(11, i, 50)).collect();
        let b: Vec<bool> = (0..4096).map(|i| probe_draw(11, i, 50)).collect();
        assert_eq!(a, b, "same seed, same schedule");
        let c: Vec<bool> = (0..4096).map(|i| probe_draw(12, i, 50)).collect();
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn draw_frequency_matches_eps_over_10k() {
        // Satellite acceptance: over 10k submits the probe fraction lands
        // within eps +/- tolerance (3-sigma of a Bernoulli(0.05) sum).
        let n = 10_000u64;
        let eps = 50u32; // 5%
        let fired = (0..n).filter(|&i| probe_draw(42, i, eps)).count() as f64;
        let expect = n as f64 * eps as f64 / 1000.0;
        let sigma = (n as f64 * 0.05 * 0.95).sqrt();
        assert!(
            (fired - expect).abs() <= 3.0 * sigma,
            "fired {fired} vs expected {expect} +/- {:.1}",
            3.0 * sigma
        );
    }

    #[test]
    fn pick_is_deterministic_and_in_range() {
        for n in [1usize, 2, 3, 17] {
            for i in 0..256 {
                let p = probe_pick(42, i, n);
                assert!(p < n);
                assert_eq!(p, probe_pick(42, i, n));
            }
        }
        // Degenerate candidate count never panics.
        assert_eq!(probe_pick(42, 0, 0), 0);
        // All candidates are reachable.
        let hit: HashSet<usize> = (0..256).map(|i| probe_pick(42, i, 3)).collect();
        assert_eq!(hit.len(), 3);
    }

    #[test]
    fn probe_admit_is_strictly_tighter_than_bounded_admission() {
        // Sweep the gauge space: wherever the probe predicate admits, the
        // BoundedQueue policy must admit too — probes are shed to zero
        // strictly before in-quota work is rejected. Mirrored in
        // tools/devsim_check.py.
        for max_inflight in [2usize, 4, 8, 64] {
            for max_queue_ns in [100_000u64, 1_000_000, 10_000_000] {
                let policy = AdmissionPolicy::BoundedQueue { max_inflight, max_queue_ns };
                for inflight in 0..=(max_inflight + 2) {
                    for backlog_ns in
                        [0u64, 40_000, 60_000, 500_000, 999_999, 1_000_001, 20_000_000]
                    {
                        for depth in [0usize, 1, 2, 3, 50] {
                            if !probe_would_admit(
                                backlog_ns,
                                depth,
                                inflight,
                                max_inflight,
                                max_queue_ns,
                            ) {
                                continue;
                            }
                            assert!(
                                policy
                                    .admit_with_drain(1, backlog_ns, inflight, depth, 0.0)
                                    .is_ok(),
                                "probe admitted where policy rejects: backlog={backlog_ns} \
                                 depth={depth} inflight={inflight}/{max_inflight} \
                                 queue_ns={max_queue_ns}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn probe_admit_requires_idle_shard() {
        assert!(probe_would_admit(0, 0, 0, 0, 0));
        assert!(!probe_would_admit(0, PROBE_MAX_QUEUE_DEPTH + 1, 0, 0, 0));
        assert!(!probe_would_admit(PROBE_MAX_BACKLOG_NS + 1, 0, 0, 0, 0));
        // Half-budget rules.
        assert!(probe_would_admit(0, 0, 0, 4, 0));
        assert!(probe_would_admit(0, 0, 1, 4, 0));
        assert!(!probe_would_admit(0, 0, 2, 4, 0));
        assert!(probe_would_admit(400_000, 0, 0, 0, 800_000));
        assert!(!probe_would_admit(400_001, 0, 0, 0, 800_000));
    }

    #[test]
    fn planner_budget_caps_issued_probes() {
        let planner = ExplorePlanner::new(ExploreConfig {
            eps_permille: 1000, // every draw fires
            budget: 5,
            seed: 1,
            top_k: 3,
        });
        let mut issued = 0u64;
        for _ in 0..100 {
            let ord = planner.next_ordinal();
            if planner.should_probe(ord) {
                planner.note_issued();
                issued += 1;
            }
        }
        assert_eq!(issued, 5, "budget caps issuance");
        let stats = planner.stats();
        assert_eq!(stats.probes_issued, 5);
        assert_eq!(stats.probes_shed, 95, "post-budget draws count as shed");
    }

    #[test]
    fn first_sight_fires_once_per_bucket() {
        let planner = ExplorePlanner::new(ExploreConfig::default());
        let a = GemmShape::new(64, 64, 64, 1);
        let b = GemmShape::new(128, 128, 128, 1);
        assert!(planner.first_sight(a));
        assert!(!planner.first_sight(a));
        assert!(planner.first_sight(b));
        assert_eq!(planner.stats().first_sight_shapes, 2);
    }

    #[test]
    fn candidates_and_ranking_respect_manifest_and_telemetry() {
        let registry = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let telemetry = TelemetrySink::new(1, 0.5);
        let shape = registry.buckets()[0];
        let cold = unmeasured_candidates(&registry, &telemetry, &shape);
        assert!(!cold.is_empty(), "synthetic manifest ships configs at every bucket");
        // Measure one candidate: it drops out of the unmeasured set.
        telemetry.record(shape, Some(cold[0]), 1e-3);
        let warmer = unmeasured_candidates(&registry, &telemetry, &shape);
        assert_eq!(warmer.len(), cold.len() - 1);
        assert!(!warmer.contains(&cold[0]));

        let model = CostModel::devsim("i7-6700k");
        let ranked = rank_by_prior(&registry, &model, &shape, 3);
        assert!(ranked.len() <= 3 && !ranked.is_empty());
        // Every ranked config is shipped at the shape.
        for &cfg in &ranked {
            assert!(registry
                .manifest
                .find_matmul(Some(cfg), shape.m, shape.k, shape.n, shape.batch)
                .is_some());
        }
        // Ranking is by ascending predicted cost.
        let costs: Vec<f64> =
            ranked.iter().map(|&c| model.predict_secs(&shape, Some(c))).collect();
        assert!(costs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn coverage_counts_measured_pairs() {
        let registry = KernelRegistry::new(Manifest::synthetic(), SelectorPolicy::Xla);
        let telemetry = TelemetrySink::new(1, 0.5);
        let (measured, total) = measured_coverage(&telemetry.snapshot(), &registry, 1);
        assert_eq!(measured, 0);
        assert!(total > 0);
        // Measure every pair: coverage reaches 100%.
        for bucket in registry.buckets() {
            for cfg in registry.healthy_shipped_configs() {
                if registry
                    .manifest
                    .find_matmul(Some(cfg), bucket.m, bucket.k, bucket.n, bucket.batch)
                    .is_some()
                {
                    telemetry.record(bucket, Some(cfg), 1e-3);
                }
            }
        }
        let (measured, total2) = measured_coverage(&telemetry.snapshot(), &registry, 1);
        assert_eq!(total2, total);
        assert_eq!(measured, total);
        // min_samples gates coverage: demanding 2 samples resets it.
        let (strict, _) = measured_coverage(&telemetry.snapshot(), &registry, 2);
        assert_eq!(strict, 0);
    }
}
