//! TPU-viability estimates for the kernel configuration space (DESIGN.md
//! §8): interpret-mode wallclock is not a TPU proxy, so real-TPU prospects
//! are assessed analytically from the BlockSpec geometry — VMEM footprint
//! of the working set and MXU systolic-array utilization of the block
//! shapes.

use crate::dataset::{all_configs, config_by_name, KernelConfig};
use crate::util::table::{fnum, Table};

/// VMEM budget of a TPU core (v4-ish), bytes.
pub const VMEM_BUDGET: usize = 16 * 1024 * 1024;

/// MXU systolic tile edge.
const MXU: f64 = 128.0;

/// Utilization of one dimension against the 128-wide systolic array:
/// blocks are padded up to multiples of 128 lanes.
fn dim_util(d: usize) -> f64 {
    let d = d as f64;
    d / ((d / MXU).ceil() * MXU)
}

/// Estimated MXU utilization of a configuration's output block.
pub fn mxu_utilization(cfg: &KernelConfig) -> f64 {
    // K-chunk >= 32 everywhere, deeper than the 8-stage bf16 pipeline, so
    // the K dimension never starves the array; block M/N padding dominates.
    dim_util(cfg.block_m()) * dim_util(cfg.block_n())
}

/// Whether the double-buffered working set fits VMEM at a given K depth.
pub fn fits_vmem(cfg: &KernelConfig, dtype_bytes: usize) -> bool {
    2 * cfg.vmem_bytes(dtype_bytes) <= VMEM_BUDGET
}

/// The TPU-viability table: VMEM fit and MXU utilization for the shipped
/// deployment plus the extreme corners of the configuration space.
pub fn tpu_estimates() -> Vec<Table> {
    let mut t = Table::new(
        "TPU-viability estimates per kernel configuration (DESIGN.md §8)",
        &["config", "block", "k_chunk", "VMEM KiB (2x buf)", "fits 16MiB", "MXU util"],
    );
    // The shipped deployment plus the extreme corners of the space.
    let mut names: Vec<String> = vec![
        "r2a8c1_wg8x32",
        "r2a8c4_wg16x16",
        "r4a4c4_wg8x32",
        "r4a8c4_wg8x32",
        "r4a8c4_wg16x16",
        "r8a4c4_wg8x32",
        "r1a4c2_wg1x64",
        "r8a8c8_wg16x16",
        "r1a1c1_wg1x64",
        "r8a8c8_wg128x1",
    ]
    .into_iter()
    .map(String::from)
    .collect();
    names.dedup();
    for name in names {
        let cfg = config_by_name(&name).expect("known config");
        t.row(vec![
            name,
            format!("{}x{}", cfg.block_m(), cfg.block_n()),
            cfg.k_chunk().to_string(),
            fnum(2.0 * cfg.vmem_bytes(4) as f64 / 1024.0, 1),
            if fits_vmem(&cfg, 4) { "yes".into() } else { "NO".into() },
            fnum(mxu_utilization(&cfg), 3),
        ]);
    }
    let viable = all_configs()
        .iter()
        .filter(|c| fits_vmem(c, 4) && mxu_utilization(c) >= 0.25)
        .count();
    t.note(&format!(
        "{viable}/640 configurations are TPU-viable (fit 2x-buffered VMEM \
         and reach >=25% MXU utilization); the deployment pipeline would \
         restrict the search space to these on real TPU hardware"
    ));
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mxu_util_monotone_to_block_size() {
        let small = config_by_name("r1a1c1_wg1x64").unwrap(); // 1x64
        let big = config_by_name("r8a8c8_wg16x16").unwrap(); // 128x128
        assert!(mxu_utilization(&big) > mxu_utilization(&small));
        assert!((mxu_utilization(&big) - 1.0).abs() < 1e-12); // 128x128 exact
    }

    #[test]
    fn all_configs_fit_vmem_at_f32() {
        // Largest block is 1024x8 with k_chunk 256: comfortably in VMEM.
        for cfg in all_configs() {
            assert!(fits_vmem(&cfg, 4), "{}", cfg.name());
        }
    }

    #[test]
    fn table_renders() {
        let t = &tpu_estimates()[0];
        assert!(t.rows.len() >= 9);
        assert!(t.notes[0].contains("/640"));
    }
}
