//! The experiment harness: one driver per figure/table of the paper's
//! evaluation (DESIGN.md §5). Every driver prints the paper-style rows as a
//! console table and dumps CSV into a results directory.

pub mod classifier_tables;
pub mod figures_data;
pub mod selection_figs;
pub mod tpu_est;
pub mod vgg_fig;

use crate::dataset::{benchmark_shapes, PerfDataset};
use crate::devsim::{generate_dataset, profile_by_name};
use crate::util::Table;
use std::path::Path;

/// Shared experiment context: simulated datasets are generated once.
pub struct Context {
    /// Master seed every stochastic stage (splits, k-means, forests) forks from.
    pub seed: u64,
    /// Take every `stride`-th benchmark shape (1 = the full suite; larger
    /// strides keep tests fast).
    pub stride: usize,
    datasets: std::cell::RefCell<std::collections::HashMap<String, std::rc::Rc<PerfDataset>>>,
}

impl Context {
    /// Full-suite context (stride 1) from a master seed.
    pub fn new(seed: u64) -> Context {
        Context { seed, stride: 1, datasets: Default::default() }
    }

    /// Subsampled context for fast tests.
    pub fn with_stride(seed: u64, stride: usize) -> Context {
        Context { seed, stride: stride.max(1), datasets: Default::default() }
    }

    /// The simulated benchmark dataset for a device (cached).
    pub fn dataset(&self, device: &str) -> std::rc::Rc<PerfDataset> {
        if let Some(ds) = self.datasets.borrow().get(device) {
            return ds.clone();
        }
        let profile = profile_by_name(device)
            .unwrap_or_else(|| panic!("unknown device {device}"));
        let mut shapes: Vec<_> = benchmark_shapes()
            .into_iter()
            .step_by(self.stride)
            .collect();
        // Striding must never drop the Figure-1/4 reference shapes.
        for &(m, k, n, b) in &figures_data::FIG1_SHAPES {
            let s = crate::dataset::GemmShape::new(m, k, n, b);
            if !shapes.contains(&s) {
                shapes.push(s);
            }
        }
        let ds = std::rc::Rc::new(generate_dataset(profile, &shapes));
        self.datasets
            .borrow_mut()
            .insert(device.to_string(), ds.clone());
        ds
    }
}

/// All experiment identifiers, in paper order.
pub const ALL_EXPERIMENTS: [&str; 10] = [
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "tab1", "tab2", "fig7",
    "tpu-est",
];

/// Run one experiment; returns its tables.
pub fn run(id: &str, ctx: &Context, artifacts_dir: &Path) -> Result<Vec<Table>, String> {
    match id {
        "fig1" => Ok(figures_data::fig1(ctx)),
        "fig2" => Ok(figures_data::fig2(ctx)),
        "fig3" => Ok(figures_data::fig3(ctx)),
        "fig4" => Ok(figures_data::fig4(ctx)),
        "fig5" => Ok(selection_figs::fig5(ctx)),
        "fig6" => Ok(selection_figs::fig6(ctx)),
        "tab1" => Ok(classifier_tables::tab1(ctx)),
        "tab2" => Ok(classifier_tables::tab2(ctx)),
        "fig7" => vgg_fig::fig7(ctx, artifacts_dir),
        "tpu-est" => Ok(tpu_est::tpu_estimates()),
        other => Err(format!(
            "unknown experiment {other:?}; known: {}",
            ALL_EXPERIMENTS.join(", ")
        )),
    }
}

/// Run one or all experiments, printing tables and dumping CSVs.
pub fn run_and_save(
    id: &str,
    ctx: &Context,
    artifacts_dir: &Path,
    out_dir: Option<&Path>,
) -> Result<(), String> {
    let ids: Vec<&str> = if id == "all" {
        ALL_EXPERIMENTS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let tables = run(id, ctx, artifacts_dir)?;
        for (i, t) in tables.iter().enumerate() {
            println!("{}", t.render());
            if let Some(dir) = out_dir {
                std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
                let fname = format!("{id}_{i}.csv");
                std::fs::write(dir.join(&fname), t.to_csv())
                    .map_err(|e| e.to_string())?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_caches_datasets() {
        let ctx = Context::new(1);
        let a = ctx.dataset("r9-nano");
        let b = ctx.dataset("r9-nano");
        assert!(std::rc::Rc::ptr_eq(&a, &b));
        assert_eq!(a.n_shapes(), benchmark_shapes().len());
    }

    #[test]
    fn unknown_experiment_rejected() {
        let ctx = Context::new(1);
        assert!(run("fig99", &ctx, Path::new(".")).is_err());
    }
}
