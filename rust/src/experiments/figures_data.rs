//! Figures 1-4: dataset-structure experiments (paper §3).

use crate::dataset::{
    config_by_index, GemmShape, Normalization, ALL_NORMALIZATIONS, NUM_CONFIGS,
};
use crate::linalg::stats::argmax;
use crate::ml::pca::Pca;
use crate::util::table::{fnum, Table};

use super::Context;

/// Figure 1's three benchmark size sets (m, k, n, batch).
pub const FIG1_SHAPES: [(usize, usize, usize, usize); 3] =
    [(512, 784, 512, 16), (512, 4608, 784, 1), (32, 12321, 27, 1)];

/// Figure 1: the per-configuration performance distribution for three size
/// sets on the AMD GPU — square performs best in few configs, tall-skinny
/// poorly everywhere.
pub fn fig1(ctx: &Context) -> Vec<Table> {
    let ds = ctx.dataset("r9-nano");
    let mut tables = Vec::new();
    for &(m, k, n, b) in &FIG1_SHAPES {
        let row = ds
            .shapes
            .iter()
            .position(|s| *s == GemmShape::new(m, k, n, b));
        let Some(r) = row else {
            continue;
        };
        let perf = ds.gflops.row(r);
        let mut order: Vec<usize> = (0..NUM_CONFIGS).collect();
        order.sort_by(|&a, &bb| perf[bb].partial_cmp(&perf[a]).unwrap());
        let best = perf[order[0]];
        let over2tf = perf.iter().filter(|&&p| p > 2000.0).count();
        let over3tf = perf.iter().filter(|&&p| p > 3000.0).count();

        let mut t = Table::new(
            &format!("Fig 1: config performance, m={m} k={k} n={n} batch={b} (r9-nano sim)"),
            &["rank", "config", "gflops", "% of best"],
        );
        for (rank, &c) in order.iter().take(5).enumerate() {
            t.row(vec![
                format!("{}", rank + 1),
                config_by_index(c).name(),
                fnum(perf[c], 1),
                fnum(100.0 * perf[c] / best, 1),
            ]);
        }
        t.row(vec!["...".into(), "median".into(), fnum(perf[order[NUM_CONFIGS / 2]], 1), fnum(100.0 * perf[order[NUM_CONFIGS / 2]] / best, 1)]);
        for (rank, &c) in order.iter().rev().take(3).rev().enumerate() {
            t.row(vec![
                format!("{}", NUM_CONFIGS - 2 + rank),
                config_by_index(c).name(),
                fnum(perf[c], 1),
                fnum(100.0 * perf[c] / best, 1),
            ]);
        }
        t.note(&format!(
            "{over2tf} configs over 2 TFLOP/s, {over3tf} over 3 TFLOP/s \
             (paper, square case: 55 and 7)"
        ));
        tables.push(t);
    }
    tables
}

/// Figure 2: how many size sets each configuration wins; the long tail.
pub fn fig2(ctx: &Context) -> Vec<Table> {
    let mut tables = Vec::new();
    for device in ["r9-nano", "i7-6700k"] {
        let ds = ctx.dataset(device);
        let counts = ds.winner_counts();
        let mut order: Vec<usize> = (0..NUM_CONFIGS).collect();
        order.sort_by(|&a, &b| counts[b].cmp(&counts[a]));
        let winners = counts.iter().filter(|&&c| c > 0).count();

        let mut t = Table::new(
            &format!("Fig 2: times each config is optimal ({device} sim)"),
            &["config", "wins"],
        );
        for &c in order.iter().take(12) {
            if counts[c] == 0 {
                break;
            }
            t.row(vec![config_by_index(c).name(), counts[c].to_string()]);
        }
        t.note(&format!(
            "{winners} distinct configs win at least one of {} size sets \
             (paper: 80 on the AMD GPU / 68 on the CPU of 300)",
            ds.n_shapes()
        ));
        tables.push(t);
    }
    tables
}

/// Figure 3: PCA explained-variance per component.
pub fn fig3(ctx: &Context) -> Vec<Table> {
    let mut tables = Vec::new();
    for device in ["r9-nano", "i7-6700k"] {
        let ds = ctx.dataset(device);
        let normalized = ds.normalized(Normalization::Standard);
        let pca = Pca::fit(&normalized, 20);
        let mut t = Table::new(
            &format!("Fig 3: PCA explained variance ({device} sim)"),
            &["component", "% variance", "cumulative %"],
        );
        let mut cum = 0.0;
        let mut landmarks = (None, None, None);
        for (i, &r) in pca.explained_variance_ratio.iter().take(20).enumerate() {
            cum += r * 100.0;
            t.row(vec![
                format!("{}", i + 1),
                fnum(r * 100.0, 2),
                fnum(cum, 2),
            ]);
            if cum >= 80.0 && landmarks.0.is_none() {
                landmarks.0 = Some(i + 1);
            }
            if cum >= 90.0 && landmarks.1.is_none() {
                landmarks.1 = Some(i + 1);
            }
            if cum >= 95.0 && landmarks.2.is_none() {
                landmarks.2 = Some(i + 1);
            }
        }
        t.note(&format!(
            "80%/90%/95% variance at {:?}/{:?}/{:?} components \
             (paper: 4/7/14 AMD, 4/6/11 Intel)",
            landmarks.0, landmarks.1, landmarks.2
        ));
        tables.push(t);
    }
    tables
}

/// Figure 4: the four normalization schemes on the best-performing size set.
pub fn fig4(ctx: &Context) -> Vec<Table> {
    let ds = ctx.dataset("r9-nano");
    let (m, k, n, b) = FIG1_SHAPES[0];
    let r = ds
        .shapes
        .iter()
        .position(|s| *s == GemmShape::new(m, k, n, b))
        .expect("fig1 shape in dataset");
    let raw = ds.gflops.row(r).to_vec();
    let best = argmax(&raw);

    // Show configs achieving over 75% of best (as the paper's plot does).
    let cutoff = 0.75 * raw[best];
    let mut shown: Vec<usize> = (0..NUM_CONFIGS).filter(|&c| raw[c] >= cutoff).collect();
    shown.sort_by(|&a, &bb| raw[bb].partial_cmp(&raw[a]).unwrap());
    shown.truncate(14);

    let mut t = Table::new(
        &format!("Fig 4: normalization schemes, m={m} k={k} n={n} b={b} (configs >75% of best)"),
        &["config", "gflops", "standard", "raw-cutoff", "cutoff", "sigmoid"],
    );
    let normalized: Vec<Vec<f64>> = ALL_NORMALIZATIONS
        .iter()
        .map(|norm| {
            let mut row = raw.clone();
            norm.apply_row(&mut row);
            row
        })
        .collect();
    for &c in &shown {
        t.row(vec![
            config_by_index(c).name(),
            fnum(raw[c], 1),
            fnum(normalized[0][c], 3),
            fnum(normalized[1][c], 3),
            fnum(normalized[2][c], 3),
            fnum(normalized[3][c], 3),
        ]);
    }
    t.note("raw-cutoff keeps survivors unscaled; cutoff rescales to [0,1]; sigmoid maps 85% -> 0.5");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_has_three_tables_with_landmarks() {
        let ctx = Context::new(1);
        let tables = fig1(&ctx);
        assert_eq!(tables.len(), 3);
        // Square case strongest, tall-skinny weakest.
        let best_of = |t: &Table| t.rows[0][2].parse::<f64>().unwrap();
        assert!(best_of(&tables[0]) > best_of(&tables[2]) * 10.0);
    }

    #[test]
    fn fig2_reports_long_tail() {
        let ctx = Context::new(1);
        let tables = fig2(&ctx);
        assert_eq!(tables.len(), 2);
        assert!(tables[0].notes[0].contains("distinct configs"));
    }

    #[test]
    fn fig3_cumulative_monotone() {
        let ctx = Context::new(1);
        for t in fig3(&ctx) {
            let mut prev = 0.0;
            for row in &t.rows {
                let cum: f64 = row[2].parse().unwrap();
                assert!(cum >= prev);
                prev = cum;
            }
            // Structured data: majority of variance in few components.
            let first: f64 = t.rows[0][1].parse().unwrap();
            assert!(first > 20.0, "first component only {first}%");
        }
    }

    #[test]
    fn fig4_best_config_normalizes_high() {
        let ctx = Context::new(1);
        let t = &fig4(&ctx)[0];
        let top = &t.rows[0];
        for col in 2..6 {
            let v: f64 = top[col].parse().unwrap();
            assert!(v > 0.97, "col {col} = {v}");
        }
    }
}
